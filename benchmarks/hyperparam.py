"""Paper Fig. 3/4: sensitivity of RWSADMM to β and κ."""
from __future__ import annotations

import csv
import os

from repro.fl.simulation import run_simulation
from repro.models.small import get_model

from .common import emit, make_trainer, mnist_like_fed


def run(rounds: int = 80, out_dir: str = "results/bench") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    data, shape = mnist_like_fed(n_clients=10, n_samples=1500)
    model = get_model("mlr", shape)
    rows = []
    for beta in (0.5, 1.0, 5.0, 10.0, 100.0):
        tr = make_trainer("rwsadmm", model, data, beta=beta)
        res = run_simulation(tr, rounds=rounds, eval_every=rounds, seed=0)
        rows.append({"param": "beta", "value": beta,
                     "acc": round(100 * res.final["acc"], 2)})
        emit(f"hyper/beta{beta}", res.wall_time_s / rounds * 1e6,
             f"acc={rows[-1]['acc']}%")
    for kappa in (0.0001, 0.001, 0.01, 0.1):
        tr = make_trainer("rwsadmm", model, data, kappa=kappa)
        res = run_simulation(tr, rounds=rounds, eval_every=rounds, seed=0)
        rows.append({"param": "kappa", "value": kappa,
                     "acc": round(100 * res.final["acc"], 2)})
        emit(f"hyper/kappa{kappa}", res.wall_time_s / rounds * 1e6,
             f"acc={rows[-1]['acc']}%")
    with open(os.path.join(out_dir, "hyperparam.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
