"""Paper Table 1: converged accuracy (%) + wall time per algorithm ×
dataset × model. Offline synthetic stand-ins (DESIGN.md §7.1); the claim
validated is the ORDERING (RWSADMM ≥ personalized baselines ≫ FedAvg
under pathological non-IID), not absolute MNIST digits.
"""
from __future__ import annotations

import csv
import os

from repro.fl.simulation import run_simulation
from repro.models.small import get_model

from .common import emit, make_trainer, mnist_like_fed, synthetic_fed

ALGOS = ["fedavg", "perfedavg", "pfedme", "ditto", "apfl", "rwsadmm"]


def run(rounds: int = 120, out_dir: str = "results/bench") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    datasets = {
        "mnist_like": mnist_like_fed(n_clients=10, n_samples=2000),
        "synthetic": synthetic_fed(n_clients=20),
    }
    for ds_name, (data, shape) in datasets.items():
        for model_name in ("mlr", "mlp"):
            model = get_model(model_name, shape)
            for algo in ALGOS:
                tr = make_trainer(algo, model, data)
                r = rounds if algo != "walkman" else rounds * 4
                res = run_simulation(tr, rounds=r, eval_every=r, seed=0)
                row = {
                    "dataset": ds_name, "model": model_name, "algo": algo,
                    "acc": round(100 * res.final["acc"], 2),
                    "acc_global": round(
                        100 * res.final.get("acc_global", 0.0), 2),
                    "time_s": round(res.wall_time_s, 1),
                    "comm_mb": round(res.total_comm_bytes / 1e6, 1),
                }
                rows.append(row)
                emit(f"table1/{ds_name}/{model_name}/{algo}",
                     res.wall_time_s / r * 1e6,
                     f"acc={row['acc']}% comm={row['comm_mb']}MB")
    with open(os.path.join(out_dir, "table1.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
