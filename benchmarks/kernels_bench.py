"""Kernel micro-benchmarks (CPU-relative; the TPU target numbers live in
the roofline report). Times the jnp oracle paths (XLA-compiled) and
derives bytes-per-call; the Pallas kernels execute in interpret mode off
TPU so their wall-time is NOT meaningful — only their validated math."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwsadmm_update.ref import (
    rwsadmm_fused_update_ref,
    rwsadmm_zone_fused_update_ref,
)

from .common import emit


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> None:
    # One derived subkey per buffer: reusing one key across draws
    # hands every buffer the same bits (q == k == v), which lets XLA
    # CSE away loads and skews the bandwidth numbers.
    root = jax.random.PRNGKey(0)
    keys = iter(jax.random.split(root, 8))

    # fused RWSADMM update, 10M params
    n = 10_000_000
    x = jax.random.normal(next(keys), (n,))
    f = jax.jit(lambda x_, z_, y_, g_: rwsadmm_fused_update_ref(
        x_, z_, y_, g_, 0.01, beta=1.0, eps_half=5e-6, n_total=20.0))
    dt = _time(f, x, x * 0.1, x + 0.01, x * 0.3)
    emit("kernel/rwsadmm_update_10M", dt * 1e6,
         f"GBps={(7 * n * 4) / dt / 1e9:.1f}")

    # masked multi-client zone update (Eq. 31), Z=8 × 1M params
    zone, n_z = 8, 1_000_000
    xs = jax.random.normal(next(keys), (zone, n_z))
    y = jax.random.normal(next(keys), (n_z,))
    mask = jnp.ones((zone,))
    f = jax.jit(lambda x_, z_, y_, g_: rwsadmm_zone_fused_update_ref(
        x_, z_, y_, g_, mask, 0.01, beta=1.0, eps_half=5e-6, n_total=20.0))
    dt = _time(f, xs, xs * 0.1, y, xs * 0.3)
    traffic = (5 * zone + 2) * n_z * 4   # (3Z+1) read + (2Z+1) write
    emit("kernel/rwsadmm_zone_update_8x1M", dt * 1e6,
         f"GBps={traffic / dt / 1e9:.1f}")

    # flash decode, 32k cache
    b, h, kv, hd, s = 4, 8, 2, 128, 32768
    q = jax.random.normal(next(keys), (b, h, hd), jnp.bfloat16)
    k = jax.random.normal(next(keys), (b, s, kv, hd), jnp.bfloat16)
    v = jax.random.normal(next(keys), (b, s, kv, hd), jnp.bfloat16)
    length = jnp.full((b,), s, jnp.int32)
    f = jax.jit(lambda q_, k_, v_: flash_decode_ref(q_, k_, v_, length))
    dt = _time(f, q, k, v)
    emit("kernel/flash_decode_32k", dt * 1e6,
         f"GBps={(2 * b * s * kv * hd * 2) / dt / 1e9:.1f}")

    # rglru scan 4k×1024
    a = jax.nn.sigmoid(jax.random.normal(next(keys), (4, 4096, 1024)))
    bb = jax.random.normal(next(keys), (4, 4096, 1024))
    f = jax.jit(rglru_scan_ref)
    dt = _time(f, a, bb)
    emit("kernel/rglru_scan_4k", dt * 1e6,
         f"GBps={(3 * a.size * 4) / dt / 1e9:.1f}")


if __name__ == "__main__":
    run()
