"""§Perf hillclimbing harness (run INSIDE the 512-device dry-run process):

  PYTHONPATH=src python -m benchmarks.perf_iterations --pair \
      tinyllama-1.1b:train_4k --option ce_impl=onehot

Runs the baseline and the optimized variant for the chosen pair, prints
the three roofline terms before/after, and appends a JSON record to
results/perf/. The hypothesis → change → measure → validate narrative is
kept in EXPERIMENTS.md §Perf.
"""
import os

from repro.launch.hostdevices import ensure_host_platform_devices

# Must precede backend init (first computation), hence top-of-module.
ensure_host_platform_devices(512)

import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import run_one  # noqa: E402

from .roofline_report import roofline_row  # noqa: E402


def terms(rec):
    r = roofline_row(rec)
    return {k: r[k] for k in ("t_compute_s", "t_memory_s",
                              "t_collective_s", "dominant",
                              "step_time_s")}


def run_smoke(pair: str = "tinyllama-1.1b:train_4k",
              timeout_s: int = 900) -> None:
    """Harness entry (``benchmarks.run``): one dry-run pair in a FRESH
    subprocess. The 512-host-device XLA flag must be set before the JAX
    backend initializes, and by the time the harness reaches this job
    earlier benchmarks have long since initialized it — so in-process
    invocation can never see the dry-run mesh."""
    import subprocess
    import sys

    from .common import REPO_ROOT, emit

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.perf_iterations",
         "--pair", pair, "--skip-baseline", "--tag", "smoke"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True,
        timeout=timeout_s)
    if res.returncode != 0:
        raise RuntimeError(
            f"perf_iterations smoke failed for {pair}:\n{res.stderr}")
    emit(f"perf_iterations/{pair}", 0.0, "dryrun=ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, help="arch:shape")
    ap.add_argument("--option", action="append", default=[],
                    help="k=v dry-run option (repeatable)")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--skip-baseline", action="store_true")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    arch, shape = args.pair.split(":")
    options = dict(kv.split("=") for kv in args.option)
    for k, v in list(options.items()):
        if v in ("True", "False"):
            options[k] = v == "True"
    os.makedirs(args.out, exist_ok=True)

    out = {"arch": arch, "shape": shape, "options": options}
    if not args.skip_baseline:
        base = run_one(arch, shape)
        out["baseline"] = {"collectives": base["collectives"],
                           "flops": base["flops"],
                           "bytes": base["bytes_accessed"],
                           "terms": terms(base)}
        print("baseline:", json.dumps(out["baseline"]["terms"], indent=1))
    opt = run_one(arch, shape, options=options)
    out["optimized"] = {"collectives": opt["collectives"],
                        "flops": opt["flops"],
                        "bytes": opt["bytes_accessed"],
                        "terms": terms(opt)}
    print("optimized:", json.dumps(out["optimized"]["terms"], indent=1))
    if "baseline" in out:
        b = out["baseline"]["terms"]["step_time_s"]
        o = out["optimized"]["terms"]["step_time_s"]
        out["speedup"] = b / max(o, 1e-12)
        print(f"roofline step-time speedup: {out['speedup']:.2f}×")

    tag = args.tag or "_".join(f"{k}-{v}" for k, v in options.items())
    path = os.path.join(args.out, f"{arch}__{shape}__{tag}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
