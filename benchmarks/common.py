"""Shared benchmark fixtures: datasets, trainer builders, CSV/JSON
helpers, and the large-n control-plane probe."""
from __future__ import annotations

import os
import resource
import sys
import time

import numpy as np

from repro.baselines import (
    APFLTrainer,
    DittoTrainer,
    FedAvgTrainer,
    PerFedAvgTrainer,
    PFedMeTrainer,
    WalkmanTrainer,
)
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import (
    make_image_dataset,
    make_synthetic_lr,
    pathological_split,
)
from repro.data.loader import build_federated, build_federated_from_pairs
from repro.fl.base import to_device_data
from repro.fl.rwsadmm_trainer import RWSADMMTrainer


def mnist_like_fed(n_clients: int = 20, n_samples: int = 3000,
                   seed: int = 0):
    imgs, labels = make_image_dataset(n_samples, seed=seed)
    idx = pathological_split(labels, n_clients, seed=seed)
    return to_device_data(build_federated(imgs, labels, idx)), (28, 28, 1)


def cifar_like_fed(n_clients: int = 20, n_samples: int = 3000,
                   seed: int = 0):
    imgs, labels = make_image_dataset(
        n_samples, shape=(32, 32, 3), noise=0.6, seed=seed)
    idx = pathological_split(labels, n_clients, seed=seed)
    return to_device_data(build_federated(imgs, labels, idx)), (32, 32, 3)


def synthetic_fed(n_clients: int = 50, seed: int = 0):
    pairs = make_synthetic_lr(n_clients, seed=seed)
    return to_device_data(build_federated_from_pairs(pairs)), (60,)


def make_trainer(algo: str, model, data, *, beta: float = 1.0,
                 kappa: float = 0.001, zone: int = 8, seed: int = 0):
    if algo == "rwsadmm":
        return RWSADMMTrainer(
            model, data,
            RWSADMMHparams(beta=beta, kappa=kappa, epsilon=1e-5),
            zone_size=zone, batch_size=32, seed=seed)
    if algo == "rwsadmm_cf":
        return RWSADMMTrainer(
            model, data, RWSADMMHparams(beta=10.0, kappa=kappa,
                                        epsilon=1e-5),
            zone_size=zone, solver="closed_form", seed=seed)
    cls = {
        "fedavg": FedAvgTrainer, "perfedavg": PerFedAvgTrainer,
        "pfedme": PFedMeTrainer, "ditto": DittoTrainer,
        "apfl": APFLTrainer,
    }.get(algo)
    if cls is not None:
        return cls(model, data, clients_per_round=min(10, data.n_clients))
    if algo == "walkman":
        return WalkmanTrainer(model, data, beta=3.0, seed=seed)
    raise ValueError(algo)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


# ---------------------------------------------------------------------------
# Machine-readable trajectory: BENCH_scaling.json at the repo root.
# ---------------------------------------------------------------------------

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_scaling.json")


def reset_peak_rss() -> None:
    """Reset the kernel's peak-RSS watermark (Linux ``clear_refs``) so
    each benchmark phase records ITS OWN peak instead of inheriting the
    process-wide high-water mark of whatever ran before it in the same
    harness process. Best-effort: silently a no-op where unsupported
    (then peaks are monotone across phases — still an upper bound)."""
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
    except OSError:
        pass


_run_peak_mb = 0.0


def peak_rss_mb() -> float:
    """Peak resident set in MB since the last :func:`reset_peak_rss`
    (VmHWM on Linux; falls back to ``ru_maxrss``, which is KB on Linux
    and bytes on macOS) — the peak-memory column of the scaling
    benchmarks. Every observation also feeds :func:`run_peak_rss_mb`."""
    global _run_peak_mb
    mb = None
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    mb = int(line.split()[1]) / 1024.0
                    break
    except OSError:
        pass
    if mb is None:
        import sys

        ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        mb = ru / (1024.0 ** 2) if sys.platform == "darwin" \
            else ru / 1024.0
    _run_peak_mb = max(_run_peak_mb, mb)
    return mb


def run_peak_rss_mb() -> float:
    """Max over every :func:`peak_rss_mb` observation this process —
    what a memory GATE should assert on: per-phase watermark resets
    make :func:`peak_rss_mb` report only the most recent phase, so
    asserting on the last reading would let an earlier phase's blow-up
    slip through."""
    return _run_peak_mb


def runtime_stamp() -> dict:
    """The jax runtime columns every bench row carries: backend name,
    visible device count, and the mesh the row ran under (``None`` =
    unsharded; sharded rows overwrite it with e.g. ``"data:8"``). Rows
    are only comparable across PRs within one runtime shape — these
    columns make that shape diffable."""
    try:
        import jax

        return {"jax_backend": jax.default_backend(),
                "device_count": int(jax.device_count()),
                "mesh": None}
    except Exception:   # jax-free tooling contexts
        return {"jax_backend": None, "device_count": None, "mesh": None}


def bench_row(name: str, *, n: int, engine: str, us_per_round: float,
              k: int = 1, **extra) -> dict:
    """One BENCH_scaling.json record (schema: name, n, K, engine,
    us_per_round, peak_rss_mb, jax_backend, device_count, mesh +
    free-form extras)."""
    row = {"name": name, "n": int(n), "K": int(k), "engine": engine,
           "us_per_round": round(float(us_per_round), 1),
           "peak_rss_mb": round(peak_rss_mb(), 1)}
    row.update(runtime_stamp())
    row.update(extra)
    return row


def backfill_bench_rows(path: str | None = None) -> str:
    """One-off migration: re-emit every existing BENCH_scaling.json row
    through the atomic writer with the :func:`runtime_stamp` columns
    backfilled. Historical rows all ran single-device CPU, so missing
    columns get exactly that; rows that already carry the columns are
    untouched."""
    from repro.telemetry import atomic_write_json, load_bench_rows

    path = path or BENCH_JSON
    rows = load_bench_rows(path)
    for r in rows:
        r.setdefault("jax_backend", "cpu")
        r.setdefault("device_count", 1)
        r.setdefault("mesh", None)
    return atomic_write_json(path, rows)


def ensure_multidevice_harness(count: int, module: str) -> None:
    """Olmax-style multi-device CPU harness (SNIPPETS §1–2): make this
    bench process see ``count`` host platform devices and run under
    tcmalloc. Call FIRST THING in ``main()`` — the XLA flag only takes
    effect before the jax backend initializes. tcmalloc can only load
    at process start, so when the library exists but is not preloaded
    the process re-execs itself ONCE (``python -m module argv…``) with
    the full env from ``launch.hostdevices``."""
    from repro.launch.hostdevices import (
        ensure_host_platform_devices,
        host_device_env,
    )

    ensure_host_platform_devices(count)
    env = host_device_env(count)
    want = env.get("LD_PRELOAD")
    if (want and want != os.environ.get("LD_PRELOAD")
            and os.environ.get("_REPRO_BENCH_REEXEC") != "1"):
        env["_REPRO_BENCH_REEXEC"] = "1"
        sys.stdout.flush()
        sys.stderr.flush()
        os.execve(sys.executable,
                  [sys.executable, "-m", module] + sys.argv[1:], env)


def write_bench_rows(rows: list[dict], path: str | None = None) -> str:
    """Merge rows into ``BENCH_scaling.json`` keyed by
    ``(name, n, K, engine)`` (so partial benchmark runs update their own
    rows without clobbering the rest) and return the path. The write
    goes through the telemetry artifacts layer — temp file +
    ``os.replace`` — so an interrupted bench can never truncate the
    repo-root trajectory file. The CSV on stdout stays the human view;
    this file is the diffable perf trajectory across PRs."""
    from repro.telemetry import (
        atomic_write_json,
        load_bench_rows,
        merge_bench_rows,
    )

    path = path or BENCH_JSON
    merged = merge_bench_rows(load_bench_rows(path), rows)
    return atomic_write_json(path, merged)


def control_plane_rate(n: int, rounds: int = 64, *,
                       mobility: str = "gauss_markov",
                       backend: str = "sparse", dropout: bool = True,
                       k_max: int = 32, zone_size: int = 8,
                       target_degree: float = 12.0,
                       rollout_chunk: int | None = 32,
                       seed: int = 0) -> float:
    """Seconds/round of pure control-plane work at scale: scenario
    rollout (mobility + link dropouts + churn-free), random-walk
    stepping, zone planning, key derivation, and wireless pricing — no
    training rounds. The radio range shrinks with n so the expected
    degree stays ~``target_degree`` (the physical regime the sparse
    backend targets: local radios, growing fleets)."""
    import dataclasses

    from repro.core import markov
    from repro.core.markov import RandomWalkServer
    from repro.scenarios import (
        LinkConfig,
        MobilityConfig,
        Scenario,
        ScenarioConfig,
    )

    reset_peak_rss()
    radio = float(np.sqrt(target_degree / (np.pi * n)))
    cfg = ScenarioConfig(
        name=f"bench_{mobility}_{backend}",
        mobility=MobilityConfig(model=mobility, radio_range=radio),
        links=LinkConfig(enabled=dropout, dropout=dropout),
        graph_backend=backend, neighbor_k_max=k_max)
    if rollout_chunk is not None:
        cfg = dataclasses.replace(cfg, rollout_chunk=rollout_chunk)
    scenario = Scenario(n, cfg, seed=seed)
    walker = RandomWalkServer(seed=seed + 1)
    walker.reset(scenario.current())
    rng = np.random.default_rng(seed)

    def price(graphs, clients, idx, mask):
        return scenario.price_schedule(graphs, clients, idx, mask, 2048)

    t0 = time.perf_counter()
    markov.zone_schedule(scenario, walker, rounds, zone_size, rng,
                         price=price)
    return (time.perf_counter() - t0) / rounds
