"""Shared benchmark fixtures: datasets, trainer builders, CSV helpers."""
from __future__ import annotations

import time

import numpy as np

from repro.baselines import (
    APFLTrainer,
    DittoTrainer,
    FedAvgTrainer,
    PerFedAvgTrainer,
    PFedMeTrainer,
    WalkmanTrainer,
)
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import (
    make_image_dataset,
    make_synthetic_lr,
    pathological_split,
)
from repro.data.loader import build_federated, build_federated_from_pairs
from repro.fl.base import to_device_data
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.models.small import get_model


def mnist_like_fed(n_clients: int = 20, n_samples: int = 3000,
                   seed: int = 0):
    imgs, labels = make_image_dataset(n_samples, seed=seed)
    idx = pathological_split(labels, n_clients, seed=seed)
    return to_device_data(build_federated(imgs, labels, idx)), (28, 28, 1)


def cifar_like_fed(n_clients: int = 20, n_samples: int = 3000,
                   seed: int = 0):
    imgs, labels = make_image_dataset(
        n_samples, shape=(32, 32, 3), noise=0.6, seed=seed)
    idx = pathological_split(labels, n_clients, seed=seed)
    return to_device_data(build_federated(imgs, labels, idx)), (32, 32, 3)


def synthetic_fed(n_clients: int = 50, seed: int = 0):
    pairs = make_synthetic_lr(n_clients, seed=seed)
    return to_device_data(build_federated_from_pairs(pairs)), (60,)


def make_trainer(algo: str, model, data, *, beta: float = 1.0,
                 kappa: float = 0.001, zone: int = 8, seed: int = 0):
    if algo == "rwsadmm":
        return RWSADMMTrainer(
            model, data,
            RWSADMMHparams(beta=beta, kappa=kappa, epsilon=1e-5),
            zone_size=zone, batch_size=32, seed=seed)
    if algo == "rwsadmm_cf":
        return RWSADMMTrainer(
            model, data, RWSADMMHparams(beta=10.0, kappa=kappa,
                                        epsilon=1e-5),
            zone_size=zone, solver="closed_form", seed=seed)
    cls = {
        "fedavg": FedAvgTrainer, "perfedavg": PerFedAvgTrainer,
        "pfedme": PFedMeTrainer, "ditto": DittoTrainer,
        "apfl": APFLTrainer,
    }.get(algo)
    if cls is not None:
        return cls(model, data, clients_per_round=min(10, data.n_clients))
    if algo == "walkman":
        return WalkmanTrainer(model, data, beta=3.0, seed=seed)
    raise ValueError(algo)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
