"""Paper Table 2 / Fig. 7: RWSADMM with 20 / 50 / 100 clients — accuracy
degrades mildly, time grows ~linearly with rounds-to-visit."""
from __future__ import annotations

import csv
import os

from repro.fl.simulation import run_simulation
from repro.models.small import get_model

from .common import emit, make_trainer, mnist_like_fed


def run(out_dir: str = "results/bench") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for n in (20, 50, 100):
        data, shape = mnist_like_fed(n_clients=n, n_samples=200 * n)
        model = get_model("mlp", shape)
        rounds = 8 * n  # visits per client roughly constant
        tr = make_trainer("rwsadmm", model, data, zone=8)
        res = run_simulation(tr, rounds=rounds, eval_every=rounds, seed=0)
        row = {
            "n_clients": n,
            "rounds": rounds,
            "acc": round(100 * res.final["acc_personalized"], 2),
            "time_s": round(res.wall_time_s, 1),
            "comm_mb": round(res.total_comm_bytes / 1e6, 1),
        }
        rows.append(row)
        emit(f"table2/clients{n}", res.wall_time_s / rounds * 1e6,
             f"acc={row['acc']}% time={row['time_s']}s")
    with open(os.path.join(out_dir, "table2_scaling.csv"), "w",
              newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
