"""Rounds-per-second scaling: eager vs scan vs scan_fused drivers.

The paper's headline claims (fast convergence, O(1) communication,
scalability) are wall-clock claims at thousands-of-rounds scale; this
benchmark measures the simulator's round throughput at n ∈ {20, 100, 500}
clients for the three RWSADMM execution engines:

  eager      — one XLA dispatch + one host sync per round (seed driver),
  scan       — whole chunk of R rounds as ONE lax.scan executable,
  scan_fused — scan + the masked multi-client Pallas zone kernel.

Timed region for scan engines includes the host-side schedule
precomputation (graphs, random walk, zone padding, keys) — the honest
end-to-end cost per chunk. Emits CSV rows:

  scan_scaling/n{N}/{engine},{us_per_round},rounds_per_s=...
  scan_scaling/n{N}/speedup,...,scan_vs_eager=...x

A second, large-n section measures the **control plane alone** (the
64-round mobility + link-dropout rollout, walk, zone planning, pricing)
at n ∈ {2000, 10000, 50000} on the sparse neighbor-list backend — the
O(n·k) lane that unblocked these sizes (the dense lane is measured at
the smallest n for reference; beyond that it is memory-blocked):

  scan_scaling/control_plane/n{N}/{backend},{us_per_round},peak_rss_mb=...

Both sections also write machine-readable rows (name, n, K, engine,
us_per_round, peak_rss_mb) into BENCH_scaling.json at the repo root, so
perf regressions are diffable across PRs.

A third section (``--lazy``) runs full TRAINING rounds at n ∈ {100k, 1M}
on the lazy client plane — bounded LRU client store + on-demand dataset
materialization (docs/performance.md §7) over the sparse control plane —
and certifies the bounded footprint via the peak_rss_mb column:

  scan_scaling/lazy_plane/n{N}/scan,{us_per_round},peak_rss_mb=...

Smoke (CI, <2 min):  python -m benchmarks.scan_scaling --rounds 30 \
    --clients 20 --no-control-plane
Sparse smoke (CI):   python -m benchmarks.scan_scaling --control-plane \
    --cp-clients 10000 --assert-rss-mb 1024
Lazy smoke (CI):     python -m benchmarks.scan_scaling --lazy \
    --lazy-clients 100000 --assert-rss-mb 2048
Full:                python -m benchmarks.scan_scaling && \
    python -m benchmarks.scan_scaling --lazy
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core.rwsadmm import RWSADMMHparams
from repro.fl.rwsadmm_trainer import ENGINES, RWSADMMTrainer
from repro.models.small import get_model

from .common import (
    bench_row,
    control_plane_rate,
    emit,
    peak_rss_mb,
    reset_peak_rss,
    run_peak_rss_mb,
    synthetic_fed,
    write_bench_rows,
)


def make_trainer(n_clients: int, seed: int = 0) -> RWSADMMTrainer:
    # The paper's Synthetic(0.5, 0.5) MLR setting (§5): the strongly
    # convex workload whose per-round compute is small enough that the
    # eager loop is dispatch-bound — the regime the scan driver targets.
    data, shape = synthetic_fed(n_clients, seed=seed)
    model = get_model("mlr", shape)
    return RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        zone_size=8, batch_size=20, solver="closed_form", seed=seed,
    )


def bench_engine(trainer: RWSADMMTrainer, engine: str, rounds: int) -> float:
    """Returns measured rounds/sec (after a warmup pass that compiles)."""
    state = trainer.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if engine == "eager":
        state, _ = trainer.round(state, 0, rng)          # compile
        jax.block_until_ready(state.server.y)
        t0 = time.perf_counter()
        for r in range(1, rounds + 1):
            state, _ = trainer.round(state, r, rng)
        jax.block_until_ready(state.server.y)
        dt = time.perf_counter() - t0
    else:
        sched = trainer.schedule(rounds, rng, start_round=0)
        state, _ = trainer.run_chunk(state, sched, engine=engine)  # compile
        jax.block_until_ready(state.server.y)
        t0 = time.perf_counter()
        sched = trainer.schedule(rounds, rng, start_round=rounds)
        state, stacked = trainer.run_chunk(state, sched, engine=engine)
        jax.block_until_ready(stacked["train_loss"])
        dt = time.perf_counter() - t0
    return rounds / dt


def run(rounds: int = 200, clients=(20, 100, 500)) -> dict:
    """Prints CSV rows; returns {n: {engine: rounds_per_s}}."""
    results: dict = {}
    json_rows = []
    for n in clients:
        per_engine: dict = {}
        for engine in ENGINES:
            reset_peak_rss()
            trainer = make_trainer(n)
            rps = bench_engine(trainer, engine, rounds)
            per_engine[engine] = rps
            emit(f"scan_scaling/n{n}/{engine}", 1e6 / rps,
                 f"rounds_per_s={rps:.1f}")
            json_rows.append(bench_row(
                f"scan_scaling/n{n}/{engine}", n=n, engine=engine,
                us_per_round=1e6 / rps))
        speed = per_engine["scan"] / per_engine["eager"]
        speed_f = per_engine["scan_fused"] / per_engine["eager"]
        emit(f"scan_scaling/n{n}/speedup", 0.0,
             f"scan_vs_eager={speed:.1f}x "
             f"scan_fused_vs_eager={speed_f:.1f}x")
        results[n] = per_engine
    write_bench_rows(json_rows)
    return results


def control_plane(clients=(2000, 10000, 50000), rounds: int = 64,
                  dense_reference: bool = True) -> dict:
    """Large-n control-plane columns on the sparse neighbor-list
    backend (+ a dense reference at the smallest n, chunked so its
    (R, n, n) stacks stay bounded). Returns {(n, backend): s_per_round}
    and appends rows to BENCH_scaling.json."""
    results: dict = {}
    json_rows = []
    todo = [(n, "sparse") for n in clients]
    if dense_reference and clients:
        # Dense last: its multi-GB footprint stays out of the sparse
        # rows even where the per-phase watermark reset (clear_refs)
        # is unavailable and peaks are monotone across phases.
        todo.append((min(clients), "dense"))
    for n, backend in todo:
        kw = {"rollout_chunk": 8} if backend == "dense" else {}
        sec = control_plane_rate(n, rounds=rounds, backend=backend, **kw)
        name = f"scan_scaling/control_plane/n{n}/{backend}"
        emit(name, sec * 1e6,
             f"rounds_per_s={1.0 / sec:.1f} "
             f"peak_rss_mb={peak_rss_mb():.0f}")
        json_rows.append(bench_row(name, n=n, engine=backend,
                                   us_per_round=sec * 1e6,
                                   rounds=rounds))
        results[(n, backend)] = sec
    write_bench_rows(json_rows)
    return results


def lazy_plane(clients=(100_000, 1_000_000), rounds: int = 32,
               capacity: int = 1024, *, shard_devices: int | None = None,
               prefetch: bool = False) -> dict:
    """Full TRAINING rounds at n up to 10⁶ on the lazy client plane:
    bounded LRU store + on-demand dataset materialization + sparse
    control plane, scan engine. The dense plane would need the (n, …)
    client stack and the (n, m, d) dataset stack — ~300 GB at n = 10⁶
    for this workload — while the lazy plane's footprint is set by
    ``capacity`` (store rows) plus the O(n·k) control plane, which is
    what the ``peak_rss_mb`` column certifies. Returns {n: s_per_round}
    and appends rows to BENCH_scaling.json.

    ``shard_devices``: place the packed store over a "data" mesh of
    that many (host platform) devices — row tag ``scan_shard{d}``; run
    under ``--shard-devices`` so the devices exist. ``prefetch``: stage
    each next chunk's dataset rows on a host thread while the current
    chunk executes (row tag suffix ``_prefetch``); the timed region
    then pipelines schedule → prefetch → chunk exactly like
    ``run_simulation`` does."""
    import dataclasses as _dc

    from repro.data import synthetic_lr_factory
    from repro.scenarios import (
        LinkConfig,
        MobilityConfig,
        ScenarioConfig,
    )

    results: dict = {}
    json_rows = []
    for n in clients:
        reset_peak_rss()
        # Narrower count tail than the paper default (mean_samples 2.0
        # vs 4.0): packed store rows are max_train wide, and one 1-in-a-
        # million lognormal straggler would pad every slot's row.
        factory = synthetic_lr_factory(
            n_clients=n, seed=0, min_samples=20, mean_samples=2.0)
        model = get_model("mlr", (60,))
        radio = float(np.sqrt(12.0 / (np.pi * n)))
        cfg = ScenarioConfig(
            name="bench_lazy_gm_sparse",
            mobility=MobilityConfig(model="gauss_markov",
                                    radio_range=radio),
            links=LinkConfig(enabled=True, dropout=True),
            graph_backend="sparse", neighbor_k_max=32)
        # Small rollout chunks: the (chunk, n, k_max) neighbor-list
        # stacks are the biggest transient at n = 10⁶ (≈0.5 GB each at
        # chunk 8) — the store itself stays capacity-bounded.
        cfg = _dc.replace(cfg, rollout_chunk=8)
        mesh = None
        if shard_devices:
            from repro.fl.sharding import FLSharding

            mesh = FLSharding(n_devices=shard_devices)
        trainer = RWSADMMTrainer(
            model, factory,
            RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
            zone_size=8, batch_size=20, solver="closed_form",
            scenario=cfg, seed=0, store_capacity=capacity,
            prefetch=prefetch, mesh=mesh)
        state = trainer.init_state(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        sched = trainer.schedule(rounds, rng, start_round=0)
        state, _ = trainer.run_chunk(state, sched, engine="scan")
        jax.block_until_ready(trainer.global_params(state))
        # Timed region: TWO pipelined chunks (schedule + ensure + scan),
        # the steady-state structure run_simulation drives — with
        # prefetch the next window's schedule/staging hides behind the
        # executing chunk, without it each window schedules up front.
        n_chunks, sched_next, r0 = 2, None, rounds
        t0 = time.perf_counter()
        for w in range(n_chunks):
            if sched_next is None:
                sched_next = trainer.schedule(rounds, rng, start_round=r0)
            sched, sched_next = sched_next, None
            r0 += rounds
            state, stacked = trainer.run_chunk(state, sched,
                                               engine="scan")
            if prefetch and w + 1 < n_chunks:
                sched_next = trainer.schedule(rounds, rng,
                                              start_round=r0)
                trainer.prefetch_chunk(sched_next)
            jax.block_until_ready(stacked["train_loss"])
        sec = (time.perf_counter() - t0) / (n_chunks * rounds)
        c = trainer.store.counters
        tag = "scan" + (f"_shard{shard_devices}" if shard_devices
                        else "") + ("_prefetch" if prefetch else "")
        name = f"scan_scaling/lazy_plane/n{n}/{tag}"
        emit(name, sec * 1e6,
             f"rounds_per_s={1.0 / sec:.1f} "
             f"peak_rss_mb={peak_rss_mb():.0f} "
             f"resident={trainer.store.n_resident}/{capacity} "
             f"miss={c['misses']} evict={c['evictions']}")
        extra = {}
        if shard_devices:
            extra["mesh"] = f"data:{shard_devices}"
        if prefetch:
            extra["prefetch_hits"] = c["prefetch_hits"]
            extra["prefetch_misses"] = c["prefetch_misses"]
        json_rows.append(bench_row(
            name, n=n, engine="scan", us_per_round=sec * 1e6,
            rounds=(n_chunks + 1) * rounds, capacity=capacity,
            resident=trainer.store.n_resident,
            store_misses=c["misses"], store_evictions=c["evictions"],
            **extra))
        results[n] = sec
        del trainer, state, sched, stacked, factory
    write_bench_rows(json_rows)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=200,
                    help="timed rounds per engine (after compile warmup)")
    ap.add_argument("--clients", type=int, nargs="+",
                    default=[20, 100, 500])
    ap.add_argument("--control-plane", action="store_true",
                    help="run ONLY the large-n control-plane columns")
    ap.add_argument("--no-control-plane", action="store_true",
                    help="skip the large-n control-plane columns")
    ap.add_argument("--cp-clients", type=int, nargs="+",
                    default=[2000, 10000, 50000],
                    help="control-plane client counts")
    ap.add_argument("--cp-rounds", type=int, default=64,
                    help="control-plane rollout window")
    ap.add_argument("--lazy", action="store_true",
                    help="run ONLY the lazy client-plane training rows")
    ap.add_argument("--lazy-clients", type=int, nargs="+",
                    default=[100_000, 1_000_000],
                    help="lazy-plane client counts")
    ap.add_argument("--lazy-rounds", type=int, default=32,
                    help="lazy-plane timed rounds (one scan chunk)")
    ap.add_argument("--lazy-capacity", type=int, default=1024,
                    help="lazy-plane store capacity (resident slots)")
    ap.add_argument("--shard-devices", type=int, default=None,
                    help="lazy plane: shard the packed store over this "
                    "many host platform devices (olmax-style multi-"
                    "device CPU harness; re-execs under tcmalloc)")
    ap.add_argument("--prefetch", action="store_true",
                    help="lazy plane: async next-chunk dataset staging")
    ap.add_argument("--assert-rss-mb", type=float, default=None,
                    help="exit nonzero if peak RSS exceeds this (the "
                    "sparse-backend / lazy-plane CI memory gate)")
    args = ap.parse_args()
    if args.shard_devices:
        from .common import ensure_multidevice_harness

        # Must precede the first computation (backend init).
        ensure_multidevice_harness(args.shard_devices,
                                   "benchmarks.scan_scaling")
    print("name,us_per_call,derived")
    if args.lazy:
        lazy_plane(clients=tuple(args.lazy_clients),
                   rounds=args.lazy_rounds,
                   capacity=args.lazy_capacity,
                   shard_devices=args.shard_devices,
                   prefetch=args.prefetch)
    else:
        if not args.control_plane:
            run(rounds=args.rounds, clients=tuple(args.clients))
        if args.control_plane or not args.no_control_plane:
            control_plane(clients=tuple(args.cp_clients),
                          rounds=args.cp_rounds,
                          dense_reference=not args.control_plane)
    if args.assert_rss_mb is not None:
        # Gate on the max over every measured phase, not the most
        # recent one (phases reset the kernel watermark) — and note the
        # dense reference phase alone needs several GB, so the gate is
        # meant for --control-plane runs (which skip it).
        peak_rss_mb()
        rss = run_peak_rss_mb()
        if rss > args.assert_rss_mb:
            print(f"FAIL: peak RSS {rss:.0f} MB > "
                  f"{args.assert_rss_mb:.0f} MB", file=sys.stderr)
            sys.exit(1)
        print(f"# peak RSS {rss:.0f} MB <= {args.assert_rss_mb:.0f} MB")


if __name__ == "__main__":
    main()
