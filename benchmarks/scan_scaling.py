"""Rounds-per-second scaling: eager vs scan vs scan_fused drivers.

The paper's headline claims (fast convergence, O(1) communication,
scalability) are wall-clock claims at thousands-of-rounds scale; this
benchmark measures the simulator's round throughput at n ∈ {20, 100, 500}
clients for the three RWSADMM execution engines:

  eager      — one XLA dispatch + one host sync per round (seed driver),
  scan       — whole chunk of R rounds as ONE lax.scan executable,
  scan_fused — scan + the masked multi-client Pallas zone kernel.

Timed region for scan engines includes the host-side schedule
precomputation (graphs, random walk, zone padding, keys) — the honest
end-to-end cost per chunk. Emits CSV rows:

  scan_scaling/n{N}/{engine},{us_per_round},rounds_per_s=...
  scan_scaling/n{N}/speedup,...,scan_vs_eager=...x

Smoke (CI, <2 min):  python -m benchmarks.scan_scaling --rounds 30 \
    --clients 20
Full:                python -m benchmarks.scan_scaling
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.rwsadmm import RWSADMMHparams
from repro.fl.rwsadmm_trainer import ENGINES, RWSADMMTrainer
from repro.models.small import get_model

from .common import emit, synthetic_fed


def make_trainer(n_clients: int, seed: int = 0) -> RWSADMMTrainer:
    # The paper's Synthetic(0.5, 0.5) MLR setting (§5): the strongly
    # convex workload whose per-round compute is small enough that the
    # eager loop is dispatch-bound — the regime the scan driver targets.
    data, shape = synthetic_fed(n_clients, seed=seed)
    model = get_model("mlr", shape)
    return RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        zone_size=8, batch_size=20, solver="closed_form", seed=seed,
    )


def bench_engine(trainer: RWSADMMTrainer, engine: str, rounds: int) -> float:
    """Returns measured rounds/sec (after a warmup pass that compiles)."""
    state = trainer.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if engine == "eager":
        state, _ = trainer.round(state, 0, rng)          # compile
        jax.block_until_ready(state.server.y)
        t0 = time.perf_counter()
        for r in range(1, rounds + 1):
            state, _ = trainer.round(state, r, rng)
        jax.block_until_ready(state.server.y)
        dt = time.perf_counter() - t0
    else:
        sched = trainer.schedule(rounds, rng, start_round=0)
        state, _ = trainer.run_chunk(state, sched, engine=engine)  # compile
        jax.block_until_ready(state.server.y)
        t0 = time.perf_counter()
        sched = trainer.schedule(rounds, rng, start_round=rounds)
        state, stacked = trainer.run_chunk(state, sched, engine=engine)
        jax.block_until_ready(stacked["train_loss"])
        dt = time.perf_counter() - t0
    return rounds / dt


def run(rounds: int = 200, clients=(20, 100, 500)) -> dict:
    """Prints CSV rows; returns {n: {engine: rounds_per_s}}."""
    results: dict = {}
    for n in clients:
        per_engine: dict = {}
        for engine in ENGINES:
            trainer = make_trainer(n)
            rps = bench_engine(trainer, engine, rounds)
            per_engine[engine] = rps
            emit(f"scan_scaling/n{n}/{engine}", 1e6 / rps,
                 f"rounds_per_s={rps:.1f}")
        speed = per_engine["scan"] / per_engine["eager"]
        speed_f = per_engine["scan_fused"] / per_engine["eager"]
        emit(f"scan_scaling/n{n}/speedup", 0.0,
             f"scan_vs_eager={speed:.1f}x "
             f"scan_fused_vs_eager={speed_f:.1f}x")
        results[n] = per_engine
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=200,
                    help="timed rounds per engine (after compile warmup)")
    ap.add_argument("--clients", type=int, nargs="+",
                    default=[20, 100, 500])
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(rounds=args.rounds, clients=tuple(args.clients))


if __name__ == "__main__":
    main()
