"""Ablations documenting the paper-fidelity decisions (DESIGN.md §7):

  * literal Eq. (11) (sign-folded gradient) vs the derived solver,
  * 1/n_i y-fold (printed Eq. 14) vs the 1/n running-average fix,
  * closed-form (Eq. 10/11) vs iterative prox-SGD (Eq. 9) solver,
  * Walkman consensus vs RWSADMM hard-constraint personalization,
  * Metropolis vs degree transition matrix.
"""
from __future__ import annotations

import jax

from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.core.rwsadmm import RWSADMMHparams
from repro.fl.simulation import run_simulation
from repro.models.small import get_model

from .common import emit, make_trainer, mnist_like_fed


def run(rounds: int = 80) -> None:
    data, shape = mnist_like_fed(n_clients=10, n_samples=1500)
    model = get_model("mlr", shape)

    runs = {
        "prox_sgd(default)": make_trainer("rwsadmm", model, data),
        "closed_form(eq10)": make_trainer("rwsadmm_cf", model, data),
        "walkman(consensus)": make_trainer("walkman", model, data),
        "metropolis": RWSADMMTrainer(
            model, data, RWSADMMHparams(beta=1.0, kappa=0.001,
                                        epsilon=1e-5),
            zone_size=8, batch_size=32, transition="metropolis"),
    }
    for name, tr in runs.items():
        r = rounds if "walkman" not in name else rounds * 5
        res = run_simulation(tr, rounds=r, eval_every=r, seed=0)
        emit(f"ablation/{name}", res.wall_time_s / r * 1e6,
             f"acc={res.final['acc']:.4f}")

    # literal Eq. (11) from the paper's own zero-ish init: provably inert.
    from repro.core import rwsadmm, tree

    hp = RWSADMMHparams(beta=10.0)
    y = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    x_lit = rwsadmm.x_update(y, y, tree.zeros_like(y), g, hp,
                             literal_eq11=True)
    moved = float(tree.linf(tree.sub(x_lit, y)))
    emit("ablation/literal_eq11_first_step", 0.0,
         f"max_movement={moved} (0.0 == paper formula is inert at init)")


if __name__ == "__main__":
    run()
