"""Telemetry overhead gate: recording must stay ≤ 5% per round.

Measures the n=2k scenario control-plane bench (the same workload as
``scan_scaling/control_plane/n2000/sparse``) twice — telemetry off vs
telemetry on (phase spans + the full per-visit walk trace streamed to
``events.jsonl``) — and writes both rows plus the measured overhead to
``BENCH_scaling.json``:

    telemetry_overhead/control_plane/n2000/{off,on}

Usage::

    python -m benchmarks.telemetry_overhead [--smoke]
        [--clients 2000] [--rounds 64] [--assert-overhead-pct 5.0]

``--assert-overhead-pct`` makes the run fail when the measured overhead
exceeds the bound (the acceptance gate; default asserts at 5%).
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.core import markov
from repro.core.markov import RandomWalkServer
from repro.telemetry import TelemetryRun, visit_events_from_schedule

from .common import bench_row, emit, reset_peak_rss, write_bench_rows


def _build(n: int, seed: int = 0):
    from repro.scenarios import (
        LinkConfig,
        MobilityConfig,
        Scenario,
        ScenarioConfig,
    )

    radio = float(np.sqrt(12.0 / (np.pi * n)))
    cfg = ScenarioConfig(
        name="telemetry_overhead",
        mobility=MobilityConfig(model="gauss_markov", radio_range=radio),
        links=LinkConfig(enabled=True, dropout=True),
        graph_backend="sparse", neighbor_k_max=32)
    scenario = Scenario(n, cfg, seed=seed)
    walker = RandomWalkServer(seed=seed + 1)
    walker.reset(scenario.current())
    return scenario, walker


def _run_once(n: int, rounds: int, zone: int, tel: TelemetryRun | None,
              seed: int = 0) -> float:
    """Seconds/round of the control-plane schedule, optionally recorded
    (phase span + per-visit trace — the full telemetry-on hot path)."""
    scenario, walker = _build(n, seed)
    scenario.telemetry = tel
    rng = np.random.default_rng(seed)

    def price(graphs, clients, idx, mask):
        return scenario.price_schedule(graphs, clients, idx, mask, 2048)

    t0 = time.perf_counter()
    if tel is None:
        sched = markov.zone_schedule(scenario, walker, rounds, zone, rng,
                                     price=price)
    else:
        with tel.phase("schedule", chunk_rounds=rounds):
            sched = markov.zone_schedule(scenario, walker, rounds, zone,
                                         rng, price=price)
        for v in visit_events_from_schedule(sched, 0):
            tel.visit(**v)
    return (time.perf_counter() - t0) / rounds


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--clients", type=int, default=2000)
    ap.add_argument("--rounds", type=int, default=64)
    ap.add_argument("--zone", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run (n=400, 2 repeats)")
    ap.add_argument("--assert-overhead-pct", type=float, default=5.0,
                    help="fail when telemetry overhead exceeds this "
                         "(negative disables)")
    args = ap.parse_args(argv)
    n, repeats = args.clients, args.repeats
    if args.smoke:
        n, repeats = min(n, 400), min(repeats, 2)

    reset_peak_rss()
    # Interleaved best-of-R so machine noise hits both arms equally.
    best_off = best_on = float("inf")
    for rep in range(repeats):
        best_off = min(best_off,
                       _run_once(n, args.rounds, args.zone, None,
                                 seed=rep))
        with tempfile.TemporaryDirectory() as td:
            with TelemetryRun(td + "/run", seed=rep,
                              config={"bench": "telemetry_overhead",
                                      "n": n}) as tel:
                best_on = min(best_on,
                              _run_once(n, args.rounds, args.zone, tel,
                                        seed=rep))
    overhead_pct = (best_on / best_off - 1.0) * 100.0
    emit(f"telemetry_overhead/control_plane/n{n}/off",
         best_off * 1e6, "us_per_round")
    emit(f"telemetry_overhead/control_plane/n{n}/on",
         best_on * 1e6, f"overhead={overhead_pct:+.2f}%")
    write_bench_rows([
        bench_row(f"telemetry_overhead/control_plane/n{n}/off",
                  n=n, engine="sparse", us_per_round=best_off * 1e6),
        bench_row(f"telemetry_overhead/control_plane/n{n}/on",
                  n=n, engine="sparse", us_per_round=best_on * 1e6,
                  overhead_pct=round(overhead_pct, 2)),
    ])
    if args.assert_overhead_pct >= 0 and \
            overhead_pct > args.assert_overhead_pct:
        raise SystemExit(
            f"telemetry overhead {overhead_pct:.2f}% exceeds "
            f"{args.assert_overhead_pct}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
