"""Splice the §Dry-run and §Roofline tables into EXPERIMENTS.md from
results/dryrun/*.json (idempotent: replaces marker sections)."""
from __future__ import annotations

import glob
import json
import os
import re

from .roofline_report import roofline_row, suggestion

ARCH_ORDER = [
    "qwen2-7b", "xlstm-350m", "whisper-large-v3", "kimi-k2-1t-a32b",
    "tinyllama-1.1b", "recurrentgemma-9b", "gemma3-12b", "qwen2-vl-2b",
    "yi-34b", "qwen3-moe-30b-a3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(dry_dir="results/dryrun"):
    recs = {}
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        recs[(rec["arch"], rec["shape"], rec["multi_pod"])] = rec
    return recs


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compile s | per-chip peak GB | "
        "per-chip GFLOPs | collective GB (per-chip, per-kind) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mp in (False, True):
                rec = recs.get((arch, shape, mp))
                if rec is None:
                    continue
                coll = ", ".join(
                    f"{k.replace('all-','a')}:{v / 1e9:.2f}"
                    for k, v in sorted(rec["collectives"].items())
                    if k != "_counts" and v > 0)
                peak = rec["memory"].get("peak_memory_in_bytes", 0) / 1e9
                lines.append(
                    f"| {arch} | {shape} | "
                    f"{'2x16x16' if mp else '16x16'} "
                    f"| {rec['lower_compile_s']} | {peak:.2f} "
                    f"| {rec['flops'] / 1e9:.1f} | {coll} |")
    skips = [
        "qwen2-7b", "whisper-large-v3", "kimi-k2-1t-a32b",
        "tinyllama-1.1b", "qwen2-vl-2b", "yi-34b", "qwen3-moe-30b-a3b",
    ]
    lines.append("")
    lines.append(f"Skipped long_500k (full attention, DESIGN.md §4): "
                 f"{', '.join(skips)}.")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | MODEL/HLO flops | one-line next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mp in (False, True):
                rec = recs.get((arch, shape, mp))
                if rec is None:
                    continue
                r = roofline_row(rec)
                lines.append(
                    f"| {arch} | {shape} | {r['mesh']} "
                    f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
                    f"| {r['t_collective_s']:.2e} | {r['dominant']} "
                    f"| {r['useful_ratio']:.2f} | {suggestion(r)[:70]} |")
    return "\n".join(lines)


def splice(md_path: str, marker: str, content: str):
    with open(md_path) as f:
        text = f.read()
    tag = f"<!-- {marker} -->"
    end_tag = f"<!-- /{marker} -->"
    block = f"{tag}\n{content}\n{end_tag}"
    if end_tag in text:
        text = re.sub(
            re.escape(tag) + r".*?" + re.escape(end_tag), block, text,
            flags=re.S)
    else:
        text = text.replace(tag, block)
    with open(md_path, "w") as f:
        f.write(text)


def run() -> None:
    """Splice if there is anything to splice. A fresh checkout has
    neither dry-run records nor an EXPERIMENTS.md — previously this
    crashed on open(), which is why ``benchmarks.run`` could not even
    register the module; skipping cleanly keeps the harness green while
    still updating the tables whenever records exist."""
    recs = load_records()
    if not os.path.exists("EXPERIMENTS.md"):
        print("# fill_experiments: no EXPERIMENTS.md here, skipping")
        return
    if not recs:
        print("# fill_experiments: no results/dryrun records, skipping")
        return
    print(f"{len(recs)} dry-run records")
    splice("EXPERIMENTS.md", "DRYRUN_TABLE", dryrun_table(recs))
    splice("EXPERIMENTS.md", "ROOFLINE_TABLE", roofline_table(recs))
    print("EXPERIMENTS.md updated")


def main():
    run()


if __name__ == "__main__":
    main()
