"""Scenario sweep: accuracy + wireless cost vs mobility and link quality.

Exercises the scenario subsystem (``src/repro/scenarios/``) end-to-end:
every mobility model × link-dropout setting runs through the compiled
``engine="scan"`` driver (scenarios stay host-side control plane, so
the fused hot path is scenario-agnostic), reporting final personalized
accuracy and the wireless CommModel's latency/energy totals next to
bytes. A speedup column re-measures scan vs eager per scenario —
the PR-1 dispatch win must survive scenario stepping.

Emits CSV rows:

  scenario_sweep/{scenario},{us_per_round},acc=... latency_s=...
      energy_j=... speedup=...
  scenario_sweep/speed_{v},...        (mobility-speed sweep, full mode)

Smoke (CI, <2 min):  python -m benchmarks.scenario_sweep --smoke
Full:                python -m benchmarks.scenario_sweep
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import os
import time

import jax
import numpy as np

from repro.core.rwsadmm import RWSADMMHparams
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model
from repro.scenarios import (
    LinkConfig,
    MobilityConfig,
    ScenarioConfig,
    get_scenario_config,
)

from .common import (
    bench_row,
    control_plane_rate,
    emit,
    peak_rss_mb,
    synthetic_fed,
    write_bench_rows,
)

MOBILITY_MODELS = ("static_regen", "random_waypoint", "gauss_markov")


def make_trainer(n_clients: int, scenario: ScenarioConfig | str,
                 seed: int = 0) -> RWSADMMTrainer:
    data, shape = synthetic_fed(n_clients, seed=seed)
    model = get_model("mlr", shape)
    return RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        zone_size=8, batch_size=20, solver="closed_form",
        scenario=scenario, seed=seed,
    )


def grid(dropout_settings=(False, True)) -> list[ScenarioConfig]:
    """All mobility models × link-dropout settings."""
    cfgs = []
    for model in MOBILITY_MODELS:
        for drop in dropout_settings:
            cfgs.append(ScenarioConfig(
                name=f"{model}{'+drop' if drop else ''}",
                mobility=MobilityConfig(model=model),
                links=LinkConfig(enabled=drop, dropout=drop),
            ))
    return cfgs


def measure_speedup(n_clients: int, scenario: ScenarioConfig,
                    rounds: int, reps: int = 6) -> float:
    """scan vs eager rounds/sec on this scenario (after compile warmup).

    Noise control on a loaded box: the two engines' timing windows are
    *interleaved* rep by rep so slow phases of the machine hit both
    estimates alike; each estimate is best-of-``reps`` (noise is
    one-sided — slowdowns only — so the max is the stable statistic);
    and every scan rep runs several chunks, because a lone chunk of
    ≲150 rounds is mostly per-chunk fixed cost (schedule-array
    assembly, one device sync), which under-reports the scan engine.
    """
    tr_e = make_trainer(n_clients, scenario)
    state_e = tr_e.init_state(jax.random.PRNGKey(0))
    rng_e = np.random.default_rng(0)
    state_e, _ = tr_e.round(state_e, 0, rng_e)          # compile
    jax.block_until_ready(state_e.server.y)

    tr_s = make_trainer(n_clients, scenario)
    state_s = tr_s.init_state(jax.random.PRNGKey(0))
    rng_s = np.random.default_rng(0)
    sched = tr_s.schedule(rounds, rng_s)                # compile
    state_s, _ = tr_s.run_chunk(state_s, sched, engine="scan")
    jax.block_until_ready(state_s.server.y)

    rates = {"eager": 0.0, "scan": 0.0}
    r_e, r_s, chunks = 1, rounds, 3
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(rounds):
            state_e, _ = tr_e.round(state_e, r_e, rng_e)
            r_e += 1
        jax.block_until_ready(state_e.server.y)
        rates["eager"] = max(rates["eager"],
                             rounds / (time.perf_counter() - t0))

        t0 = time.perf_counter()
        for _ in range(chunks):
            sched = tr_s.schedule(rounds, rng_s, start_round=r_s)
            r_s += rounds
            state_s, stacked = tr_s.run_chunk(state_s, sched,
                                              engine="scan")
        jax.block_until_ready(stacked["train_loss"])
        rates["scan"] = max(rates["scan"],
                            chunks * rounds / (time.perf_counter() - t0))
    return rates["scan"] / rates["eager"]


def run(n_clients: int = 20, rounds: int = 150, speedup_rounds: int = 200,
        smoke: bool = False, out_dir: str = "results/bench") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    # Speedups first, accuracy after: the timing phase runs in a fresh
    # process state instead of after the accuracy simulations have
    # churned the heap (which was measurably inflating the noise).
    speedups = {cfg.name: measure_speedup(n_clients, cfg, speedup_rounds)
                for cfg in grid()}
    for cfg in grid():
        tr = make_trainer(n_clients, cfg)
        res = run_simulation(tr, rounds=rounds, eval_every=rounds,
                             seed=0, engine="scan")
        speedup = speedups[cfg.name]
        rows.append({
            "scenario": cfg.name,
            "mobility": cfg.mobility.model,
            "link_dropout": int(cfg.links.enabled),
            "final_acc": round(float(res.final["acc_personalized"]), 4),
            "comm_mb": round(res.total_comm_bytes / 1e6, 2),
            "latency_s": round(res.total_latency_s, 3),
            "energy_j": round(res.total_energy_j, 3),
            "scan_vs_eager": round(speedup, 2),
        })
        emit(f"scenario_sweep/{cfg.name}",
             1e6 * res.wall_time_s / rounds,
             f"acc={rows[-1]['final_acc']} "
             f"latency_s={rows[-1]['latency_s']} "
             f"energy_j={rows[-1]['energy_j']} "
             f"scan_vs_eager={speedup:.1f}x")

    # Dropout scenarios pay the per-round link-layer stack; the batched
    # rollout amortizes it on the scan side while the eager driver still
    # steps it round-by-round — so the scan-vs-eager win under dropout
    # must be at least the pure-mobility win (the PR-3 acceptance bar).
    # ok allows 10% measurement noise on the 3-vs-3 sample means (each
    # a best-of-reps on a loaded box; observed run-to-run sigma ~0.06):
    # pre-rollout the ratio sat at ~0.75–0.8 (4–5x vs 5–6x),
    # post-rollout it hovers around 0.95–1.1, so 0.9 separates the
    # regimes without flaking.
    drop = np.mean([r["scan_vs_eager"] for r in rows if r["link_dropout"]])
    pure = np.mean([r["scan_vs_eager"] for r in rows
                    if not r["link_dropout"]])
    emit("scenario_sweep/dropout_vs_mobility", 0.0,
         f"dropout_speedup={drop:.2f}x mobility_speedup={pure:.2f}x "
         f"ratio={drop / pure:.2f} ok={int(drop / pure >= 0.9)}")

    if not smoke:
        # Mobility-speed × link-reliability sweeps (gauss_markov): how
        # fast clients move and how lossy links are both tax accuracy
        # and wireless cost.
        base = get_scenario_config("gauss_markov")
        for speed in (0.005, 0.02, 0.08):
            cfg = dataclasses.replace(
                base, name=f"gm_speed{speed}", mobility=dataclasses.replace(
                    base.mobility, mean_speed=speed))
            res = run_simulation(make_trainer(n_clients, cfg),
                                 rounds=rounds, eval_every=rounds,
                                 seed=0, engine="scan")
            emit(f"scenario_sweep/speed_{speed}", 0.0,
                 f"acc={res.final['acc_personalized']:.4f} "
                 f"latency_s={res.total_latency_s:.3f}")
        for sens in (-85.0, -75.0, -65.0):   # better → worse radios
            cfg = ScenarioConfig(
                name=f"gm_sens{sens}",
                mobility=MobilityConfig(model="gauss_markov"),
                links=LinkConfig(enabled=True, sensitivity_dbm=sens),
            )
            res = run_simulation(make_trainer(n_clients, cfg),
                                 rounds=rounds, eval_every=rounds,
                                 seed=0, engine="scan")
            emit(f"scenario_sweep/sensitivity_{sens}", 0.0,
                 f"acc={res.final['acc_personalized']:.4f} "
                 f"latency_s={res.total_latency_s:.3f} "
                 f"energy_j={res.total_energy_j:.3f}")

    with open(os.path.join(out_dir, "scenario_sweep.csv"), "w",
              newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


def large_n(rounds: int = 64) -> list[dict]:
    """Large-n scenario columns on the sparse neighbor-list backend:
    the full mobility × dropout grid at n=2000 (control-plane only —
    the dense lane is memory-blocked here), gauss_markov at n=10000 and
    n=50000 for the scaling tail. Appends rows to BENCH_scaling.json."""
    cells = [(2000, model, drop) for model in MOBILITY_MODELS
             for drop in (False, True)]
    cells += [(10000, "gauss_markov", True), (50000, "gauss_markov", True)]
    json_rows = []
    for n, model, drop in cells:
        sec = control_plane_rate(n, rounds=rounds, mobility=model,
                                 dropout=drop)
        name = (f"scenario_sweep/large_n/"
                f"{model}{'+drop' if drop else ''}/n{n}")
        emit(name, sec * 1e6,
             f"rounds_per_s={1.0 / sec:.1f} "
             f"peak_rss_mb={peak_rss_mb():.0f}")
        json_rows.append(bench_row(name, n=n, engine="sparse",
                                   us_per_round=sec * 1e6,
                                   mobility=model, dropout=int(drop)))
    write_bench_rows(json_rows)
    return json_rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI budget: fewer rounds, no speed/sens/large-n "
                    "sweeps")
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--large-n", action="store_true",
                    help="run ONLY the sparse-backend large-n columns")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.large_n:
        large_n()
        return
    rounds = args.rounds or (30 if args.smoke else 150)
    # Speedup windows shorter than ~100 rounds are dominated by
    # per-chunk fixed costs and box noise; keep them longer than the
    # accuracy runs even in smoke mode.
    speedup_rounds = 150 if args.smoke else 300
    run(n_clients=args.clients, rounds=rounds,
        speedup_rounds=speedup_rounds, smoke=args.smoke)
    if not args.smoke:
        large_n()


if __name__ == "__main__":
    main()
