"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see each module for the
paper artifact it reproduces). Budget knobs via env:
  BENCH_ROUNDS (default 100) — FL rounds per configuration.
  BENCH_SKIP   — comma-separated module names to skip.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    rounds = int(os.environ.get("BENCH_ROUNDS", "100"))
    skip = set(os.environ.get("BENCH_SKIP", "").split(","))
    print("name,us_per_call,derived")

    from . import (
        ablations,
        comm_cost,
        convergence,
        hyperparam,
        kernels_bench,
        mixing,
        roofline_report,
        scan_scaling,
        table1,
        table2_scaling,
    )

    jobs = [
        ("mixing", lambda: mixing.run()),
        ("kernels", lambda: kernels_bench.run()),
        ("scan_scaling",
         lambda: scan_scaling.run(rounds=min(rounds, 200))),
        ("convergence", lambda: convergence.run(rounds=rounds)),
        ("table1", lambda: table1.run(rounds=max(rounds, 120))),
        ("table2", lambda: table2_scaling.run()),
        ("hyperparam", lambda: hyperparam.run(rounds=min(rounds, 80))),
        ("comm_cost", lambda: comm_cost.run(rounds=max(rounds, 150))),
        ("ablations", lambda: ablations.run(rounds=min(rounds, 80))),
        ("roofline", lambda: roofline_report.run()),
    ]
    failures = []
    for name, job in jobs:
        if name in skip:
            print(f"# skipped {name}")
            continue
        try:
            job()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"# FAILED {name}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
