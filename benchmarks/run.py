"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see each module for the
paper artifact it reproduces). Budget knobs via env:
  BENCH_ROUNDS (default 100) — FL rounds per configuration.
  BENCH_SKIP   — comma-separated module names to skip.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    rounds = int(os.environ.get("BENCH_ROUNDS", "100"))
    skip = set(os.environ.get("BENCH_SKIP", "").split(","))
    print("name,us_per_call,derived")

    from . import (
        ablations,
        comm_cost,
        convergence,
        fill_experiments,
        fleet_scaling,
        hyperparam,
        kernels_bench,
        mixing,
        roofline_report,
        scan_scaling,
        scenario_sweep,
        table1,
        table2_scaling,
    )

    jobs = [
        ("mixing", lambda: mixing.run()),
        ("kernels", lambda: kernels_bench.run()),
        ("scan_scaling",
         lambda: scan_scaling.run(rounds=min(rounds, 200))),
        ("scan_scaling_large_n",
         # Sparse-backend control plane at n ∈ {2k, 10k, 50k} (the dense
         # reference rides along at the smallest n).
         lambda: scan_scaling.control_plane(rounds=min(rounds, 64))),
        ("scenario_sweep",
         # Smoke budget: the full grid with short accuracy runs; the
         # speed/sensitivity/large-n sweeps stay in the module's own
         # full mode.
         lambda: scenario_sweep.run(n_clients=20, rounds=min(rounds, 30),
                                    speedup_rounds=150, smoke=True)),
        ("fleet_scaling",
         lambda: fleet_scaling.run(rounds=min(rounds, 40), clients=(40,),
                                   walkers=(1, 3), modes=("roundrobin",))),
        ("convergence", lambda: convergence.run(rounds=rounds)),
        ("table1", lambda: table1.run(rounds=max(rounds, 120))),
        ("table2", lambda: table2_scaling.run()),
        ("hyperparam", lambda: hyperparam.run(rounds=min(rounds, 80))),
        ("comm_cost", lambda: comm_cost.run(rounds=max(rounds, 150))),
        ("ablations", lambda: ablations.run(rounds=min(rounds, 80))),
        ("roofline", lambda: roofline_report.run()),
        ("perf_iterations",
         # Imported lazily AND run in a fresh subprocess: the module
         # sets the 512-virtual-device XLA flag at import time, which
         # must neither leak into this process's env before the other
         # jobs initialize JAX nor arrive after backend init (where it
         # would be ignored).
         lambda: __import__("benchmarks.perf_iterations",
                            fromlist=["run_smoke"]).run_smoke()),
        ("fill_experiments", lambda: fill_experiments.run()),
    ]
    failures = []
    for name, job in jobs:
        if name in skip:
            print(f"# skipped {name}")
            continue
        try:
            job()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"# FAILED {name}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
