"""Fleet scaling: eager vs scan round throughput for K mobile servers.

The fleet extension (multiple walkers, ``fl.fleet_trainer``) is the
repo's beyond-paper scalability workload; this benchmark measures the
compiled fleet driver's win over the eager per-round loop at K ∈
{1, 3, 5} walkers, both fleet modes:

  roundrobin   — one zone per round, walkers take turns (K× coverage
                 per wall step at single-walker round cost),
  simultaneous — K zones per wall step through the batched multi-zone
                 kernel (K× zone throughput per round).

Timed region for the scan engines includes schedule precomputation
(graphs, K random walks, zone plans, sync mask, keys, pricing) — the
honest end-to-end cost per chunk. Also reports the fleet hitting time
(wall steps until the union of walker visits covers every client) next
to a single walker's, the ~K× coverage claim. Emits CSV rows:

  fleet_scaling/{mode}/n{N}/K{K}/{engine},{us_per_round},rounds_per_s=...
  fleet_scaling/{mode}/n{N}/K{K}/speedup,...,scan_vs_eager=...x

Rows are also written machine-readably (name, n, K, engine,
us_per_round, peak_rss_mb) into BENCH_scaling.json at the repo root —
the diffable perf trajectory across PRs.

Smoke (CI, < 2 min):  python -m benchmarks.fleet_scaling --smoke
Full:                 python -m benchmarks.fleet_scaling
(full run covers the acceptance bar: scan ≥ 5× eager at n=100, K=3.)
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core.rwsadmm import RWSADMMHparams
from repro.fl.fleet_trainer import FleetRWSADMMTrainer
from repro.fl.rwsadmm_trainer import ENGINES
from repro.models.small import get_model

from .common import (
    bench_row,
    emit,
    reset_peak_rss,
    synthetic_fed,
    write_bench_rows,
)


def make_fleet(n_clients: int, k: int, mode: str,
               seed: int = 0) -> FleetRWSADMMTrainer:
    data, shape = synthetic_fed(n_clients, seed=seed)
    model = get_model("mlr", shape)
    return FleetRWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        n_walkers=k, sync_every=10, fleet_mode=mode,
        zone_size=8, batch_size=20, solver="closed_form", seed=seed,
    )


def bench_engine(trainer: FleetRWSADMMTrainer, engine: str,
                 rounds: int) -> float:
    """Measured rounds/sec (after a warmup pass that compiles)."""
    state = trainer.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if engine == "eager":
        state, _ = trainer.round(state, 0, rng)          # compile
        jax.block_until_ready(state.base.server.y)
        t0 = time.perf_counter()
        for r in range(1, rounds + 1):
            state, _ = trainer.round(state, r, rng)
        jax.block_until_ready(state.base.server.y)
        dt = time.perf_counter() - t0
    else:
        sched = trainer.schedule(rounds, rng, start_round=0)
        state, _ = trainer.run_chunk(state, sched, engine=engine)
        jax.block_until_ready(state.base.server.y)       # compile
        t0 = time.perf_counter()
        sched = trainer.schedule(rounds, rng, start_round=rounds)
        state, stacked = trainer.run_chunk(state, sched, engine=engine)
        jax.block_until_ready(stacked["train_loss"])
        dt = time.perf_counter() - t0
    return rounds / dt


def hitting_times(n_clients: int, walkers=(1, 3, 5),
                  rounds: int = 4000) -> dict:
    """Fleet wall-clock hitting time vs K (the ~K× coverage claim).
    Walk-only: steps the schedules without training rounds."""
    out: dict = {}
    for k in walkers:
        trainer = make_fleet(n_clients, k, "simultaneous")
        # Walk-only: step every walker through the graph schedule
        # directly (same per-walker streams as a full fleet schedule)
        # without paying zone planning / pricing / key materialization.
        graphs = trainer.dyn_graph.schedule(rounds, include_current=True)
        for w in trainer.walkers:
            w.walk_schedule(graphs[1:], advance_first=True)
        t = trainer.fleet_hitting_time()
        out[k] = t
        emit(f"fleet_scaling/hitting_time/n{n_clients}/K{k}",
             0.0, f"wall_steps={t}")
    return out


def run(rounds: int, clients, walkers, modes) -> dict:
    results: dict = {}
    json_rows = []
    for mode in modes:
        for n in clients:
            for k in walkers:
                per_engine: dict = {}
                for engine in ENGINES:
                    reset_peak_rss()
                    trainer = make_fleet(n, k, mode)
                    rps = bench_engine(trainer, engine, rounds)
                    per_engine[engine] = rps
                    name = f"fleet_scaling/{mode}/n{n}/K{k}/{engine}"
                    emit(name, 1e6 / rps, f"rounds_per_s={rps:.1f}")
                    json_rows.append(bench_row(
                        name, n=n, k=k, engine=engine,
                        us_per_round=1e6 / rps, mode=mode))
                speed = per_engine["scan"] / per_engine["eager"]
                speed_f = per_engine["scan_fused"] / per_engine["eager"]
                emit(f"fleet_scaling/{mode}/n{n}/K{k}/speedup", 0.0,
                     f"scan_vs_eager={speed:.1f}x "
                     f"scan_fused_vs_eager={speed_f:.1f}x")
                results[(mode, n, k)] = per_engine
    write_bench_rows(json_rows)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=200,
                    help="timed rounds per engine (after compile warmup)")
    ap.add_argument("--clients", type=int, nargs="+", default=[100])
    ap.add_argument("--walkers", type=int, nargs="+", default=[1, 3, 5])
    ap.add_argument("--modes", nargs="+",
                    default=["roundrobin", "simultaneous"])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: short run, exits nonzero unless "
                    "scan beats eager at every K")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        results = run(rounds=40, clients=(40,), walkers=(1, 3, 5),
                      modes=("roundrobin",))
        hitting_times(40, walkers=(1, 3, 5), rounds=600)
        bad = [key for key, eng in results.items()
               if eng["scan"] <= eng["eager"]]
        if bad:
            print(f"FAIL: scan did not beat eager at {bad}",
                  file=sys.stderr)
            sys.exit(1)
        return
    run(rounds=args.rounds, clients=tuple(args.clients),
        walkers=tuple(args.walkers), modes=tuple(args.modes))
    hitting_times(max(args.clients), walkers=tuple(args.walkers))


if __name__ == "__main__":
    main()
