"""Roofline analysis (deliverable g): three-term model per (arch × shape)
from the dry-run's compiled artifacts.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

Hardware constants (TPU v5e target): 197 TFLOP/s bf16 / chip, 819 GB/s
HBM, ~50 GB/s/link ICI. HLO flops/bytes from compiled.cost_analysis()
(reported per-device program ⇒ already divided by chips — we detect which
convention applies from magnitudes and normalize; see _per_chip below).
collective_bytes parsed from the compiled HLO (launch/dryrun.py), with
per-kind byte multipliers: all-gather/reduce-scatter move (n−1)/n ≈ 1× the
full buffer across the slowest link in a ring; all-reduce ≈ 2×;
all-to-all ≈ 1×; collective-permute 1×.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)

KIND_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def roofline_row(rec: dict) -> dict:
    chips = rec["n_chips"]
    # cost_analysis flops are for the per-device SPMD program.
    flops_per_chip = rec["flops"]
    bytes_per_chip = rec.get("bytes_accessed", 0.0)
    coll = rec.get("collectives", {})
    coll_bytes = sum(KIND_MULT.get(k, 1.0) * v for k, v in coll.items()
                     if k != "_counts")

    t_compute = flops_per_chip / PEAK_FLOPS
    t_memory = bytes_per_chip / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D; decode D = batch tokens.
    n_params = rec["active_params"]
    if rec["kind"] == "train":
        d_tokens = rec["seq_len"] * rec["global_batch"]
        model_flops = 6 * n_params * d_tokens
    elif rec["kind"] == "prefill":
        d_tokens = rec["seq_len"] * rec["global_batch"]
        model_flops = 2 * n_params * d_tokens  # forward only
    else:  # decode: one token per sequence
        d_tokens = rec["global_batch"]
        model_flops = 2 * n_params * d_tokens
    useful_ratio = model_flops / max(1.0, flops_per_chip * chips)

    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_total": flops_per_chip * chips,
        "useful_ratio": useful_ratio,
        "coll_bytes": coll_bytes,
        "step_time_s": max(terms.values()),
    }


SUGGESTIONS = {
    ("compute",): "increase per-chip arithmetic intensity is already the "
                  "bound — win by cutting redundant HLO flops (remat, "
                  "duplicate projections)",
    ("memory",): "fuse elementwise chains / cast activations to bf16 / "
                 "enlarge per-chip tile so HBM reads amortize",
    ("collective",): "reshard to cut the dominant collective (fewer "
                     "all-gathers via replicated decode weights, bigger "
                     "model-axis blocks, or overlap with compute)",
}


def suggestion(row: dict) -> str:
    return SUGGESTIONS[(row["dominant"],)]


def load(dry_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            rows.append(roofline_row(rec))
    return rows


def run(dry_dir: str = "results/dryrun",
        out_path: str = "results/bench/roofline.md") -> list[dict]:
    rows = load(dry_dir)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s "
        "| dominant | MODEL/HLO | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {suggestion(r)[:60]}… |")
    with open(out_path, "w") as f:
        f.write("\n".join(lines) + "\n")
    for r in rows:
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
              f"dom={r['dominant']} step={r['step_time_s']:.3e}s "
              f"useful={r['useful_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    run()
