"""Assumption 3.1 / Eq. 6 benchmark: mixing time τ(δ) and the convergence
constant across graph topologies + the App. D.2 eigenvalue requirement."""
from __future__ import annotations

import numpy as np

from repro.core import graph as G
from repro.core import markov as M

from .common import emit


def run() -> None:
    rng = np.random.default_rng(0)
    tests = [
        ("geo_n20_deg5", G.random_geometric_graph(20, 5, rng)),
        ("geo_n100_deg5", G.random_geometric_graph(100, 5, rng)),
        ("geo_n100_deg20", G.random_geometric_graph(100, 20, rng)),
        ("line_n20", G.line_graph(20)),
        ("complete_n20", G.complete_graph(20)),
    ]
    for name, g in tests:
        p = M.degree_transition_matrix(g)
        rep = M.verify_assumption_3_1(p, delta=0.5)
        m = g.n_edges
        eig_req = rep["lambda2"] < 1 - 1 / m ** (2 / 3)  # App. D.2
        emit(f"mixing/{name}", 0.0,
             f"tau={rep['tau']} sigma={rep['sigma']:.4f} "
             f"lambda2={rep['lambda2']:.4f} holds={rep['holds']} "
             f"appD2={bool(eig_req)}")


if __name__ == "__main__":
    run()
