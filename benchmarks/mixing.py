"""Assumption 3.1 / Eq. 6 benchmark: mixing time τ(δ) and the convergence
constant across graph topologies + the App. D.2 eigenvalue requirement —
plus the walk-policy sweep (docs/walks.md): hitting time, staleness, and
accuracy-vs-uniform for every ``markov.WALK_POLICIES`` entry on the
paper's skewed (pathological) partition, written into
``BENCH_scaling.json``.

CLI: ``python -m benchmarks.mixing [--smoke]`` runs the policy sweep
alone (``--smoke``: CI-sized budget).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import graph as G
from repro.core import markov as M

from .common import bench_row, emit, mnist_like_fed, write_bench_rows


def run(*, smoke: bool = False) -> None:
    mixing_report()
    policy_sweep(smoke=smoke)


def mixing_report() -> None:
    rng = np.random.default_rng(0)
    tests = [
        ("geo_n20_deg5", G.random_geometric_graph(20, 5, rng)),
        ("geo_n100_deg5", G.random_geometric_graph(100, 5, rng)),
        ("geo_n100_deg20", G.random_geometric_graph(100, 20, rng)),
        ("line_n20", G.line_graph(20)),
        ("complete_n20", G.complete_graph(20)),
    ]
    for name, g in tests:
        p = M.degree_transition_matrix(g)
        rep = M.verify_assumption_3_1(p, delta=0.5)
        m = g.n_edges
        eig_req = rep["lambda2"] < 1 - 1 / m ** (2 / 3)  # App. D.2
        emit(f"mixing/{name}", 0.0,
             f"tau={rep['tau']} sigma={rep['sigma']:.4f} "
             f"lambda2={rep['lambda2']:.4f} holds={rep['holds']} "
             f"appD2={bool(eig_req)}")


def policy_sweep(*, rounds: int = 40, n_clients: int = 12,
                 walk_bias: float = 0.5, seeds: tuple = (0, 1, 2),
                 smoke: bool = False) -> list[dict]:
    """Short training runs (seed-averaged) per walk policy on the
    pathological split: hitting time (rounds to full coverage), the
    staleness distribution of client service (p50 at the horizon,
    worst gap over the run), and personalized accuracy relative to the
    uniform Metropolis walk. The acceptance property — a biased policy
    beats uniform Metropolis on mean hitting time AND mean worst
    staleness — is asserted here, so a regression fails the benchmark
    lane. γ = 0.5 keeps the importance-weight spread small enough that
    the corrected y-update stays stable (large γ trades accuracy for
    coverage; see docs/walks.md)."""
    from repro.core.rwsadmm import RWSADMMHparams
    from repro.fl.rwsadmm_trainer import RWSADMMTrainer
    from repro.fl.simulation import run_simulation
    from repro.models.small import get_model

    if smoke:
        seeds = seeds[:2]
    data, shape = mnist_like_fed(
        n_clients, n_samples=1200 if smoke else 3000, seed=0)
    model = get_model("mlr", shape)

    rows: list[dict] = []
    results: dict[str, dict] = {}
    for policy in M.WALK_POLICIES:
        hits, smaxs, p50s, accs = [], [], [], []
        t0 = time.perf_counter()
        for seed in seeds:
            tr = RWSADMMTrainer(
                model, data,
                RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
                zone_size=4, batch_size=20, solver="closed_form",
                walk_policy=policy, walk_bias=walk_bias, seed=seed)
            res = run_simulation(tr, rounds=rounds, eval_every=rounds,
                                 seed=seed, engine="scan")
            hit = tr.walker.hitting_time()
            hits.append(hit if hit is not None else rounds + 1)
            smaxs.append(max(m["staleness_max"]
                             for m in res.round_metrics))
            p50s.append(res.round_metrics[-1]["staleness_p50"])
            accs.append(res.history[-1]["acc_personalized"])
        dt = time.perf_counter() - t0
        us = dt / (rounds * len(seeds)) * 1e6
        results[policy] = {
            "hitting_time": float(np.mean(hits)),
            "staleness_max": float(np.mean(smaxs)),
            "staleness_p50": float(np.mean(p50s)),
            "acc": float(np.mean(accs)),
            "us": round(us, 1),
        }
        r = results[policy]
        emit(f"mixing/policy_{policy}", us,
             f"hit={r['hitting_time']:.1f} "
             f"stale_max={r['staleness_max']:.1f} "
             f"stale_p50={r['staleness_p50']:.1f} "
             f"acc={r['acc']:.4f}")

    acc_uniform = results["metropolis"]["acc"]
    for policy, r in results.items():
        r["acc_vs_uniform"] = round(r["acc"] - acc_uniform, 4)
        us = r.pop("us")
        rows.append(bench_row(
            f"walk_policy/{policy}", n=n_clients, engine="scan",
            us_per_round=us, rounds=rounds, bias_gamma=walk_bias,
            **r))
    write_bench_rows(rows)

    # Acceptance: some biased policy dominates uniform Metropolis on
    # BOTH coverage speed and worst service gap.
    uni = results["metropolis"]
    winners = [p for p in M.BIASED_POLICIES
               if results[p]["hitting_time"] < uni["hitting_time"]
               and results[p]["staleness_max"] < uni["staleness_max"]]
    emit("mixing/policy_acceptance", 0.0,
         f"winners={sorted(winners)} "
         f"uniform_hit={uni['hitting_time']} "
         f"uniform_stale_max={uni['staleness_max']}")
    if not winners:
        raise AssertionError(
            "no biased policy beat uniform Metropolis on hitting time "
            f"AND staleness_max: {results}")
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        policy_sweep(smoke=True)
    else:
        run()
