"""Paper Fig. 2 (and App. D.4/D.5): test-accuracy / train-loss convergence
curves for RWSADMM vs baselines. Emits per-round CSV curves."""
from __future__ import annotations

import csv
import os

from repro.fl.simulation import run_simulation
from repro.models.small import get_model

from .common import emit, make_trainer, mnist_like_fed

ALGOS = ["fedavg", "perfedavg", "pfedme", "ditto", "apfl", "rwsadmm"]


def run(rounds: int = 100, out_dir: str = "results/bench") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    data, shape = mnist_like_fed(n_clients=10, n_samples=2000)
    curves = {}
    for model_name in ("mlr", "mlp"):
        model = get_model(model_name, shape)
        for algo in ALGOS:
            tr = make_trainer(algo, model, data)
            res = run_simulation(tr, rounds=rounds, eval_every=10, seed=0)
            rs, accs = res.curve("acc")
            curves[(model_name, algo)] = (rs, accs)
            # "fast convergence" metric: rounds to 90% of final accuracy
            target = 0.9 * accs[-1]
            hit = next((int(r) for r, a in zip(rs, accs) if a >= target),
                       rounds)
            emit(f"convergence/{model_name}/{algo}",
                 res.wall_time_s / rounds * 1e6,
                 f"final_acc={accs[-1]:.4f} rounds_to_90pct={hit}")
    path = os.path.join(out_dir, "convergence.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["model", "algo", "round", "acc"])
        for (model_name, algo), (rs, accs) in curves.items():
            for r, a in zip(rs, accs):
                w.writerow([model_name, algo, int(r), float(a)])
    return curves


if __name__ == "__main__":
    run()
