"""§4 communication comparison: bytes to reach a target accuracy.

Validates the paper's headline: RWSADMM's per-round communication is
O(1) (the walking token + |S| zone uploads) vs O(m) for the FedAvg
family, and its complexity constant scales with ln²n/(1−λ₂)² (Eq. 30) —
we report both the measured bytes-to-accuracy and the analytic constant.

Every run attaches the ``lossy_links`` scenario so the wireless
CommModel (``scenarios/links.py``) prices each round in latency and
energy next to bytes: RWSADMM pays short zone-range hops, the FedAvg
family pays client↔base-station round trips — the Table-style
comparison covers the wireless cost model, not just byte counts.
"""
from __future__ import annotations

import csv
import os

import numpy as np

from repro.core import graph as G
from repro.core import markov as M
from repro.fl.simulation import run_simulation
from repro.models.small import get_model

from .common import emit, make_trainer, mnist_like_fed

ALGOS = ["fedavg", "pfedme", "ditto", "apfl", "rwsadmm"]


def run(target: float = 0.8, rounds: int = 150,
        out_dir: str = "results/bench") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    data, shape = mnist_like_fed(n_clients=10, n_samples=2000)
    model = get_model("mlr", shape)
    rows = []
    for algo in ALGOS:
        tr = make_trainer(algo, model, data, zone=4)
        res = run_simulation(tr, rounds=rounds, eval_every=10, seed=0,
                             scenario="lossy_links")
        rs, accs = res.curve("acc")
        per_round = res.total_comm_bytes / rounds
        hit = next((i for i, a in enumerate(accs) if a >= target), None)
        bytes_to_target = (res.history[hit]["comm_bytes_total"]
                           if hit is not None else -1)
        rows.append({
            "algo": algo,
            "bytes_per_round": int(per_round),
            "bytes_to_{:.0%}".format(target): int(bytes_to_target),
            "latency_s_per_round": round(res.total_latency_s / rounds, 5),
            "energy_j_per_round": round(res.total_energy_j / rounds, 5),
            "final_acc": round(float(accs[-1]), 4),
        })
        emit(f"comm/{algo}", per_round,
             f"to_target={bytes_to_target / 1e6:.1f}MB "
             f"latency_s_per_round={rows[-1]['latency_s_per_round']} "
             f"energy_j_per_round={rows[-1]['energy_j_per_round']} "
             f"final={accs[-1]:.3f}")

    # Analytic complexity constant ln²n/(1−λ₂)² across graph densities.
    for n, deg in ((20, 5), (50, 5), (100, 5), (100, 20)):
        g = G.random_geometric_graph(n, min_degree=deg,
                                     rng=np.random.default_rng(0))
        p = M.degree_transition_matrix(g)
        lam2 = M.lambda2(p)
        const = np.log(n) ** 2 / max(1e-9, (1 - lam2) ** 2)
        emit(f"comm/complexity_n{n}_deg{deg}", 0.0,
             f"lambda2={lam2:.4f} ln2n_over_gap2={const:.1f}")
        rows.append({"algo": f"analytic_n{n}_deg{deg}",
                     "bytes_per_round": 0,
                     "bytes_to_{:.0%}".format(target): 0,
                     "final_acc": round(const, 2)})
    with open(os.path.join(out_dir, "comm_cost.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return rows


if __name__ == "__main__":
    run()
