"""Compiled multi-round scan driver: schedule precomputation, lax.scan
chunk execution, and the fused-kernel hot path must reproduce the eager
per-round driver exactly (scan) or to fp tolerance (scan_fused).

Covers the acceptance bar: ≥20 rounds, both solvers, chunk boundaries
crossing a graph-regeneration epoch (regen_every=10), plus the masked
multi-client zone kernel vs its jnp oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import markov
from repro.core.graph import DynamicGraph
from repro.core.markov import RandomWalkServer
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import to_device_data, validate_round_metrics
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model

ROUNDS = 25  # crosses regen boundaries at rounds 10 and 20


@pytest.fixture(scope="module")
def fed():
    imgs, labels = make_image_dataset(600, seed=0)
    parts = pathological_split(labels, 10, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))
    return data, model


def make_trainer(fed, solver, scenario=None, **kw):
    data, model = fed
    return RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        zone_size=4, batch_size=20, regen_every=10, solver=solver,
        scenario=scenario, seed=0, **kw,
    )


def run_eager(tr, rounds=ROUNDS):
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    losses = []
    for r in range(rounds):
        state, m = tr.round(state, r, rng)
        losses.append(m["train_loss"])
    return state, np.asarray(losses)


def run_scan(tr, engine, chunks=(10, 10, 5)):
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    losses = []
    r = 0
    for n in chunks:
        sched = tr.schedule(n, rng, start_round=r)
        state, stacked = tr.run_chunk(state, sched, engine=engine)
        losses.extend(np.asarray(stacked["train_loss"]).tolist())
        r += n
    return state, np.asarray(losses)


def assert_trees_close(a, b, atol=0.0, rtol=0.0):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol, rtol=rtol)


# ------------------------------------------------------- schedule APIs ---
def test_graph_schedule_matches_stepping():
    a = DynamicGraph(12, min_degree=3, regen_every=4, seed=7)
    b = DynamicGraph(12, min_degree=3, regen_every=4, seed=7)
    graphs = a.schedule(9, include_current=True)
    manual = [b.current()] + [b.step() for _ in range(8)]
    assert len(graphs) == 9
    for ga, gb in zip(graphs, manual):
        np.testing.assert_array_equal(ga.adjacency, gb.adjacency)
    # regen epochs were crossed (rounds 4 and 8)
    assert a.n_regens == b.n_regens == 2


def test_walk_schedule_matches_stepping():
    g = DynamicGraph(10, min_degree=3, seed=3)
    graphs = g.schedule(8, include_current=True)
    wa = RandomWalkServer(seed=1)
    wa.reset(graphs[0])
    wb = RandomWalkServer(seed=1)
    wb.reset(graphs[0])
    batch = wa.walk_schedule(graphs, advance_first=False)
    manual = [wb.position] + [wb.step(gr) for gr in graphs[1:]]
    np.testing.assert_array_equal(batch, np.asarray(manual))
    np.testing.assert_array_equal(wa.visit_counts, wb.visit_counts)


def test_zone_schedule_shapes_and_chunking():
    """Two chunked schedules replay one long schedule draw-for-draw."""
    def build(chunks):
        g = DynamicGraph(15, min_degree=4, regen_every=10, seed=5)
        w = RandomWalkServer(seed=6)
        w.reset(g.current())
        rng = np.random.default_rng(9)
        out, r = [], 0
        for n in chunks:
            out.append(markov.zone_schedule(g, w, n, 4, rng, start_round=r))
            r += n
        return out

    (one,) = build([24])
    parts = build([10, 14])
    assert one.idx.shape == (24, 4)
    assert one.keys.shape == (24, 2)
    cat = np.concatenate([p.idx for p in parts])
    np.testing.assert_array_equal(one.idx, cat)
    np.testing.assert_array_equal(
        one.keys, np.concatenate([p.keys for p in parts]))
    np.testing.assert_array_equal(
        one.clients, np.concatenate([p.clients for p in parts]))
    # padded slots masked out, active counts consistent
    assert (one.active == one.mask.sum(axis=1)).all()
    assert ((one.mask == 0) | (one.mask == 1)).all()


def test_schedule_keys_match_eager_key_sequence():
    """keys[k] == PRNGKey(k-th rng.integers draw) given identical zone
    subsampling draws in between."""
    g = DynamicGraph(8, min_degree=7, seed=2)   # complete-ish: no subsample
    w = RandomWalkServer(seed=3)
    w.reset(g.current())
    rng = np.random.default_rng(11)
    sched = markov.zone_schedule(g, w, 5, 8, rng, start_round=0)
    rng2 = np.random.default_rng(11)
    for k in range(5):
        expect = np.asarray(jax.random.PRNGKey(rng2.integers(2**31 - 1)))
        np.testing.assert_array_equal(sched.keys[k], expect)


# ------------------------------------------------- driver equivalence ----
@pytest.mark.parametrize("solver", ["closed_form", "prox_sgd"])
def test_scan_driver_equals_eager(fed, solver):
    """scan ≡ eager: identical client/server states and per-round losses
    over 25 rounds, chunk boundaries crossing a regeneration epoch."""
    st_e, losses_e = run_eager(make_trainer(fed, solver))
    st_s, losses_s = run_scan(make_trainer(fed, solver), "scan")
    assert_trees_close(st_e.clients.x, st_s.clients.x, atol=1e-6)
    assert_trees_close(st_e.clients.z, st_s.clients.z, atol=1e-6)
    assert_trees_close(st_e.server.y, st_s.server.y, atol=1e-6)
    np.testing.assert_allclose(losses_e, losses_s, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(st_e.visited),
                                  np.asarray(st_s.visited))
    assert int(st_s.server.round) == ROUNDS


def test_scan_fused_matches_eager_closed_form(fed):
    """scan_fused (masked zone Pallas kernel) tracks the eager closed-form
    trajectory to fp tolerance over 25 rounds."""
    st_e, losses_e = run_eager(make_trainer(fed, "closed_form"))
    st_f, losses_f = run_scan(make_trainer(fed, "closed_form"),
                              "scan_fused", chunks=(25,))
    assert_trees_close(st_e.clients.x, st_f.clients.x, atol=5e-6)
    assert_trees_close(st_e.server.y, st_f.server.y, atol=5e-6)
    np.testing.assert_allclose(losses_e, losses_f, atol=1e-4)


def test_scan_fused_rejects_prox_sgd(fed):
    tr = make_trainer(fed, "prox_sgd")
    state = tr.init_state(jax.random.PRNGKey(0))
    sched = tr.schedule(2, np.random.default_rng(0))
    with pytest.raises(ValueError, match="closed_form"):
        tr.run_chunk(state, sched, engine="scan_fused")


# ------------------------------------------- scenario equivalence -------
# All three mobility models, link dropouts on/off, churn on/off: the
# compiled scan driver must replay the eager trajectory under every
# scenario (the whole environment is host-side control plane).
SCENARIOS = [
    "random_waypoint",            # smooth mobility, links off, churn off
    "gauss_markov",               # smooth mobility (correlated velocities)
    "lossy_links",                # link dropouts ON
    "duty_cycle",                 # churn ON
    "field_trial",                # dropouts + churn together
]


@pytest.mark.slow
@pytest.mark.parametrize("scenario", SCENARIOS)
def test_scan_driver_equals_eager_under_scenario(fed, scenario):
    st_e, losses_e = run_eager(
        make_trainer(fed, "closed_form", scenario), rounds=13)
    st_s, losses_s = run_scan(
        make_trainer(fed, "closed_form", scenario), "scan", chunks=(6, 7))
    assert_trees_close(st_e.clients.x, st_s.clients.x, atol=1e-6)
    assert_trees_close(st_e.server.y, st_s.server.y, atol=1e-6)
    np.testing.assert_allclose(losses_e, losses_s, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(st_e.visited),
                                  np.asarray(st_s.visited))


def test_static_regen_scenario_is_trajectory_identical(fed):
    """Acceptance bar: scenario='static_regen' is bit-for-bit identical
    to the legacy DynamicGraph path (scenario=None), both engines."""
    st_none, losses_none = run_eager(make_trainer(fed, "closed_form", None),
                                     rounds=15)
    st_name, losses_name = run_eager(
        make_trainer(fed, "closed_form", "static_regen"), rounds=15)
    np.testing.assert_array_equal(losses_none, losses_name)
    assert_trees_close(st_none.clients.x, st_name.clients.x)
    assert_trees_close(st_none.server.y, st_name.server.y)
    st_scan, losses_scan = run_scan(
        make_trainer(fed, "closed_form", "static_regen"), "scan",
        chunks=(10, 5))
    np.testing.assert_allclose(losses_none, losses_scan, atol=1e-5)
    assert_trees_close(st_none.server.y, st_scan.server.y, atol=1e-6)


def test_round_metrics_schema_parity(fed):
    """Both engines emit the same round_metrics schema: identical key
    sets per entry, aligned 'round' values, identical wireless costs."""
    data, model = fed

    def mk():
        return RWSADMMTrainer(
            model, data, RWSADMMHparams(beta=1.0), zone_size=4,
            batch_size=20, regen_every=10, scenario="lossy_links", seed=0)

    res_e = run_simulation(mk(), rounds=12, eval_every=6, seed=0)
    res_s = run_simulation(mk(), rounds=12, eval_every=6, seed=0,
                           engine="scan")
    assert len(res_e.round_metrics) == len(res_s.round_metrics) == 12
    # Shared canonical validator: required keys, one key set per list,
    # canonical host types, consecutive rounds — and identical key sets
    # across engines.
    keys_e = validate_round_metrics(res_e.round_metrics)
    keys_s = validate_round_metrics(res_s.round_metrics)
    assert keys_e == keys_s, (sorted(keys_e), sorted(keys_s))
    for me, ms in zip(res_e.round_metrics, res_s.round_metrics):
        assert me["round"] == ms["round"]
        assert me["client"] == ms["client"]
        assert me["zone"] == ms["zone"]
        assert me["comm_bytes"] == ms["comm_bytes"]
        assert me["latency_s"] == ms["latency_s"]   # one pricing path
        assert me["energy_j"] == ms["energy_j"]
    assert res_e.total_latency_s == res_s.total_latency_s
    assert res_e.total_energy_j == res_s.total_energy_j


# ------------------------------------------- biased walk policies -------
@pytest.mark.parametrize("policy", ["staleness", "label_skew"])
def test_scan_driver_equals_eager_biased_policy(fed, policy):
    """Importance-biased walks thread the iw correction through both
    engines identically: scan replays the eager trajectory (states,
    losses, visits) with the correction active, chunk boundary mid-run."""
    kw = dict(walk_policy=policy, walk_bias=1.5)
    st_e, losses_e = run_eager(make_trainer(fed, "closed_form", **kw),
                               rounds=12)
    st_s, losses_s = run_scan(make_trainer(fed, "closed_form", **kw),
                              "scan", chunks=(5, 7))
    assert_trees_close(st_e.clients.x, st_s.clients.x, atol=1e-6)
    assert_trees_close(st_e.server.y, st_s.server.y, atol=1e-6)
    np.testing.assert_allclose(losses_e, losses_s, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(st_e.visited),
                                  np.asarray(st_s.visited))
    # the correction actually engaged: some recorded weight is not 1.0
    tr = make_trainer(fed, "closed_form", **kw)
    _ = run_eager(tr, rounds=12)
    assert any(w != 1.0 for w in tr.walker.weight_history)


def test_scan_fused_equals_eager_biased_policy(fed):
    """The fused kernel path applies the iw correction by rescaling the
    kernel's y-step, tracking the eager trajectory to fp tolerance."""
    kw = dict(walk_policy="staleness", walk_bias=1.5)
    st_e, losses_e = run_eager(make_trainer(fed, "closed_form", **kw),
                               rounds=12)
    st_f, losses_f = run_scan(make_trainer(fed, "closed_form", **kw),
                              "scan_fused", chunks=(12,))
    assert_trees_close(st_e.clients.x, st_f.clients.x, atol=5e-6)
    assert_trees_close(st_e.server.y, st_f.server.y, atol=5e-6)
    np.testing.assert_allclose(losses_e, losses_f, atol=1e-4)


def test_biased_policy_changes_trajectory(fed):
    """The correction is live: a staleness-policy run produces different
    server duals than the uniform default under identical seeds. (The
    visit sequence itself may coincide for many rounds — MH caps the
    probability of moving to attractive stale neighbors at the proposal
    1/deg, so early biased rows often equal the degree-chain rows — but
    the iw-scaled y-update must diverge as soon as any iw ≠ 1.)"""
    tr_u = make_trainer(fed, "closed_form")
    tr_b = make_trainer(fed, "closed_form", walk_policy="staleness",
                        walk_bias=1.5)
    st_u, _ = run_eager(tr_u, rounds=12)
    st_b, _ = run_eager(tr_b, rounds=12)
    assert any(w != 1.0 for w in tr_b.walker.weight_history)
    diffs = [float(np.abs(np.asarray(a) - np.asarray(b)).max())
             for a, b in zip(jax.tree_util.tree_leaves(st_u.server.y),
                             jax.tree_util.tree_leaves(st_b.server.y))]
    assert max(diffs) > 1e-6


# ------------------------------------------- staleness round metrics ----
def _staleness_oracle(idx, mask, rounds, n):
    """Independent recomputation of the per-round staleness metrics from
    the schedule's served sets."""
    last = np.full(n, -1, dtype=np.int64)
    out = []
    for r in range(rounds):
        served = np.asarray(idx[r])[np.asarray(mask[r]) > 0]
        last[served] = r
        stale = r - last
        out.append((float(np.median(stale)), int(stale.max())))
    return out


def test_staleness_metrics_pinned_and_engine_identical(fed):
    """Both engines emit staleness_p50/staleness_max, the values match
    an oracle replay of the served sets, and round 0 pins to the
    everyone-unserved baseline (served clients at staleness 0, the rest
    at 1 — integer math throughout, so equality is exact)."""
    rounds = 9

    tr_e = make_trainer(fed, "closed_form")
    rng = np.random.default_rng(0)
    state = tr_e.init_state(jax.random.PRNGKey(0))
    metrics_e = []
    for r in range(rounds):
        state, m = tr_e.round(state, r, rng)
        metrics_e.append(m)

    tr_s = make_trainer(fed, "closed_form")
    rng = np.random.default_rng(0)
    state = tr_s.init_state(jax.random.PRNGKey(0))
    sched = tr_s.schedule(rounds, rng, start_round=0)
    state, stacked = tr_s.run_chunk(state, sched, engine="scan")
    metrics_s = tr_s.chunk_round_metrics(sched, stacked, 0)

    oracle = _staleness_oracle(sched.idx, sched.mask, rounds,
                               tr_s.n_clients)
    for r, (me, ms) in enumerate(zip(metrics_e, metrics_s)):
        assert "staleness_p50" in me and "staleness_max" in me
        assert me["staleness_p50"] == ms["staleness_p50"]
        assert me["staleness_max"] == ms["staleness_max"]
        assert (ms["staleness_p50"], ms["staleness_max"]) == oracle[r]
    assert metrics_e[0]["staleness_max"] == 1   # unserved clients at r=0
    # chunked scan replays the one-shot values too
    tr_c = make_trainer(fed, "closed_form")
    rng = np.random.default_rng(0)
    state = tr_c.init_state(jax.random.PRNGKey(0))
    chunked = []
    r0 = 0
    for c in (4, 5):
        sch = tr_c.schedule(c, rng, start_round=r0)
        state, stk = tr_c.run_chunk(state, sch, engine="scan")
        chunked.extend(tr_c.chunk_round_metrics(sch, stk, r0))
        r0 += c
    for ms, mc in zip(metrics_s, chunked):
        assert ms["staleness_p50"] == mc["staleness_p50"]
        assert ms["staleness_max"] == mc["staleness_max"]


def test_run_simulation_engines_agree(fed):
    """run_simulation(engine=scan) reproduces the eager history/metrics."""
    data, model = fed

    def mk():
        return RWSADMMTrainer(
            model, data, RWSADMMHparams(beta=1.0), zone_size=4,
            batch_size=20, regen_every=10, seed=0)

    res_e = run_simulation(mk(), rounds=22, eval_every=10, seed=0)
    res_s = run_simulation(mk(), rounds=22, eval_every=10, seed=0,
                           engine="scan")
    assert [h["round"] for h in res_e.history] \
        == [h["round"] for h in res_s.history] == [10, 20, 22]
    for he, hs in zip(res_e.history, res_s.history):
        np.testing.assert_allclose(he["acc_personalized"],
                                   hs["acc_personalized"], atol=1e-6)
    assert res_e.total_comm_bytes == res_s.total_comm_bytes
    for me, ms in zip(res_e.round_metrics, res_s.round_metrics):
        assert me["client"] == ms["client"]
        assert me["zone"] == ms["zone"]
        np.testing.assert_allclose(me["train_loss"], ms["train_loss"],
                                   atol=1e-5)


# ------------------------------------------------- masked zone kernel ----
def test_zone_kernel_matches_oracle():
    from repro.core import tree as T
    from repro.kernels.rwsadmm_update.ops import rwsadmm_zone_fused_update
    from repro.kernels.rwsadmm_update.ref import (
        rwsadmm_zone_fused_update_ref,
    )

    key = jax.random.PRNGKey(0)
    Z, N = 5, 3000
    ks = jax.random.split(key, 4)
    x, z, g = (jax.random.normal(k, (Z, N)) for k in ks[:3])
    y = jax.random.normal(ks[3], (N,))
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])

    def split(a):
        return {"a": a[..., :1000].reshape(a.shape[:-1] + (10, 100)),
                "b": a[..., 1000:]}

    xk, zk, yk = rwsadmm_zone_fused_update(
        split(x), split(z), split(y), split(g), mask, 0.01,
        beta=2.0, eps_half=5e-4, n_total=8.0)
    xr, zr, yr = rwsadmm_zone_fused_update_ref(
        x, z, y, g, mask, 0.01, beta=2.0, eps_half=5e-4, n_total=8.0)
    np.testing.assert_allclose(np.asarray(jax.vmap(T.flatten)(xk)), xr,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(jax.vmap(T.flatten)(zk)), zr,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(T.flatten(yk)), yr, atol=1e-6)
    # padding invariants: masked-out clients pass through, zero y-fold
    np.testing.assert_array_equal(
        np.asarray(jax.vmap(T.flatten)(xk))[3:], np.asarray(x)[3:])
    np.testing.assert_array_equal(
        np.asarray(jax.vmap(T.flatten)(zk))[3:], np.asarray(z)[3:])


def test_zone_kernel_matches_masked_zone_round():
    """Kernel vs core.rwsadmm.zone_round_masked (pytree-level oracle)."""
    from repro.core import rwsadmm
    from repro.core.rwsadmm import ClientState
    from repro.kernels.rwsadmm_update.ops import rwsadmm_zone_fused_update

    hp = RWSADMMHparams(beta=4.0, kappa=0.02, epsilon=1e-4)
    key = jax.random.PRNGKey(1)
    Z = 6
    template = {"w": jnp.zeros((Z, 37, 5)), "b": jnp.zeros((Z, 11))}
    ks = jax.random.split(key, 4)
    mk = lambda k: jax.tree_util.tree_map(
        lambda l: jax.random.normal(jax.random.fold_in(k, l.ndim), l.shape),
        template)
    x, z, g = mk(ks[0]), mk(ks[1]), mk(ks[2])
    y = jax.tree_util.tree_map(lambda l: l[0] * 0.5, mk(ks[3]))
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])

    ref_c, ref_y = rwsadmm.zone_round_masked(
        ClientState(x=x, z=z), y, g, mask, hp, 0.02, n_total=9.0)
    xk, zk, yk = rwsadmm_zone_fused_update(
        x, z, y, g, mask, 0.02, beta=hp.beta, eps_half=hp.eps_half,
        n_total=9.0)
    assert_trees_close(ref_c.x, xk, atol=1e-6)
    assert_trees_close(ref_c.z, zk, atol=1e-6)
    assert_trees_close(ref_y, yk, atol=1e-6)
