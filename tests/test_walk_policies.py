"""Statistical test harness for the walk-policy stack (docs/walks.md).

The importance-biased policies make two separable claims, and the
harness tests each where it is mathematically exact:

1. **Chain design** — the biased MH construction targets π ∝ w:
   detailed balance holds algebraically, ``stationary_distribution``
   of the built matrix matches w/Σw, and a *chi-square goodness-of-fit*
   test confirms long thinned walks realize that π empirically, for
   every policy on both graph backends. The critical value comes from
   the Wilson–Hilferty cube-root normal approximation (no scipy
   dependency); walks are thinned (every 20th visit) so the chain's
   autocorrelation doesn't inflate the statistic, and all draws are
   seeded, so the statistics below are deterministic numbers checked
   against a fixed α = 1e-4 threshold — not flaky re-rolls.

2. **Estimator correction** — the per-visit importance weight
   iw = Σw/(n·w_i) = 1/(n·π_i) makes the visit-weighted estimator
   unbiased under the chain's stationary law: Σ_i π_i·iw_i·f_i = f̄
   exactly (an algebraic identity, property-tested over arbitrary
   weight vectors), and live ``label_skew`` walks (fixed target)
   converge to the true mean. The ``staleness`` target moves every
   step, so its correction is exact only w.r.t. the *instantaneous*
   frozen chain — which is precisely what its chi-square and identity
   tests freeze and verify.

Plus regression pins for the O(1) incremental ``hitting_time`` against
the oracle history rescan, and the iw plumbing through
``zone_schedule``/``fleet_zone_schedule``.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
from repro.core import markov as M
from repro.core.graph import (
    DynamicGraph,
    neighbor_graph_from_dense,
    random_geometric_graph,
)
from repro.core.markov import RandomWalkServer
from repro.data.partition import (
    client_label_histograms,
    label_skew_weights,
    padded_label_histograms,
)

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile("walks", deadline=None)
    hypothesis.settings.load_profile("walks")

N_NODES = 12
# Fixed per-client utilities for the label_skew tests (any strictly
# positive vector works; this one is spread enough to bias visibly).
LABEL_W = np.random.default_rng(42).uniform(0.5, 3.0, N_NODES)


def small_graph():
    return random_geometric_graph(N_NODES, 4, np.random.default_rng(0))


def make_walker(policy, seed=11, gamma=1.5, label_w=LABEL_W):
    w = RandomWalkServer(transition="metropolis", seed=seed,
                         policy=policy, bias_gamma=gamma)
    if policy == "label_skew":
        w.set_label_weights(label_w)
    return w


def chi2_critical(df, z=3.719):
    """Upper χ²_df quantile via Wilson–Hilferty (cube-root normal):
    χ²_q ≈ df·(1 − 2/(9df) + z·√(2/(9df)))³. z = 3.719 is the standard
    normal upper 1e-4 quantile, so this is the α = 1e-4 critical value
    (within ~1% of the exact quantile for df ≥ 5 — plenty for a test
    threshold with the observed ≥ 1.8× margins)."""
    return df * (1.0 - 2.0 / (9.0 * df)
                 + z * np.sqrt(2.0 / (9.0 * df))) ** 3


def chi2_stat(samples, pi):
    n = len(pi)
    counts = np.bincount(np.asarray(samples), minlength=n)
    expected = len(samples) * np.asarray(pi)
    return float(((counts - expected) ** 2 / expected).sum())


def replay_iws(history, n, policy, gamma, label_w=None):
    """Oracle replay of the per-visit importance weights from the visit
    history alone — independently re-derives what ``_record_visit``
    computed (same float ops, so equality is exact)."""
    last = np.full(n, -1, dtype=np.int64)
    last[history[0]] = 0
    iws = [1.0]
    for t in range(1, len(history)):
        if policy == "staleness":
            k = t - 1
            w = (1.0 + (k - last).astype(np.float64)) ** gamma
        else:
            w = np.asarray(label_w, np.float64)
        i = history[t]
        iws.append(float(w.sum() / (n * w[i])))
        last[i] = t
    return np.asarray(iws)


# ------------------------------------------------------- chain design ----
def test_biased_matrix_detailed_balance_and_stochasticity():
    """w_i·P_ij = w_j·P_ji for every edge (detailed balance — the
    algebraic reason π ∝ w), rows sum to 1, entries nonnegative."""
    g = small_graph()
    rng = np.random.default_rng(1)
    for _ in range(4):
        w = rng.uniform(0.1, 5.0, g.n)
        p = M.biased_transition_matrix(g, w)
        np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-12)
        assert (p >= 0.0).all()
        flow = w[:, None] * p
        off = ~np.eye(g.n, dtype=bool)
        np.testing.assert_allclose(flow[off], flow.T[off], atol=1e-12)


def test_biased_row_self_loop_never_negative():
    """Regression: the rounded off-diagonal terms w_j/(w_i·deg_j) can
    sum a hair past 1.0, which used to leave a −2⁻⁵² self-loop that
    ``rng.choice`` rejects mid-walk. Seed 44 below reproduces the
    overflow pre-clamp (matrix min was −2.22e−16); both the full
    matrix and the backend-shared row builder must clamp identically."""
    rng = np.random.default_rng(44)
    g = random_geometric_graph(30, 6, rng)
    w = rng.uniform(0.2, 5.0, 30)
    p = M.biased_transition_matrix(g, w)
    assert p.min() >= 0.0
    np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-12)

    sg = neighbor_graph_from_dense(g)
    wk = M.RandomWalkServer(transition="metropolis", seed=0,
                            policy="label_skew")
    wk.set_label_weights(w)
    # Row comparison uses the walker's mean-normalized weights — the
    # chain is scale-invariant mathematically but not bit-for-bit.
    p_norm = M.biased_transition_matrix(g, wk.label_weights)
    draw = np.random.default_rng(7)
    for i in range(g.n):
        for graph in (g, sg):
            _, row = wk._biased_row(graph, i)
            assert row.min() >= 0.0
            assert row[i] == p_norm[i, i]
            draw.choice(g.n, p=row)  # raises if any mass is negative

    # The uniform Metropolis chain has the identical failure mode
    # (min(1/deg_i, 1/deg_j) terms rounding past 1): the n=12 deg-5
    # graph at rng seed 0 had a −2.22e−16 diagonal pre-clamp. Pin the
    # dense matrix and the sparse row builder together.
    g0 = random_geometric_graph(12, 5, np.random.default_rng(0))
    pm = M.metropolis_transition_matrix(g0)
    assert pm.min() >= 0.0
    np.testing.assert_allclose(pm.sum(axis=1), 1.0, atol=1e-12)
    sg0 = neighbor_graph_from_dense(g0)
    uni = M.RandomWalkServer(transition="metropolis", seed=0)
    for i in range(g0.n):
        cands, probs = uni._sparse_row(sg0, i)
        assert probs.min() >= 0.0
        assert probs[cands == i][0] == pm[i, i]
        draw.choice(g0.n, p=uni.transition_row(g0, i))


def test_biased_matrix_unit_weights_is_metropolis():
    """w ≡ 1 degenerates to the Metropolis-Hastings chain float-for-
    float — the biased construction is a strict generalization."""
    g = small_graph()
    np.testing.assert_array_equal(
        M.biased_transition_matrix(g, np.ones(g.n)),
        M.metropolis_transition_matrix(g))


def test_stationary_distribution_matches_design_target():
    """``stationary_distribution`` of the built chain equals w/Σw, and
    the walker's ``stationary_target`` agrees (label_skew: after mean
    normalization, which leaves π invariant)."""
    g = small_graph()
    rng = np.random.default_rng(2)
    for _ in range(4):
        w = rng.uniform(0.05, 8.0, g.n)
        pi = M.stationary_distribution(M.biased_transition_matrix(g, w))
        np.testing.assert_allclose(pi, w / w.sum(), atol=1e-9)
    walker = make_walker("label_skew")
    pi = M.stationary_distribution(walker.matrix(g))
    np.testing.assert_allclose(pi, walker.stationary_target(g.n),
                               atol=1e-9)


CHI2_STEPS, CHI2_THIN = 30_000, 20


@pytest.mark.parametrize("backend", ["dense", "sparse"])
@pytest.mark.parametrize("policy", ["degree", "metropolis", "label_skew"])
def test_chi_square_stationarity(policy, backend):
    """Long seeded walk, thinned to beat autocorrelation: empirical
    visit frequencies pass a χ² GOF test against the chain's
    ``stationary_distribution`` at α = 1e-4, on both graph backends.
    (Observed statistics ≤ ~21 vs the 37.75 critical value.)"""
    g = small_graph()
    gr = neighbor_graph_from_dense(g) if backend == "sparse" else g
    walker = make_walker(policy)
    walker.reset(gr, start=0)
    for _ in range(CHI2_STEPS):
        walker.step(gr)
    pi = M.stationary_distribution(walker.matrix(g))
    stat = chi2_stat(np.asarray(walker.history[1:])[::CHI2_THIN], pi)
    assert stat < chi2_critical(g.n - 1), (policy, backend, stat)


@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_chi_square_staleness_frozen_target(backend):
    """The staleness target moves every step, so its stationarity claim
    is instantaneous: freeze the weight vector a live staleness walk
    developed, run the fixed-target chain it induces (via a label_skew
    walker — the identical row construction), and χ²-test against
    π ∝ w_frozen."""
    g = small_graph()
    live = make_walker("staleness")
    live.reset(g, start=0)
    for _ in range(60):
        live.step(g)
    snap = live.policy_weights(g.n)
    assert snap.min() >= 1.0 and snap.max() > snap.min()  # developed

    gr = neighbor_graph_from_dense(g) if backend == "sparse" else g
    frozen = make_walker("label_skew", seed=13, label_w=snap)
    frozen.reset(gr, start=0)
    for _ in range(CHI2_STEPS):
        frozen.step(gr)
    pi = M.stationary_distribution(frozen.matrix(g))
    np.testing.assert_allclose(pi, snap / snap.sum(), atol=1e-9)
    stat = chi2_stat(np.asarray(frozen.history[1:])[::CHI2_THIN], pi)
    assert stat < chi2_critical(g.n - 1), (backend, stat)


def test_staleness_walk_covers_faster_than_uniform():
    """The point of the staleness bias: chasing under-visited clients
    covers the graph sooner and keeps the staleness clock tighter than
    the uniform Metropolis chain (same seeds, same graph)."""
    g = small_graph()
    cover_b, cover_u, stale_b, stale_u = [], [], [], []
    for seed in range(5):
        walkers = (make_walker("staleness", seed=seed),
                   make_walker("metropolis", seed=seed))
        for walker, cover, stale in zip(walkers, (cover_b, cover_u),
                                        (stale_b, stale_u)):
            walker.reset(g, start=0)
            worst = 0
            for k in range(1, 400):
                walker.step(g)
                worst = max(worst, k - int(walker._last_visit.min()))
            cover.append(walker.hitting_time())
            stale.append(worst)
    assert np.mean(cover_b) < np.mean(cover_u)
    assert np.mean(stale_b) < np.mean(stale_u)


# ------------------------------------------------- estimator correction --
def test_importance_weight_identity_exact():
    """The unbiasedness identity, algebraically: under the chain's own
    stationary law, Σ_i π_i · iw_i · f_i = mean(f) for ANY positive
    weight vector and ANY f (iw_i = Σw/(n·w_i) = 1/(n·π_i))."""
    g = small_graph()
    rng = np.random.default_rng(3)
    for _ in range(6):
        w = rng.uniform(0.05, 10.0, g.n)
        f = rng.normal(size=g.n)
        pi = M.stationary_distribution(M.biased_transition_matrix(g, w))
        iw = w.sum() / (g.n * w)
        assert abs(float((pi * iw * f).sum()) - f.mean()) < 1e-9


def test_label_skew_walk_unbiased_estimates():
    """Live fixed-target walks: the iw-weighted empirical mean of a
    per-client statistic converges to the true (uniform) mean even
    though visits are biased toward high-utility clients. Seeded, so
    the per-seed errors are deterministic (observed ≤ 0.017)."""
    g = small_graph()
    f = np.random.default_rng(5).uniform(0, 1, g.n)
    errs = []
    for seed in range(4):
        walker = make_walker("label_skew", seed=seed)
        walker.reset(g, start=0)
        for _ in range(6000):
            walker.step(g)
        iw = np.asarray(walker.weight_history[1:])
        hist = np.asarray(walker.history[1:])
        errs.append(abs(float((iw * f[hist]).mean()) - f.mean()))
    assert max(errs) < 0.05
    assert np.mean(errs) < 0.02


@hypothesis.given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    gamma=st.floats(min_value=0.25, max_value=3.0,
                    allow_nan=False, allow_infinity=False),
)
@hypothesis.settings(max_examples=30, deadline=None)
def test_property_identity_holds_for_any_weights(seed, gamma):
    """Property form of the unbiasedness identity: arbitrary positive
    weight vectors (any draw, any sharpening exponent) keep
    Σ π_i·iw_i·f_i = mean(f) to fp accuracy."""
    g = small_graph()
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.02, 20.0, g.n) ** gamma
    f = rng.normal(size=g.n)
    pi = M.stationary_distribution(M.biased_transition_matrix(g, w))
    iw = w.sum() / (g.n * w)
    scale = max(1.0, float(np.abs(f).max()))
    assert abs(float((pi * iw * f).sum()) - f.mean()) < 1e-8 * scale


def test_property_identity_deterministic_twin():
    """Seed-sweep twin of the hypothesis property above, so minimal
    environments (no hypothesis installed) keep the coverage."""
    g = small_graph()
    for seed in range(8):
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.02, 20.0, g.n) ** rng.uniform(0.25, 3.0)
        f = rng.normal(size=g.n)
        pi = M.stationary_distribution(M.biased_transition_matrix(g, w))
        iw = w.sum() / (g.n * w)
        assert abs(float((pi * iw * f).sum()) - f.mean()) < 1e-8


@pytest.mark.parametrize("policy", ["staleness", "label_skew"])
def test_recorded_iws_match_oracle_replay(policy):
    """``weight_history`` equals an independent replay from the visit
    history (exact floats): iw is computed from the pre-visit weight
    state, staleness clocks tick in visit order, label weights are
    scale-invariant in iw."""
    g = small_graph()
    walker = make_walker(policy, gamma=2.0)
    walker.reset(g, start=0)
    for _ in range(300):
        walker.step(g)
    # label_skew: replay with the walker's mean-normalized weights —
    # iw is mathematically scale-invariant but only bit-exact on the
    # floats the walker actually read.
    oracle = replay_iws(walker.history, g.n, policy, 2.0,
                        walker.label_weights)
    np.testing.assert_array_equal(np.asarray(walker.weight_history),
                                  oracle)


def test_uniform_policies_record_unit_weights():
    """degree/metropolis: every recorded weight is exactly 1.0 and
    ``walk_weights`` returns None — the engines' signal to skip the
    correction and keep the uniform computation graph untouched."""
    g = small_graph()
    for policy in ("degree", "metropolis"):
        walker = make_walker(policy)
        walker.reset(g, start=0)
        for _ in range(50):
            walker.step(g)
        assert walker.weight_history == [1.0] * 51
        assert walker.walk_weights(20) is None
        assert not walker.is_biased
    assert make_walker("staleness").is_biased
    with pytest.raises(ValueError, match="unknown walk policy"):
        RandomWalkServer(policy="nope")


def test_label_weights_validation():
    walker = make_walker("metropolis")
    with pytest.raises(ValueError, match="strictly positive"):
        walker.set_label_weights(np.array([1.0, 0.0, 2.0]))
    walker = make_walker("label_skew", label_w=np.array([2.0, 4.0, 6.0]))
    np.testing.assert_allclose(walker.label_weights.mean(), 1.0)
    with pytest.raises(ValueError, match="length"):
        walker.policy_weights(7)


# ------------------------------------------------- hitting-time pin ------
def oracle_hitting_time(history, n):
    """The O(history·n) rescan the incremental tracker replaced."""
    seen = set()
    for t, i in enumerate(history):
        seen.add(int(i))
        if len(seen) == n:
            return t
    return None


@pytest.mark.parametrize("backend", ["dense", "sparse"])
@pytest.mark.parametrize("policy", M.WALK_POLICIES)
def test_hitting_time_matches_oracle(policy, backend):
    """The incremental first-full-coverage step equals the oracle scan
    at every prefix of the walk, on both backends, for every policy —
    including None before coverage and a clean slate after reset()."""
    g = random_geometric_graph(25, 4, np.random.default_rng(6))
    gr = neighbor_graph_from_dense(g) if backend == "sparse" else g
    walker = make_walker(policy,
                         label_w=np.random.default_rng(0).uniform(0.5, 2,
                                                                  25))
    walker.reset(gr, start=0)
    assert walker.hitting_time() == oracle_hitting_time(walker.history,
                                                        g.n) is None
    for _ in range(600):
        walker.step(gr)
        assert walker.hitting_time() == oracle_hitting_time(
            walker.history, g.n)
    assert walker.hitting_time() is not None     # 600 steps cover n=25
    walker.reset(gr, start=0)
    assert walker.hitting_time() is None


def test_hitting_time_batched_walk_matches_oracle():
    g = small_graph()
    walker = make_walker("staleness")
    walker.reset(g, start=0)
    walker.walk_schedule_batched([g] * 120)
    assert walker.hitting_time() == oracle_hitting_time(walker.history,
                                                        g.n)


# ------------------------------------------------- schedule plumbing -----
def test_zone_schedule_iw_column():
    """The (R,) iw column equals the oracle replay of the walker's visit
    history tail, aligned with the clients column; uniform policies get
    iw=None. Chunked schedules concatenate to the one-shot column."""
    def build(policy, chunks):
        dg = DynamicGraph(N_NODES, min_degree=4, regen_every=10, seed=5)
        walker = make_walker(policy, seed=6)
        walker.reset(dg.current())
        rng = np.random.default_rng(9)
        out, r = [], 0
        for c in chunks:
            out.append(M.zone_schedule(dg, walker, c, 4, rng,
                                       start_round=r))
            r += c
        return out, walker

    (one,), walker = build("staleness", [18])
    assert one.iw is not None and one.iw.shape == (18,)
    oracle = replay_iws(walker.history, N_NODES, "staleness", 1.5)
    np.testing.assert_array_equal(one.iw, oracle[-18:])
    np.testing.assert_array_equal(one.clients,
                                  np.asarray(walker.history)[-18:])
    assert one.iw[0] == 1.0          # round-0 entry: the reset visit

    parts, _ = build("staleness", [8, 10])
    np.testing.assert_array_equal(
        one.iw, np.concatenate([p.iw for p in parts]))

    (uni,), _ = build("metropolis", [18])
    assert uni.iw is None


@pytest.mark.parametrize("mode", ["roundrobin", "simultaneous"])
def test_fleet_schedule_iw_column(mode):
    """Fleet iw shapes: (R,) in round-robin (the active walker's weight;
    parked walkers contribute their last recorded weight), (R, K) in
    simultaneous. Values tie back to the walkers' weight histories."""
    k_walkers, rounds = 3, 12
    dg = DynamicGraph(20, min_degree=4, regen_every=10, seed=2)
    walkers = [make_walker("staleness", seed=10 + k,
                           label_w=np.ones(20)) for k in range(k_walkers)]
    for w in walkers:
        w.reset(dg.current())
    sched = M.fleet_zone_schedule(dg, walkers, rounds, 4,
                                  np.random.default_rng(3),
                                  mode=mode, sync_every=7)
    if mode == "roundrobin":
        assert sched.iw.shape == (rounds,)
        for r in range(rounds):
            k = int(sched.walker[r])
            assert sched.iw[r] in walkers[k].weight_history
    else:
        assert sched.iw.shape == (rounds, k_walkers)
        for k, w in enumerate(walkers):
            np.testing.assert_array_equal(
                sched.iw[-5:, k], np.asarray(w.weight_history[-5:]))
    uni = [RandomWalkServer(seed=20 + k) for k in range(k_walkers)]
    dg2 = DynamicGraph(20, min_degree=4, regen_every=10, seed=2)
    for w in uni:
        w.reset(dg2.current())
    assert M.fleet_zone_schedule(dg2, uni, rounds, 4,
                                 np.random.default_rng(3),
                                 mode=mode, sync_every=7).iw is None


# ------------------------------------------------- partition utilities ---
def test_label_histograms_and_skew_weights():
    """Histogram rows are simplex points; a client holding only the
    globally rarest label gets the largest utility; balanced clients
    sit at u = 1; γ sharpens monotonically."""
    labels = np.array([0] * 50 + [1] * 30 + [2] * 10)
    parts = [np.arange(0, 40),            # pure label 0 (common)
             np.arange(50, 80),           # pure label 1
             np.arange(80, 90),           # pure label 2 (rare)
             np.array([0, 1, 50, 51, 80, 81])]   # balanced thirds
    hist = client_label_histograms(labels, parts)
    np.testing.assert_allclose(hist.sum(axis=1), 1.0)
    u = label_skew_weights(hist)
    assert u[2] == u.max() and u[0] == u.min()
    np.testing.assert_allclose(u[3], 1.0)
    u_sharp = label_skew_weights(hist, gamma=2.0)
    np.testing.assert_allclose(u_sharp, u ** 2)


def test_padded_histograms_match_list_histograms():
    """The trainers' padded-device layout produces the same histograms
    as the index-list partitioner view (padding rows ignored)."""
    rng = np.random.default_rng(8)
    labels = rng.integers(0, 5, 200)
    parts = [rng.choice(200, size=s, replace=False)
             for s in (30, 17, 44)]
    m = max(len(p) for p in parts)
    y_padded = np.zeros((3, m), np.int64)
    n_valid = np.array([len(p) for p in parts])
    for k, p in enumerate(parts):
        y_padded[k, : len(p)] = labels[p]
    np.testing.assert_allclose(
        padded_label_histograms(y_padded, n_valid, n_classes=5),
        client_label_histograms(labels, parts, n_classes=5))
