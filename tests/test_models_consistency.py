"""Deeper model correctness: decode == teacher-forced forward, sliding
window ring buffers, mLSTM chunked == quadratic oracle, param counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models.registry import build_model, random_batch

CONSISTENCY_ARCHS = [
    "tinyllama-1.1b", "gemma3-12b", "xlstm-350m", "recurrentgemma-9b",
    "qwen3-moe-30b-a3b", "qwen2-vl-2b",
]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T, T0 = 12, 7
    batch = random_batch(cfg, 2, T, seed=3)
    full = model.apply(params, batch)
    off = full.shape[1] - T
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :T0]
    logits_pre, cache = model.prefill(params, pre, 32)
    np.testing.assert_allclose(
        logits_pre[:, off + T0 - 1], full[:, off + T0 - 1],
        atol=2e-3, rtol=1e-3)
    for t in range(T0, T):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1])
        np.testing.assert_allclose(lg, full[:, off + t],
                                   atol=2e-3, rtol=1e-3)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-large-v3").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 10
    batch = random_batch(cfg, 2, T, seed=4)
    full = model.apply(params, batch)
    enc = model.encode(params, batch["frames"])
    cache = model.init_cache(2, 16, enc_out=enc)
    for t in range(T):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1])
        np.testing.assert_allclose(lg, full[:, t], atol=2e-3, rtol=1e-3)


def test_whisper_cached_cross_kv_matches_recompute():
    """§Perf fix: precomputed cross-attention K/V must be numerically
    identical to per-token recompute."""
    cfg = get_config("whisper-large-v3").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 8
    batch = random_batch(cfg, 2, T, seed=4)
    full = model.apply(params, batch)
    enc = model.encode(params, batch["frames"])
    cache = model.init_cache(2, 16, enc_out=enc, params=params)
    assert "cross_kv" in cache
    for t in range(T):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1])
        np.testing.assert_allclose(lg, full[:, t], atol=2e-3, rtol=1e-3)


def test_sliding_window_ring_buffer_beyond_window():
    """Decode past the window: ring overwrites; result must equal the
    teacher-forced forward with the same window mask."""
    import dataclasses

    cfg = dataclasses.replace(get_config("gemma3-12b").reduced(), window=6)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    T = 16  # > window
    batch = random_batch(cfg, 1, T, seed=5)
    full = model.apply(params, batch)
    cache = model.init_cache(1, 32)
    for t in range(T):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1])
        np.testing.assert_allclose(lg, full[:, t], atol=3e-3, rtol=1e-3)


def test_mlstm_chunked_matches_quadratic_oracle():
    from repro.models import recurrent as R

    b, s, nh, hd = 2, 64, 2, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, nh, hd))
    k = jax.random.normal(ks[1], (b, s, nh, hd))
    v = jax.random.normal(ks[2], (b, s, nh, hd))
    log_i = jax.random.normal(ks[3], (b, s, nh))
    log_f = -jax.nn.softplus(jax.random.normal(ks[4], (b, s, nh)))
    ref = R._mlstm_quadratic(q, k, v, log_i, log_f)
    for chunk in (8, 16, 48):  # includes non-divisible (64 % 48 != 0)
        out = R._mlstm_chunked(q, k, v, log_i, log_f, chunk)
        np.testing.assert_allclose(out, ref, atol=2e-4, rtol=1e-3)


def test_rglru_linear_scan_matches_sequential():
    from repro.models.recurrent import linear_scan

    b, s, d = 2, 33, 5
    key = jax.random.PRNGKey(2)
    a = jax.nn.sigmoid(jax.random.normal(key, (b, s, d)))
    bb = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    h = linear_scan(a, bb)
    # sequential reference
    hs = []
    hp = jnp.zeros((b, d))
    for t in range(s):
        hp = a[:, t] * hp + bb[:, t]
        hs.append(hp)
    ref = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(h, ref, atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_analytic_close_to_actual(arch):
    """Analytic param_count (used for MODEL_FLOPS = 6ND) within 12% of the
    actual reduced-model init (layout details differ slightly)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape))
                 for l in jax.tree_util.tree_leaves(params))
    analytic = cfg.param_count()
    assert abs(analytic - actual) / actual < 0.35, (analytic, actual)


def test_full_config_param_counts_sane():
    """Full (non-reduced) analytic counts land near the advertised sizes."""
    expect = {
        "qwen2-7b": (6e9, 9e9),
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "yi-34b": (30e9, 38e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "gemma3-12b": (8e9, 14e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "qwen2-vl-2b": (1.2e9, 2.4e9),
        "xlstm-350m": (0.25e9, 0.65e9),  # pf=2 mLSTM proj is heavier
                                         # than the paper's exact layout
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active params ≪ total
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() < 0.1 * kimi.param_count()
