"""Per-architecture smoke tests (required deliverable f):

For each assigned arch: instantiate the REDUCED variant of the same family
(≤2 pattern repeats, d_model ≤ 256, ≤4 experts), run one forward and one
RWSADMM train step on CPU, assert output shapes and absence of NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.core import rwsadmm
from repro.core.rwsadmm import RWSADMMHparams
from repro.models.registry import build_model, random_batch

B, T = 2, 16


@pytest.fixture(scope="module")
def hp():
    return RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = random_batch(cfg, B, T, seed=1)
    logits = model.apply(params, batch)
    s_total = T + (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, s_total, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_rwsadmm_train_step(arch, hp):
    """One full RWSADMM zone step on the reduced model: stochastic grad at
    x', closed-form x/z updates, incremental y fold — shapes preserved,
    no NaNs, and the update actually moves x."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = random_batch(cfg, B, T, seed=2)

    x, z = params, jax.tree_util.tree_map(jnp.zeros_like, params)
    y = params
    loss, grads = jax.value_and_grad(model.loss)(x, batch)
    assert jnp.isfinite(loss)

    client = rwsadmm.ClientState(x=x, z=z)
    new_client, c_new, c_old = rwsadmm.client_round(
        client, y, grads, hp, kappa=jnp.asarray(0.001))
    y_new = rwsadmm.y_update(y, c_new, c_old, n_total=4)

    for t in (new_client.x, new_client.z, y_new):
        leaves = jax.tree_util.tree_leaves(t)
        assert all(not bool(jnp.isnan(l).any()) for l in leaves)
    # structure preserved
    assert (jax.tree_util.tree_structure(new_client.x)
            == jax.tree_util.tree_structure(params))
    # x moved (gradient step from y)
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), new_client.x, x)
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if arch == "whisper-large-v3":
        cache = model.init_cache(B, 32)
    else:
        cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    # cache advanced
    assert int(cache2["step"]) == 1
