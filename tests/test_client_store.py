"""Property tests for the bounded LRU client-state store (the lazy
client plane's core, ``repro.fl.client_store``).

Invariants, each as a hypothesis property with a deterministic
seed-sweep twin (pattern of ``test_scenario_properties.py``):

* residency never exceeds capacity, and the store's LRU bookkeeping
  (resident set + order, spill set, per-call counters) tracks an
  independent python oracle replay exactly;
* evict → restore is bit-exact: rows written before eviction come back
  bit-for-bit on revisit, and never-written rows equal the init
  template;
* visit order dictates eviction order (least-recently-visited outside
  the working set goes first);
* capacity ≥ the visited set degenerates to the dense plane: zero
  evictions, zero restores;
* a single working set larger than capacity refuses loudly;
* async prefetch is invisible to the LRU: a prefetch-on store driven
  through a stage-next/ensure-current pipeline tracks a prefetch-off
  twin bit-for-bit (residency, spills, base counters, row data), and
  its ``prefetch_{hits,misses}`` counters replay a python staging-set
  oracle exactly.
"""
import os
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
from repro.checkpoint import load_client_store, save_client_store
from repro.data import synthetic_lr_factory
from repro.fl.client_store import (
    PREFETCH_COUNTERS,
    STORE_COUNTERS,
    ClientStore,
)

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "smoke", max_examples=20, deadline=None,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    hypothesis.settings.register_profile("default", deadline=None)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "default"))

N_CLIENTS = 12


def _make_store(capacity, n=N_CLIENTS, seed=0, prefetch=False):
    factory = synthetic_lr_factory(
        n_clients=n, n_features=5, n_classes=3, min_samples=4,
        mean_samples=1.0, seed=seed)
    store = ClientStore(factory, capacity, prefetch=prefetch)
    template = {"x": jnp.full((3,), 0.5, jnp.float32),
                "z": jnp.zeros((2,), jnp.float32)}
    clients = store.reset(template)
    return store, clients, template


def _write_rows(store, clients, mirror, ids, tag):
    """Scatter a distinguishable value into each visited row (simulating
    a training update) and mirror it host-side for later comparison."""
    slots = store.slots(np.asarray(ids))
    for i, s in zip(ids, slots):
        val = np.float32(1.0 + tag + i / 64.0)
        clients = jax.tree_util.tree_map(
            lambda l: l.at[int(s)].set(val), clients)
        mirror[int(i)] = val
    return clients


def _row_leaves(clients, slot):
    return [np.asarray(leaf[slot])
            for leaf in jax.tree_util.tree_leaves(clients)]


def _check_row(store, clients, template, mirror, i):
    """Row for client ``i`` (resident or spilled) must equal the last
    value written, or the template if never written."""
    if store.slot_arr[i] >= 0:
        leaves = _row_leaves(clients, int(store.slot_arr[i]))
    elif int(i) in store._spill:
        leaves = store._spill[int(i)]
    else:
        return  # never materialized — nothing to check
    expect = (jax.tree_util.tree_leaves(template) if int(i) not in mirror
              else [np.full(np.shape(t), mirror[int(i)], np.float32)
                    for t in jax.tree_util.tree_leaves(template)])
    for got, want in zip(leaves, expect):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def check_lru_oracle(zones, capacity, n=N_CLIENTS):
    """Drive the store through ``zones`` (a visit sequence of id lists,
    possibly with repeats/padding) against an independent LRU oracle."""
    store, clients, template = _make_store(capacity, n=n)
    mirror: dict[int, np.float32] = {}
    oracle: OrderedDict[int, None] = OrderedDict()
    spilled: set[int] = set()
    for t, zone in enumerate(zones):
        zone = [int(i) % n for i in zone]
        uniq = list(dict.fromkeys(zone))
        if len(uniq) > capacity:
            with pytest.raises(ValueError, match="exceeds store capacity"):
                store.ensure(clients, np.asarray(zone))
            continue  # refused before any mutation
        clients, stats = store.ensure(clients, np.asarray(zone))

        # -- oracle replay of this ensure call ------------------------
        missing = [i for i in uniq if i not in oracle]
        exp = {"hits": len(uniq) - len(missing), "misses": len(missing),
               "evictions": 0, "restores": 0}
        need = len(missing) - (capacity - len(oracle))
        if need > 0:
            victims = [i for i in oracle if i not in set(uniq)][:need]
            for v in victims:
                del oracle[v]
                spilled.add(v)
            exp["evictions"] = need
        for i in missing:
            if i in spilled:
                exp["restores"] += 1
                spilled.discard(i)
            oracle[i] = None
        for i in uniq:
            oracle.move_to_end(i)
        assert stats == exp, f"step {t}: {stats} != oracle {exp}"

        # -- structural invariants ------------------------------------
        assert store.n_resident == len(oracle) <= capacity
        assert list(store.resident_ids) == list(oracle)
        assert set(store.spilled_ids.tolist()) == spilled
        # id→slot and slot→id maps are mutual inverses on residents
        for i in oracle:
            assert store.gid_of[store.slot_arr[i]] == i

        clients = _write_rows(store, clients, mirror, uniq, tag=t)

    # Every materialized client's row survives arbitrary evict/restore
    # churn bit-for-bit (resident or in the spill buffer).
    for i in range(n):
        _check_row(store, clients, template, mirror, i)
    # ...and a final revisit restores each spilled row bit-exactly.
    for i in store.spilled_ids.tolist():
        clients, stats = store.ensure(clients, np.asarray([i]))
        assert stats["restores"] == 1
        _check_row(store, clients, template, mirror, i)
    return store


def check_dense_degeneration(zones, capacity, n=N_CLIENTS):
    """capacity ≥ the whole visited set ⇒ the store is just a dense
    plane over the visited ids: no evictions, no restores, every
    visited client stays resident."""
    store, clients, _ = _make_store(capacity, n=n)
    visited: set[int] = set()
    for zone in zones:
        zone = [int(i) % min(n, capacity) for i in zone]
        visited.update(zone)
        clients, _ = store.ensure(clients, np.asarray(zone))
    assert store.counters["evictions"] == 0
    assert store.counters["restores"] == 0
    assert set(store.resident_ids.tolist()) == visited
    assert store.spilled_ids.size == 0


# ------------------------------------------------------------------
# hypothesis properties + deterministic twins
# ------------------------------------------------------------------
ZONES = st.lists(
    st.lists(st.integers(0, N_CLIENTS - 1), min_size=1, max_size=6),
    min_size=1, max_size=14)


@hypothesis.given(zones=ZONES, capacity=st.integers(2, N_CLIENTS))
def test_lru_oracle_property(zones, capacity):
    check_lru_oracle(zones, capacity)


@pytest.mark.parametrize("seed", range(8))
def test_lru_oracle_sampled(seed):
    rng = np.random.default_rng(seed)
    zones = [rng.integers(0, N_CLIENTS, size=rng.integers(1, 7)).tolist()
             for _ in range(rng.integers(3, 15))]
    check_lru_oracle(zones, capacity=int(rng.integers(2, N_CLIENTS + 1)))


@hypothesis.given(zones=ZONES, capacity=st.integers(4, N_CLIENTS))
def test_dense_degeneration_property(zones, capacity):
    check_dense_degeneration(zones, capacity)


@pytest.mark.parametrize("seed", range(6))
def test_dense_degeneration_sampled(seed):
    rng = np.random.default_rng(seed)
    zones = [rng.integers(0, N_CLIENTS, size=rng.integers(1, 5)).tolist()
             for _ in range(rng.integers(2, 10))]
    check_dense_degeneration(zones, capacity=int(rng.integers(4, 13)))


def test_visit_order_is_eviction_order():
    """Visit 0..5 in order into a capacity-6 store, then force two
    evictions: the two least-recently-visited ids (0, 1) spill first."""
    store, clients, _ = _make_store(capacity=6)
    for i in range(6):
        clients, _ = store.ensure(clients, np.asarray([i]))
    clients, stats = store.ensure(clients, np.asarray([6, 7]))
    assert stats == {"hits": 0, "misses": 2, "evictions": 2, "restores": 0}
    assert set(store.spilled_ids.tolist()) == {0, 1}
    # Re-touching 2 protects it: next eviction takes 3.
    clients, _ = store.ensure(clients, np.asarray([2]))
    clients, stats = store.ensure(clients, np.asarray([8]))
    assert stats["evictions"] == 1
    assert 3 in store.spilled_ids.tolist()
    assert 2 in store.resident_ids.tolist()


def test_working_set_over_capacity_raises():
    store, clients, _ = _make_store(capacity=3)
    with pytest.raises(ValueError, match="exceeds store capacity"):
        store.ensure(clients, np.arange(4))
    # duplicates don't count against the working set
    clients, stats = store.ensure(clients, np.asarray([1, 1, 2, 2, 1]))
    assert stats == {"hits": 0, "misses": 2, "evictions": 0, "restores": 0}


def test_out_of_range_and_unreset_errors():
    store, clients, _ = _make_store(capacity=4)
    with pytest.raises(IndexError):
        store.ensure(clients, np.asarray([N_CLIENTS]))
    with pytest.raises(KeyError, match="not resident"):
        store.slots(np.asarray([5]))
    fresh = ClientStore(store.factory, 4)
    with pytest.raises(RuntimeError, match="reset"):
        fresh.ensure(clients, np.asarray([0]))
    with pytest.raises(ValueError, match="capacity"):
        ClientStore(store.factory, 0)


def test_state_dict_roundtrip_with_spill(tmp_path):
    """Checkpoint round-trip through npz: a fresh store restored from
    disk reproduces the mapping, LRU order, counters, spill rows, and
    re-materialized packed dataset rows exactly."""
    store, clients, template = _make_store(capacity=4)
    mirror: dict[int, np.float32] = {}
    for t, zone in enumerate([[0, 1, 2], [3, 4], [5, 0], [6, 7]]):
        clients, _ = store.ensure(clients, np.asarray(zone))
        clients = _write_rows(store, clients, mirror, zone, tag=t)
    assert store.spilled_ids.size > 0
    path = str(tmp_path / "store.npz")
    save_client_store(path, store)

    fresh, _, _ = _make_store(capacity=4)
    load_client_store(path, fresh)
    np.testing.assert_array_equal(fresh.gid_of, store.gid_of)
    np.testing.assert_array_equal(fresh.slot_arr, store.slot_arr)
    assert list(fresh.resident_ids) == list(store.resident_ids)
    np.testing.assert_array_equal(fresh.spilled_ids, store.spilled_ids)
    assert fresh.counters == store.counters
    for i in store.spilled_ids.tolist():
        for a, b in zip(fresh._spill[int(i)], store._spill[int(i)]):
            np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree_util.tree_leaves(fresh.data),
                    jax.tree_util.tree_leaves(store.data)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # wrong-capacity restore refuses
    wrong, _, _ = _make_store(capacity=5)
    with pytest.raises(ValueError, match="capacity"):
        load_client_store(path, wrong)


# ------------------------------------------------------------------
# async prefetch: LRU-invisible staging, oracle-exact counters
# ------------------------------------------------------------------
def check_prefetch_oracle(zones, capacity, n=N_CLIENTS):
    """Drive a prefetch-on store through the scan pipeline's shape —
    ensure the current zone, then stage the next zone behind it — and
    replay every step against (a) a prefetch-off twin fed the same
    visits and (b) an independent python staging-set oracle."""
    sp, cp, template = _make_store(capacity, n=n, prefetch=True)
    s0, c0, _ = _make_store(capacity, n=n)
    staged: set[int] = set()
    mirror_p: dict[int, np.float32] = {}
    mirror_0: dict[int, np.float32] = {}
    zones = [[int(i) % n for i in z] for z in zones]
    zones = [z for z in zones
             if len(dict.fromkeys(z)) <= capacity]  # refusals: LRU oracle
    for t, zone in enumerate(zones):
        uniq = list(dict.fromkeys(zone))
        miss = [i for i in uniq if sp.slot_arr[i] < 0]
        exp_hits = sum(1 for i in miss if i in staged)
        cp, stp = sp.ensure(cp, np.asarray(zone))
        c0, st0 = s0.ensure(c0, np.asarray(zone))
        # base stats equal the prefetch-off twin; prefetch stats match
        # the staging-set oracle (consumed rows were staged earlier)
        assert stp == {**st0, "prefetch_hits": exp_hits,
                       "prefetch_misses": len(miss) - exp_hits}
        staged -= set(miss)            # ensure() pops what it consumed
        assert set(sp._staging) == staged
        # the LRU never sees the staging buffer: identical bookkeeping
        assert list(sp.resident_ids) == list(s0.resident_ids)
        assert set(sp.spilled_ids.tolist()) \
            == set(s0.spilled_ids.tolist())
        cp = _write_rows(sp, cp, mirror_p, uniq, tag=t)
        c0 = _write_rows(s0, c0, mirror_0, uniq, tag=t)
        if t + 1 < len(zones):
            nxt = list(dict.fromkeys(zones[t + 1]))
            todo = [i for i in nxt
                    if sp.slot_arr[i] < 0 and i not in staged]
            assert sp.prefetch(np.asarray(zones[t + 1])) == len(todo)
            staged |= set(todo)
            sp._join_prefetch()
            assert set(sp._staging) == staged
    # staged draws come from the same pure factory as sync draws: the
    # packed dataset block and every client row are bit-identical
    for a, b in zip(jax.tree_util.tree_leaves(sp.data),
                    jax.tree_util.tree_leaves(s0.data)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(cp),
                    jax.tree_util.tree_leaves(c0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for i in range(n):
        _check_row(sp, cp, template, mirror_p, i)
    base = {k: sp.counters[k] for k in STORE_COUNTERS}
    assert base == {k: s0.counters[k] for k in STORE_COUNTERS}


@hypothesis.given(zones=ZONES, capacity=st.integers(2, N_CLIENTS))
def test_prefetch_oracle_property(zones, capacity):
    check_prefetch_oracle(zones, capacity)


@pytest.mark.parametrize("seed", range(6))
def test_prefetch_oracle_sampled(seed):
    rng = np.random.default_rng(seed)
    zones = [rng.integers(0, N_CLIENTS, size=rng.integers(1, 6)).tolist()
             for _ in range(rng.integers(3, 12))]
    check_prefetch_oracle(zones,
                          capacity=int(rng.integers(2, N_CLIENTS + 1)))


def test_prefetch_requires_flag_and_is_idempotent():
    """prefetch() on a store built without the flag is a hard no-op;
    with the flag, re-staging the same ids hands the worker nothing."""
    s0, c0, _ = _make_store(capacity=4)
    assert s0.prefetch(np.asarray([0, 1])) == 0
    assert "prefetch_hits" not in s0.counters
    sp, cp, _ = _make_store(capacity=4, prefetch=True)
    assert sp.prefetch(np.asarray([0, 1, 1])) == 2
    assert sp.prefetch(np.asarray([0, 1])) == 0   # already staged
    cp, stats = sp.ensure(cp, np.asarray([0, 1]))
    assert stats["prefetch_hits"] == 2 and sp._staging == {}
    assert sp.prefetch(np.asarray([0, 1])) == 0   # now resident


def test_counter_keys_stable():
    """The telemetry event names derive from STORE_COUNTERS (plus the
    PREFETCH_COUNTERS pair when staging is on) — pin the schema so
    dashboards don't silently lose a series."""
    assert STORE_COUNTERS == ("hits", "misses", "evictions", "restores")
    assert PREFETCH_COUNTERS == ("prefetch_hits", "prefetch_misses")
    store, _, _ = _make_store(capacity=3, prefetch=True)
    assert set(store.counters) == set(STORE_COUNTERS + PREFETCH_COUNTERS)
