"""Dry-run machinery tests: lower+compile on a small host-device mesh in a
SUBPROCESS (jax pins the device count at first init, so the 8-device test
must not contaminate the main test process)."""
import json
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import json, sys
    import repro.launch.dryrun as D
    import repro.launch.mesh as M
    import jax
    M.make_production_mesh = (
        lambda multi_pod=False: jax.make_mesh((2,2,2), ("pod","data","model"))
        if multi_pod else jax.make_mesh((2,4), ("data","model")))
    D.make_production_mesh = M.make_production_mesh
    import repro.configs.base as CB
    CB.INPUT_SHAPES["train_4k"] = CB.InputShape("train_4k", 256, 8, "train")
    CB.INPUT_SHAPES["prefill_32k"] = CB.InputShape(
        "prefill_32k", 512, 8, "prefill")
    CB.INPUT_SHAPES["decode_32k"] = CB.InputShape(
        "decode_32k", 1024, 8, "decode")
    CB.INPUT_SHAPES["long_500k"] = CB.InputShape(
        "long_500k", 4096, 1, "decode")
    out = {}
    for arch, shape, mp in json.loads(sys.argv[1]):
        rec = D.run_one(arch, shape, multi_pod=mp)
        out[f"{arch}|{shape}|{mp}"] = {
            "flops": rec["flops"],
            "coll": {k: v for k, v in rec["collectives"].items()
                     if k != "_counts"},
            "peak": rec["memory"].get("peak_memory_in_bytes", 0),
        }
    print("RESULT" + json.dumps(out))
""")


def _run(combos):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(combos)],
        capture_output=True, text=True, timeout=540, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_dense_train_and_decode_lower_on_mesh():
    out = _run([["tinyllama-1.1b", "train_4k", False],
                ["tinyllama-1.1b", "decode_32k", False]])
    tr = out["tinyllama-1.1b|train_4k|False"]
    assert tr["flops"] > 1e9
    assert "all-reduce" in tr["coll"]  # zone gradient reduction exists
    de = out["tinyllama-1.1b|decode_32k|False"]
    assert de["flops"] > 1e6


def test_moe_expert_parallel_lowers():
    out = _run([["qwen3-moe-30b-a3b", "train_4k", False]])
    rec = out["qwen3-moe-30b-a3b|train_4k|False"]
    # expert-parallel psum + ZeRO gathers must appear
    assert rec["coll"].get("all-reduce", 0) > 0
    assert rec["coll"].get("all-gather", 0) > 0


def test_multi_pod_mesh_shards_pod_axis():
    out = _run([["tinyllama-1.1b", "train_4k", True]])
    rec = out["tinyllama-1.1b|train_4k|True"]
    assert rec["flops"] > 0


def test_hybrid_long_context_decode_lowers():
    out = _run([["recurrentgemma-9b", "long_500k", True],
                ["gemma3-12b", "long_500k", False]])
    for k, rec in out.items():
        assert rec["flops"] > 0, k


def test_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes

    hlo = """
      %all-reduce.1 = f32[64,512]{1,0} all-reduce(%dot), channel_id=1
      %ag = bf16[8,128]{1,0} all-gather(%p0), dimensions={0}
      %fusion.2 = f32[2,2]{1,0} fusion(%all-reduce.1, %c), kind=kLoop
      %rs = (f32[16]{0}, f32[16]{0}) reduce-scatter(%a, %b), dims={0}
      %cp-start = bf16[4]{0} collective-permute-start(%x)
    """
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"] == 64 * 512 * 4
    assert out["all-gather"] == 8 * 128 * 2
    assert out["reduce-scatter"] == 2 * 16 * 4
    assert out["collective-permute"] == 4 * 2
    # the fusion operand mention must NOT be counted
    assert out["_counts"]["all-reduce"] == 1


def test_param_spec_rules():
    """Sharding rules: divisibility fallback + expected axes (no devices
    needed — specs are pure metadata)."""
    import numpy as np

    import jax
    from repro.configs import get_config
    from repro.launch.sharding import param_spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("qwen2-7b")
    # scan-stacked leaf: (repeats, H*hd, d)
    leaf = jax.ShapeDtypeStruct((28, 28 * 128, 3584), np.float32)
    spec = param_spec("layers/0/mix/wo", leaf, cfg, FakeMesh(), ("data",))
    assert spec == jax.sharding.PartitionSpec(None, "model", ("data",))
    # whisper vocab 51866 % 16 != 0 → replicate that dim
    wcfg = get_config("whisper-large-v3")
    emb = jax.ShapeDtypeStruct((51866, 1280), np.float32)
    spec = param_spec("embed", emb, wcfg, FakeMesh(), ("data",))
    assert spec[0] is None
