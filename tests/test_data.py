"""Data pipeline: synthetic generators + federated partitioners."""
import numpy as np

from repro.data import (
    dirichlet_split,
    make_image_dataset,
    make_synthetic_lr,
    pathological_split,
)
from repro.data.loader import (
    build_federated,
    build_federated_from_pairs,
    minibatch,
)


def test_image_dataset_learnable_structure():
    x, y = make_image_dataset(500, seed=0)
    assert x.shape == (500, 28, 28, 1) and y.shape == (500,)
    # class-conditional means must differ (prototype structure)
    m0 = x[y == 0].mean(axis=0)
    m1 = x[y == 1].mean(axis=0)
    assert np.abs(m0 - m1).mean() > 0.05


def test_pathological_split_two_labels():
    """Paper §5: each client holds exactly `labels_per_client` labels and
    allocation sizes vary."""
    _, y = make_image_dataset(4000, seed=1)
    parts = pathological_split(y, 20, labels_per_client=2, seed=0)
    assert len(parts) == 20
    sizes = []
    for idx in parts:
        labels = set(y[idx].tolist())
        assert len(labels) <= 2
        sizes.append(len(idx))
    assert max(sizes) > min(sizes)  # variable allocations


def test_dirichlet_split_covers_all_clients():
    _, y = make_image_dataset(2000, seed=2)
    parts = dirichlet_split(y, 10, alpha=0.3, seed=0)
    assert len(parts) == 10
    assert all(len(p) >= 8 for p in parts)


def test_synthetic_lr_generator():
    data = make_synthetic_lr(10, n_features=60, n_classes=10, seed=0)
    assert len(data) == 10
    for x, y in data:
        assert x.shape[1] == 60
        assert y.min() >= 0 and y.max() < 10
    # heterogeneity: per-client optimal weights differ → label dists differ
    h0 = np.bincount(data[0][1], minlength=10) / len(data[0][1])
    h1 = np.bincount(data[1][1], minlength=10) / len(data[1][1])
    assert np.abs(h0 - h1).sum() > 0.2


def test_build_federated_split_75_25():
    x, y = make_image_dataset(1000, seed=3)
    parts = pathological_split(y, 5, seed=1)
    fed = build_federated(x, y, parts, test_frac=0.25)
    assert fed.n_clients == 5
    for i in range(5):
        c = fed.client(i)
        total = c.n_train + c.n_test
        assert abs(c.n_test / total - 0.25) < 0.1


def test_minibatch_respects_mask():
    x, y = make_image_dataset(600, seed=4)
    parts = pathological_split(y, 6, seed=2)
    fed = build_federated(x, y, parts)
    rng = np.random.default_rng(0)
    xb, yb = minibatch(rng, fed, 2, 16)
    assert xb.shape[0] == 16
    # every sampled label must be one of the client's ≤2 labels
    valid = fed.mask_train[2].astype(bool)
    allowed = set(fed.y_train[2][valid].tolist())
    assert set(yb.tolist()) <= allowed


def test_build_from_pairs():
    data = make_synthetic_lr(4, seed=1)
    fed = build_federated_from_pairs(data)
    assert fed.n_clients == 4
    assert fed.x_train.shape[2] == 60
