"""Sparse neighbor-list graph backend: the O(n·k) control plane must be
bit-identical to the dense O(n²) oracle wherever the construction is
RNG-free — graphs, walks, zone schedules (incl. pricing), fleet plans —
and individually deterministic where it is not (link-dropout sampling,
a documented RNG-stream break between backends).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import markov
from repro.core.graph import (
    NeighborGraph,
    neighbor_graph_from_dense,
    pair_sq_dists,
    pairwise_sq_dists,
    patch_connected,
    patch_connected_lists,
    random_geometric_graph,
)
from repro.core.markov import RandomWalkServer
from repro.scenarios import (
    LinkConfig,
    LinkModel,
    MobilityConfig,
    Scenario,
    ScenarioConfig,
    get_scenario_config,
    range_graph,
    sparse_knn_graph,
    sparse_range_graph,
)


def _sparse_cfg(name: str, n: int, **kw) -> ScenarioConfig:
    return dataclasses.replace(get_scenario_config(name),
                               graph_backend="sparse", neighbor_k_max=n,
                               **kw)


def _check_invariants(g: NeighborGraph):
    """Packed-left, row-sorted, symmetric, self-loop-free."""
    deg = g.nbr_mask.sum(axis=1)
    adj = g.to_dense().adjacency
    assert not adj.diagonal().any()
    np.testing.assert_array_equal(adj, adj.T)
    for i in range(g.n):
        row = g.nbrs[i]
        d = int(deg[i])
        assert g.nbr_mask[i, :d].all() and not g.nbr_mask[i, d:].any()
        assert (np.diff(row[:d]) > 0).all()
        np.testing.assert_array_equal(
            g.nbr_d2[i, :d], pair_sq_dists(g.positions,
                                           np.full(d, i), row[:d]))


# ------------------------------------------------ distance formula pin --
def test_pair_formula_matches_matrix_formula():
    """The one distance expression: gathered pairs, the (n, n) matrix,
    and the (R, n, n) batch must produce identical floats — the
    foundation of every sparse≡dense pin below."""
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 1, (200, 2))
    d2 = pairwise_sq_dists(pos)
    i = rng.integers(0, 200, 5000)
    j = rng.integers(0, 200, 5000)
    keep = i != j
    np.testing.assert_array_equal(pair_sq_dists(pos, i[keep], j[keep]),
                                  d2[i[keep], j[keep]])
    np.testing.assert_array_equal(
        G.pairwise_sq_dists_batch(pos[None])[0], d2)


# ------------------------------------------------ graph construction ----
@pytest.mark.parametrize("seed", range(8))
def test_sparse_range_graph_matches_dense(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 150))
    pos = rng.uniform(0, 1, (n, 2))
    radio = float(rng.uniform(0.08, 0.45))
    dense = range_graph(pos, radio, 5)
    sparse = sparse_range_graph(pos, radio, 5, k_max=n)
    np.testing.assert_array_equal(sparse.to_dense().adjacency,
                                  dense.adjacency)
    _check_invariants(sparse)


@pytest.mark.parametrize("seed", range(8))
def test_sparse_knn_graph_matches_dense(seed):
    """random_geometric_graph's body (kNN + patch) for given positions."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(8, 150))
    pos = rng.uniform(0, 1, (n, 2))
    d2 = pairwise_sq_dists(pos)
    adj = patch_connected(G.knn_adjacency(d2, 5), d2)
    sparse = sparse_knn_graph(pos, 5, k_max=n)
    np.testing.assert_array_equal(sparse.to_dense().adjacency, adj)
    _check_invariants(sparse)


def test_neighbor_graph_dense_roundtrip_and_accessors():
    g = random_geometric_graph(60, 5, np.random.default_rng(3))
    ng = neighbor_graph_from_dense(g)
    _check_invariants(ng)
    assert ng.n == g.n and ng.n_edges == g.n_edges
    assert ng.is_connected() == g.is_connected()
    np.testing.assert_array_equal(ng.degree(), g.degree())
    for i in (0, 17, 59):
        np.testing.assert_array_equal(ng.neighbors(i), g.neighbors(i))
        np.testing.assert_array_equal(ng.neighborhood(i),
                                      g.neighborhood(i))
    np.testing.assert_array_equal(ng.to_dense().adjacency, g.adjacency)


def test_connectivity_and_patch_match_dense():
    """BFS-on-lists + the cross-component patch replay the dense lane's
    exact edge insertions on a clustered (disconnected) layout."""
    rng = np.random.default_rng(7)
    pos = np.concatenate([rng.uniform(0.0, 0.25, (20, 2)),
                          rng.uniform(0.75, 1.0, (20, 2)),
                          rng.uniform([0.0, 0.75], [0.25, 1.0], (15, 2))])
    d2 = pairwise_sq_dists(pos)
    adj = G.knn_adjacency(d2, 3)
    rows, cols = np.nonzero(adj)
    ng = G.neighbor_graph_from_pairs(
        len(pos), rows, cols, pair_sq_dists(pos, rows, cols), pos)
    assert ng.is_connected() == G.adjacency_connected(adj)
    assert not ng.is_connected()
    patched = patch_connected(adj.copy(), d2)
    nbrs, mask, nd2 = patch_connected_lists(
        ng.nbrs.copy(), ng.nbr_mask.copy(), ng.nbr_d2.copy(), pos)
    out = NeighborGraph(nbrs=nbrs, nbr_mask=mask, positions=pos,
                        nbr_d2=nd2)
    np.testing.assert_array_equal(out.to_dense().adjacency, patched)
    _check_invariants(out)


def test_k_max_caps_knn_union_hubs():
    """The static_regen lane honors neighbor_k_max too: symmetrized-kNN
    hub nodes are truncated to their nearest links, the degree floor is
    re-patched, and the graph stays connected."""
    pos = np.random.default_rng(21).uniform(0, 1, (400, 2))
    capped = sparse_knn_graph(pos, 5, k_max=7)
    free = sparse_knn_graph(pos, 5, k_max=400)
    _check_invariants(capped)
    assert capped.is_connected()
    assert capped.degree().min() >= 5
    assert capped.degree().max() < free.degree().max()


def test_k_max_caps_degree_but_keeps_graph_usable():
    """A tight k_max truncates to each node's nearest in-range links;
    the result stays symmetric, connected, and above the degree floor
    (patches may locally exceed the cap — it is a soft cap)."""
    pos = np.random.default_rng(11).uniform(0, 1, (300, 2))
    g = sparse_range_graph(pos, 0.25, 5, k_max=8)
    _check_invariants(g)
    assert g.is_connected()
    deg = g.degree()
    assert deg.min() >= 5
    dense_deg = range_graph(pos, 0.25, 5).degree()
    assert deg.max() < dense_deg.max()          # the cap actually bit


# ------------------------------------------------ random walk parity ----
@pytest.mark.parametrize("transition", ["degree", "metropolis"])
def test_sparse_walk_replays_dense_walk(transition):
    """step() on neighbor lists consumes the walker RNG exactly like the
    dense Generator.choice path and visits the same clients."""
    g = random_geometric_graph(80, 5, np.random.default_rng(2))
    ng = neighbor_graph_from_dense(g)
    wd = RandomWalkServer(transition=transition, seed=5)
    ws = RandomWalkServer(transition=transition, seed=5)
    wd.reset(g, start=3)
    ws.reset(ng, start=3)
    for _ in range(200):
        assert wd.step(g) == ws.step(ng)
    np.testing.assert_array_equal(wd.visit_counts, ws.visit_counts)
    # streams still aligned after 200 steps
    assert wd._rng.random() == ws._rng.random()


@pytest.mark.parametrize("transition", ["degree", "metropolis"])
def test_sparse_batched_walk_replays_dense(transition):
    g = random_geometric_graph(50, 5, np.random.default_rng(4))
    ng = neighbor_graph_from_dense(g)
    wd = RandomWalkServer(transition=transition, seed=8)
    ws = RandomWalkServer(transition=transition, seed=8)
    wd.reset(g, start=0)
    ws.reset(ng, start=0)
    np.testing.assert_array_equal(
        wd.walk_schedule_batched([g] * 60, advance_first=True),
        ws.walk_schedule_batched([ng] * 60, advance_first=True))


def test_sparse_transition_row_matches_dense():
    g = random_geometric_graph(40, 5, np.random.default_rng(9))
    ng = neighbor_graph_from_dense(g)
    for transition in ("degree", "metropolis"):
        wd = RandomWalkServer(transition=transition)
        ws = RandomWalkServer(transition=transition)
        for i in (0, 13, 39):
            np.testing.assert_array_equal(ws.transition_row(ng, i),
                                          wd.transition_row(g, i))


def _biased_pair(policy, seed, n=60):
    """Dense/sparse walker twins for a biased policy on one graph."""
    g = random_geometric_graph(n, 5, np.random.default_rng(2))
    ng = neighbor_graph_from_dense(g)
    out = []
    for _ in range(2):
        w = RandomWalkServer(transition="metropolis", seed=seed,
                             policy=policy, bias_gamma=1.5)
        if policy == "label_skew":
            w.set_label_weights(
                np.random.default_rng(42).uniform(0.5, 3.0, n))
        out.append(w)
    return g, ng, out[0], out[1]


@pytest.mark.parametrize("policy", sorted(markov.BIASED_POLICIES))
def test_sparse_biased_walk_replays_dense(policy):
    """Biased-policy step() on neighbor lists: same visits, same
    importance weights (exact floats — the shared ``_biased_row``
    scatter), same RNG stream, matching the dense Generator.choice
    path."""
    g, ng, wd, ws = _biased_pair(policy, seed=5)
    wd.reset(g, start=3)
    ws.reset(ng, start=3)
    for _ in range(200):
        assert wd.step(g) == ws.step(ng)
    np.testing.assert_array_equal(wd.visit_counts, ws.visit_counts)
    np.testing.assert_array_equal(np.asarray(wd.weight_history),
                                  np.asarray(ws.weight_history))
    assert wd._rng.random() == ws._rng.random()


@pytest.mark.parametrize("policy", sorted(markov.BIASED_POLICIES))
def test_sparse_biased_batched_walk_replays_dense(policy):
    """walk_schedule_batched under biased policies: bit-for-bit visit
    and weight sequences across backends (the compressed sparse CDF
    shares the dense CDF's float levels)."""
    g, ng, wd, ws = _biased_pair(policy, seed=8, n=50)
    wd.reset(g, start=0)
    ws.reset(ng, start=0)
    np.testing.assert_array_equal(
        wd.walk_schedule_batched([g] * 60, advance_first=True),
        ws.walk_schedule_batched([ng] * 60, advance_first=True))
    np.testing.assert_array_equal(np.asarray(wd.weight_history),
                                  np.asarray(ws.weight_history))
    np.testing.assert_array_equal(wd.walk_weights(60), ws.walk_weights(60))


@pytest.mark.parametrize("policy", sorted(markov.BIASED_POLICIES))
def test_sparse_biased_transition_row_matches_dense(policy):
    """Row i of the biased MH chain is bit-identical across backends at
    every walker state, and matches the full-matrix construction."""
    g, ng, wd, ws = _biased_pair(policy, seed=3, n=40)
    wd.reset(g, start=0)
    ws.reset(ng, start=0)
    for step in range(30):
        p = markov.biased_transition_matrix(g, wd.policy_weights(g.n))
        for i in (0, 13, 39, wd.position):
            dense_row = wd.transition_row(g, i)
            np.testing.assert_array_equal(ws.transition_row(ng, i),
                                          dense_row)
            np.testing.assert_allclose(dense_row, p[i], atol=1e-15)
        assert wd.step(g) == ws.step(ng)


# ------------------------------------------------ scenario schedules ----
SCENARIOS_RNG_FREE = ["static_regen", "random_waypoint", "gauss_markov",
                      "duty_cycle"]


@pytest.mark.parametrize("scenario", SCENARIOS_RNG_FREE)
def test_zone_schedule_sparse_equals_dense(scenario):
    """The acceptance pin: graphs → avail traces → walks → zones → keys
    → latency/energy columns, identical across backends, across chunk
    boundaries. (Dropout scenarios are excluded: per-edge sampling is
    the documented RNG-stream break.)"""
    n, rounds = 26, 22

    def build(backend):
        cfg = dataclasses.replace(get_scenario_config(scenario),
                                  graph_backend=backend,
                                  neighbor_k_max=n)
        sc = Scenario(n, cfg, seed=3)
        w = RandomWalkServer(seed=7)
        w.reset(sc.current())
        rng = np.random.default_rng(11)

        def price(graphs, clients, idx, mask):
            return sc.price_schedule(graphs, clients, idx, mask, 4096)

        s1 = markov.zone_schedule(sc, w, rounds, 6, rng, price=price)
        s2 = markov.zone_schedule(sc, w, rounds, 6, rng,
                                  start_round=rounds, price=price)
        return s1, s2

    for a, b in zip(build("dense"), build("sparse")):
        np.testing.assert_array_equal(a.idx, b.idx)
        np.testing.assert_array_equal(a.mask, b.mask)
        np.testing.assert_array_equal(a.n_i, b.n_i)
        np.testing.assert_array_equal(a.clients, b.clients)
        np.testing.assert_array_equal(a.active, b.active)
        np.testing.assert_array_equal(a.keys, b.keys)
        np.testing.assert_array_equal(a.latency_s, b.latency_s)
        np.testing.assert_array_equal(a.energy_j, b.energy_j)


@pytest.mark.parametrize("mode", ["roundrobin", "simultaneous"])
def test_fleet_schedule_sparse_equals_dense(mode):
    n, rounds, k_walkers = 24, 18, 3

    def build(backend):
        cfg = _sparse_cfg("duty_cycle", n) if backend == "sparse" else \
            dataclasses.replace(get_scenario_config("duty_cycle"))
        sc = Scenario(n, cfg, seed=2)
        ws = [RandomWalkServer(seed=50 + 10 * k)
              for k in range(k_walkers)]
        for w in ws:
            w.reset(sc.current())
        rng = np.random.default_rng(0)
        return markov.fleet_zone_schedule(sc, ws, rounds, 5, rng,
                                          mode=mode, sync_every=6)

    a, b = build("dense"), build("sparse")
    np.testing.assert_array_equal(a.idx, b.idx)
    np.testing.assert_array_equal(a.mask, b.mask)
    np.testing.assert_array_equal(a.clients, b.clients)
    np.testing.assert_array_equal(a.keys, b.keys)
    np.testing.assert_array_equal(a.sync, b.sync)


def test_positions_only_identical_across_backends():
    """positions_only consumers (base-station baselines) never touch
    connectivity, so the backends are trivially interchangeable."""
    for name in ("random_waypoint", "gauss_markov"):
        sd = Scenario(20, dataclasses.replace(
            get_scenario_config(name)), seed=1, positions_only=True)
        ss = Scenario(20, _sparse_cfg(name, 20), seed=1,
                      positions_only=True)
        for _ in range(10):
            sd.step()
            ss.step()
        np.testing.assert_array_equal(sd.positions, ss.positions)


# ------------------------------------------------ link dropout lane -----
def test_sparse_dropout_deterministic_subset_connected():
    """The sparse dropout stream: same seed → same survivors; survivors
    ⊆ base edges ∪ patch links; every round connected; eager step and
    batched rollout replay each other draw-for-draw."""
    n = 30
    cfg = _sparse_cfg("lossy_links", n)

    def run(batched):
        sc = Scenario(n, cfg, seed=4)
        graphs = sc.schedule(12, include_current=True, batched=batched)
        return graphs

    g1, g2 = run(True), run(False)
    for a, b in zip(g1, g2):
        np.testing.assert_array_equal(a.nbrs, b.nbrs)
        np.testing.assert_array_equal(a.nbr_mask, b.nbr_mask)
    base = Scenario(n, dataclasses.replace(cfg, links=LinkConfig()),
                    seed=4)
    base_graphs = base.schedule(12, include_current=True)
    for eff, mob in zip(g1, base_graphs):
        assert eff.is_connected()
        _check_invariants(eff)
        lost = mob.n_edges - eff.n_edges
        assert lost >= 0 or eff.n_edges - mob.n_edges <= n  # patch links


def test_sparse_dropout_respects_probabilities():
    """Statistically: far edges drop more often than near edges."""
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, 1, (60, 2))
    g = sparse_range_graph(pos, 0.5, 5, k_max=60)
    link = LinkModel(LinkConfig(enabled=True, dropout=True))
    ei, ej, d2 = g.undirected_edges()
    near = d2 < np.median(d2)
    survived = np.zeros(len(ei))
    for t in range(60):
        eff = link._apply_dropouts_sparse(g, np.random.default_rng(t))
        dense = eff.to_dense().adjacency
        survived += dense[ei, ej]
    assert survived[near].mean() > survived[~near].mean()


# ------------------------------------------------ end-to-end trainer ----
def test_trainer_trajectory_identical_across_backends():
    """RWSADMMTrainer on a sparse gauss_markov scenario reproduces the
    dense trainer's compiled-scan trajectory bit-for-bit (no dropout)."""
    import jax

    from repro.data import make_image_dataset, pathological_split
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data
    from repro.fl.rwsadmm_trainer import RWSADMMTrainer
    from repro.models.small import get_model

    imgs, labels = make_image_dataset(400, seed=0)
    parts = pathological_split(labels, 12, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))

    def run(backend):
        cfg = ScenarioConfig(
            name=f"t_{backend}",
            mobility=MobilityConfig(model="gauss_markov"),
            graph_backend=backend, neighbor_k_max=12)
        tr = RWSADMMTrainer(model, data, zone_size=4, batch_size=16,
                            solver="closed_form", scenario=cfg, seed=0)
        rng = np.random.default_rng(0)
        state = tr.init_state(jax.random.PRNGKey(0))
        sched = tr.schedule(10, rng)
        state, stacked = tr.run_chunk(state, sched, engine="scan")
        return np.asarray(stacked["train_loss"]), state

    losses_d, st_d = run("dense")
    losses_s, st_s = run("sparse")
    np.testing.assert_array_equal(losses_d, losses_s)
    import jax

    for a, b in zip(jax.tree_util.tree_leaves(st_d.clients),
                    jax.tree_util.tree_leaves(st_s.clients)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="graph_backend"):
        Scenario(10, dataclasses.replace(
            get_scenario_config("static_regen"), graph_backend="csr"))


def test_cell_list_guard_rejects_effectively_dense_search():
    """A radio range far too large for n must fail loudly, not OOM."""
    pos = np.random.default_rng(0).uniform(0, 1, (4000, 2))
    from repro.scenarios.mobility import _CellGrid

    with pytest.raises(ValueError, match="candidate pairs"):
        _CellGrid(pos, 0.9).candidate_pairs(max_pairs=100_000)
