"""Scenario subsystem: mobility models, wireless link layer, churn,
comm pricing, registry, and trainer wiring (src/repro/scenarios/)."""
import numpy as np
import pytest

from repro.core.graph import DynamicGraph
from repro.scenarios import (
    ChurnConfig,
    LinkConfig,
    MobilityConfig,
    Scenario,
    ScenarioConfig,
    available_scenarios,
    build_scenario,
    get_scenario_config,
    range_graph,
    register_scenario,
)
from repro.scenarios.churn import ChurnModel
from repro.scenarios.links import LinkModel
from repro.scenarios.mobility import build_mobility

N = 20
ROUNDS = 25


# ----------------------------------------------------------- mobility ---
def test_static_regen_bit_identical_to_dynamic_graph():
    """Acceptance bar: scenario='static_regen' replays DynamicGraph's
    draw sequence exactly (graphs, positions, regen epochs)."""
    scn = build_scenario(None, 15, seed=3, min_degree=4, regen_every=5)
    dg = DynamicGraph(15, min_degree=4, regen_every=5, seed=3)
    gs = scn.schedule(22, include_current=True)
    gd = dg.schedule(22, include_current=True)
    for a, b in zip(gs, gd):
        np.testing.assert_array_equal(a.adjacency, b.adjacency)
        np.testing.assert_array_equal(a.positions, b.positions)
    assert scn.n_regens == dg.n_regens == 4


@pytest.mark.parametrize("model", ["random_waypoint", "gauss_markov"])
def test_smooth_mobility_bounded_and_connected(model):
    cfg = MobilityConfig(model=model)
    mob = build_mobility(N, cfg)
    rng = np.random.default_rng(0)
    g = mob.reset(rng)
    prev = g.positions.copy()
    # generous bound: waypoint ≤ speed_max, gauss-markov ≈ |v| + 3σ
    step_bound = max(cfg.speed_max,
                     cfg.mean_speed + 4 * cfg.sigma_speed) + 1e-9
    for _ in range(ROUNDS):
        g = mob.step(rng)
        assert (g.positions >= 0).all() and (g.positions <= 1).all()
        moved = np.linalg.norm(g.positions - prev, axis=1)
        assert moved.max() <= 2 * step_bound   # 2x: boundary reflection
        assert g.is_connected()
        assert (g.degree() >= min(cfg.min_degree, N - 1)).all()
        prev = g.positions.copy()


def test_random_waypoint_moves_toward_waypoint():
    mob = build_mobility(5, MobilityConfig(model="random_waypoint",
                                           speed_min=0.05, speed_max=0.05))
    rng = np.random.default_rng(1)
    mob.reset(rng)
    before = np.linalg.norm(mob.waypoint - mob.pos, axis=1)
    mob.step(rng)
    after = np.linalg.norm(mob.waypoint - mob.pos, axis=1)
    # distance shrinks for clients that haven't redrawn their waypoint
    same = before > 0.05
    assert (after[same] < before[same] + 1e-12).all()


def test_range_graph_properties():
    rng = np.random.default_rng(2)
    pos = rng.uniform(size=(N, 2))
    g = range_graph(pos, 0.3, 5)
    assert g.is_connected()
    assert (g.degree() >= 5).all()
    # all in-range pairs are linked
    d = np.linalg.norm(pos[:, None] - pos[None], axis=2)
    in_range = (d <= 0.3) & ~np.eye(N, dtype=bool)
    assert (g.adjacency[in_range]).all()


def test_unknown_mobility_model_raises():
    with pytest.raises(ValueError, match="unknown mobility"):
        build_mobility(5, MobilityConfig(model="teleport"))


# ---------------------------------------------------------- link layer ---
def test_link_success_probability_monotone_and_bounded():
    lm = LinkModel(LinkConfig(enabled=True))
    d = np.linspace(0.0, 2.0, 300)
    p = lm.success_probability(d)
    assert (p >= lm.cfg.min_success - 1e-12).all() and (p <= 1.0).all()
    assert (np.diff(p) <= 1e-12).all()          # decreasing in distance


def test_link_power_form_matches_logistic_margin():
    """success_probability_sq's algebraic form == the documented
    logistic-of-margin formula."""
    c = LinkConfig(enabled=True)
    lm = LinkModel(c)
    d = np.linspace(0.001, 1.5, 100)
    pl = c.ref_loss_db + 10 * c.path_loss_exp * np.log10(
        np.maximum(d, c.ref_distance) / c.ref_distance)
    margin = c.tx_power_dbm - c.sensitivity_dbm - pl
    ref = np.clip(1 / (1 + np.exp(-margin / c.shadowing_db)),
                  c.min_success, 1.0)
    np.testing.assert_allclose(lm.success_probability(d), ref, rtol=1e-10)


def test_link_dropouts_subset_and_connected():
    scn = Scenario(N, "lossy_links", seed=0)
    base_extra = 0
    for _ in range(20):
        g = scn.step()
        base = scn._base
        # dropped graph ⊆ base graph ∪ connectivity patch
        extra = g.adjacency & ~base.adjacency
        base_extra += int(extra.sum())
        assert g.is_connected()
        assert (g.positions == base.positions).all()
    # patching may add a few edges, but dropouts dominate
    assert base_extra < 20 * N


def test_link_matrix_zero_off_edges():
    scn = Scenario(N, "lossy_links", seed=1)
    g = scn.current()
    p = scn.link.link_matrix(g)
    assert (p[~g.adjacency] == 0).all()
    assert (p[g.adjacency] > 0).all()
    np.testing.assert_allclose(p, p.T)


# --------------------------------------------------------------- churn ---
def test_churn_duty_cycle_fraction():
    cfg = ChurnConfig(enabled=True, duty_cycle=0.6, period=10)
    cm = ChurnModel(500, cfg)
    rng = np.random.default_rng(0)
    avail = cm.reset(rng)
    fracs = [avail.mean()]
    for r in range(1, 40):
        fracs.append(cm.step(r, rng).mean())
    # phases are uniform, so ~duty_cycle of clients are awake each round
    assert abs(np.mean(fracs) - 0.6) < 0.05


def test_churn_stragglers_miss_rounds():
    cfg = ChurnConfig(enabled=True, duty_cycle=1.0, period=10,
                      straggler_frac=0.5, straggler_p=1.0)
    cm = ChurnModel(100, cfg)
    rng = np.random.default_rng(0)
    avail = cm.reset(rng)
    assert cm.stragglers.sum() == 50
    assert (~avail[cm.stragglers]).all()       # p=1: all miss
    assert avail[~cm.stragglers].all()


def test_zone_planning_respects_availability():
    from repro.core import markov

    scn = Scenario(N, "duty_cycle", seed=0)
    rng = np.random.default_rng(0)
    g = scn.current()
    avail = scn.availability()
    offline = np.flatnonzero(~avail)
    assert len(offline) > 0
    i_k = int(offline[0])   # even an offline visited client participates
    idx, mask, n_i = markov.plan_zone_round(g, i_k, 8, rng, avail=avail)
    live = idx[mask > 0]
    assert i_k in live
    assert all(avail[c] or c == i_k for c in live)


# ------------------------------------------------------------- pricing ---
def test_price_round_matches_price_schedule():
    scn = Scenario(N, "lossy_links", seed=0)
    graphs = [scn.current()] + [scn.step() for _ in range(4)]
    rng = np.random.default_rng(0)
    from repro.core import markov

    clients = np.asarray([1, 4, 7, 2, 9])
    idx = np.zeros((5, 6), np.int32)
    mask = np.zeros((5, 6), np.float32)
    for k in range(5):
        idx[k], mask[k], _ = markov.plan_zone_round(
            graphs[k], int(clients[k]), 6, rng)
    lat_b, en_b = scn.price_schedule(graphs, clients, idx, mask, 10_000)
    for k in range(5):
        lat, en = scn.price_round(graphs[k], int(clients[k]), idx[k],
                                  mask[k], 10_000)
        assert lat == lat_b[k] and en == en_b[k]   # one code path, exact


def test_price_solo_zone_is_free():
    scn = Scenario(N, "static_regen", seed=0)
    g = scn.current()
    lat, en = scn.price_round(g, 3, np.asarray([3], np.int32),
                              np.ones(1, np.float32), 10_000)
    assert lat == 0.0 and en == 0.0


def test_price_scales_with_payload_and_links():
    lossless = Scenario(N, "static_regen", seed=0)
    lossy = Scenario(N, "lossy_links", seed=0)
    g = lossless.current()
    idx = np.asarray([3, 5, 8, 11], np.int32)
    mask = np.ones(4, np.float32)
    l1, e1 = lossless.price_round(g, 3, idx, mask, 10_000)
    l2, e2 = lossless.price_round(g, 3, idx, mask, 20_000)
    assert l2 > l1 and e2 > e1
    # retransmissions make lossy links strictly more expensive
    l3, e3 = lossy.price_round(lossy.current(), 3, idx, mask, 10_000)
    assert l3 > 0 and e3 > 0


# ---------------------------------------------------- registry + wiring ---
def test_registry_roundtrip():
    names = available_scenarios()
    assert {"static_regen", "random_waypoint", "gauss_markov",
            "lossy_links", "duty_cycle", "field_trial"} <= set(names)
    cfg = get_scenario_config("field_trial")
    assert cfg.links.enabled and cfg.churn.enabled
    custom = register_scenario(ScenarioConfig(
        name="test_custom", links=LinkConfig(enabled=True)))
    assert get_scenario_config("test_custom") is custom
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario_config("no_such_scenario")


def test_scenario_layer_independence():
    """Toggling churn must not perturb the mobility stream (separate
    RNG streams per layer)."""
    a = Scenario(N, "random_waypoint", seed=0)
    b = Scenario(N, ScenarioConfig(
        name="rwp+churn",
        mobility=MobilityConfig(model="random_waypoint"),
        churn=ChurnConfig(enabled=True)), seed=0)
    for _ in range(10):
        ga, gb = a.step(), b.step()
        np.testing.assert_array_equal(ga.positions, gb.positions)
        np.testing.assert_array_equal(ga.adjacency, gb.adjacency)


def test_baseline_trainer_scenario_wiring():
    """FedAvg-family selection is churn-aware and rounds carry wireless
    costs when a scenario is attached."""
    import jax

    from repro.baselines import FedAvgTrainer
    from repro.data import make_image_dataset, pathological_split
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data
    from repro.models.small import get_model

    imgs, labels = make_image_dataset(200, seed=0)
    parts = pathological_split(labels, 10, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    tr = FedAvgTrainer(get_model("mlr", (28, 28, 1)), data,
                       clients_per_round=4)
    tr.attach_scenario("duty_cycle", seed=0)
    state = tr.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for r in range(3):
        state, m = tr.round(state, r, rng)
        assert "latency_s" in m and "energy_j" in m
        assert m["latency_s"] > 0
    sel = tr.select_clients(3, rng, 4)
    avail = tr.scenario.availability()   # select_clients stepped churn
    assert all(avail[c] for c in sel)
