"""Property-based tests for the scenario invariants the theory leans on
(Assumption 3.1 irreducibility, App. D.2 degree floor, zone
non-emptiness, Metropolis stochasticity) — over *sampled* environments,
not just the handful of fixed seeds the example tests use.

Runs under hypothesis when installed (``pip install -r
requirements-dev.txt``; CI's property-tests job sets
``HYPOTHESIS_PROFILE=smoke`` to cap examples). Without hypothesis the
``@given`` tests skip via ``_hypothesis_compat`` — the deterministic
``test_*_sampled`` twins below still exercise every invariant over a
seed sweep, so minimal environments keep real coverage.
"""
import os

import numpy as np
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st

from repro.core import markov
from repro.core.graph import pairwise_sq_dists, patch_connected
from repro.scenarios import (
    ChurnConfig,
    LinkConfig,
    MobilityConfig,
    Scenario,
    ScenarioConfig,
    build_mobility,
    range_graph,
)

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "smoke", max_examples=20, deadline=None,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    hypothesis.settings.register_profile("default", deadline=None)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "default"))

MODELS = ("static_regen", "random_waypoint", "gauss_markov")


def _mobility_cfg(model: str, radio_range: float,
                  min_degree: int) -> MobilityConfig:
    return MobilityConfig(model=model, radio_range=radio_range,
                          min_degree=min_degree)


def _rollout_graphs(model, n, rounds, seed, radio_range=0.3, min_degree=4):
    mob = build_mobility(n, _mobility_cfg(model, radio_range, min_degree))
    rng = np.random.default_rng(seed)
    first = mob.reset(rng)
    return [first] + mob.rollout(rounds, rng)


# ----------------------------------------------- positions stay bounded ---
def check_positions_in_bounds(model, n, rounds, seed):
    for g in _rollout_graphs(model, n, rounds, seed):
        assert (g.positions >= 0.0).all() and (g.positions <= 1.0).all()


@hypothesis.given(model=st.sampled_from(MODELS),
                  n=st.integers(min_value=5, max_value=25),
                  rounds=st.integers(min_value=1, max_value=25),
                  seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_rollout_positions_in_bounds(model, n, rounds, seed):
    """Rolled-out positions stay in the unit square for every model."""
    check_positions_in_bounds(model, n, rounds, seed)


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("seed", range(4))
def test_rollout_positions_in_bounds_sampled(model, seed):
    check_positions_in_bounds(model, 12, 15, seed)


# --------------------------------------- graphs connected, degree floor ---
def check_graphs_connected_min_degree(model, n, rounds, seed,
                                      radio_range, min_degree):
    k = min(min_degree, n - 1)
    for g in _rollout_graphs(model, n, rounds, seed,
                             radio_range=radio_range,
                             min_degree=min_degree):
        assert g.is_connected()
        assert (g.degree() >= k).all()


@hypothesis.given(model=st.sampled_from(MODELS),
                  n=st.integers(min_value=4, max_value=22),
                  rounds=st.integers(min_value=1, max_value=15),
                  seed=st.integers(min_value=0, max_value=2**31 - 1),
                  radio_range=st.floats(min_value=0.05, max_value=0.9),
                  min_degree=st.integers(min_value=1, max_value=8))
def test_rollout_graphs_connected_with_degree_floor(model, n, rounds, seed,
                                                    radio_range, min_degree):
    """Every patched graph is connected (Assumption 3.1) with the
    min-degree floor satisfied (App. D.2), whatever the radio range."""
    check_graphs_connected_min_degree(model, n, rounds, seed,
                                      radio_range, min_degree)


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("seed,radio_range,min_degree",
                         [(0, 0.05, 5), (1, 0.35, 3), (2, 0.8, 1),
                          (3, 0.15, 8)])
def test_rollout_graphs_connected_sampled(model, seed, radio_range,
                                          min_degree):
    check_graphs_connected_min_degree(model, 14, 10, seed,
                                      radio_range, min_degree)


def check_dropout_graphs_connected(n, rounds, seed, sensitivity_dbm):
    cfg = ScenarioConfig(
        name="prop",
        mobility=MobilityConfig(model="random_waypoint"),
        links=LinkConfig(enabled=True, sensitivity_dbm=sensitivity_dbm),
        rollout_chunk=7,
    )
    scn = Scenario(n, cfg, seed=seed)
    for g in scn.schedule(rounds, include_current=True):
        assert g.is_connected()


@hypothesis.given(n=st.integers(min_value=4, max_value=20),
                  rounds=st.integers(min_value=1, max_value=20),
                  seed=st.integers(min_value=0, max_value=2**31 - 1),
                  sensitivity_dbm=st.floats(min_value=-90.0,
                                            max_value=-50.0))
def test_dropout_patched_graphs_stay_connected(n, rounds, seed,
                                               sensitivity_dbm):
    """However lossy the links, every post-dropout re-patched graph is
    connected — the walk chain never strands."""
    check_dropout_graphs_connected(n, rounds, seed, sensitivity_dbm)


@pytest.mark.parametrize("seed,sens", [(0, -85.0), (1, -65.0), (2, -50.0)])
def test_dropout_patched_graphs_stay_connected_sampled(seed, sens):
    check_dropout_graphs_connected(15, 12, seed, sens)


# --------------------------------------------- zones never churn empty ---
def check_zone_nonempty(n, seed, avail_bits, zone_size):
    rng = np.random.default_rng(seed)
    g = range_graph(rng.uniform(size=(n, 2)), 0.3, 4)
    avail = np.array([(avail_bits >> i) & 1 == 1 for i in range(n)])
    for i_k in range(n):
        idx, mask, n_i = markov.plan_zone_round(
            g, i_k, zone_size, rng, avail=avail)
        live = idx[mask > 0]
        assert len(live) >= 1          # churn can never empty the zone
        assert i_k in live             # the visited client always stays
        assert n_i >= 1
        # everyone else in the zone really was available
        assert all(avail[c] or c == i_k for c in live)


@hypothesis.given(n=st.integers(min_value=3, max_value=20),
                  seed=st.integers(min_value=0, max_value=2**31 - 1),
                  avail_bits=st.integers(min_value=0, max_value=2**20 - 1),
                  zone_size=st.integers(min_value=1, max_value=10))
def test_churned_zone_never_below_one_client(n, seed, avail_bits,
                                             zone_size):
    """For ANY availability mask — including all-offline — the planned
    zone keeps at least the visited client."""
    check_zone_nonempty(n, seed, avail_bits, zone_size)


@pytest.mark.parametrize("seed,avail_bits", [(0, 0), (1, 0b1010101010),
                                             (2, 2**20 - 1), (3, 1)])
def test_churned_zone_never_below_one_client_sampled(seed, avail_bits):
    check_zone_nonempty(12, seed, avail_bits, 6)


# ------------------------------------------- Metropolis stochasticity ---
def check_metropolis_stochastic(graphs):
    for g in graphs:
        p = markov.metropolis_transition_matrix(g)
        assert (p >= -1e-12).all()
        np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-9)
        # off-diagonal support == graph edges (irreducible on connected g)
        off = p.copy()
        np.fill_diagonal(off, 0.0)
        assert ((off > 0) == g.adjacency).all()


@hypothesis.given(model=st.sampled_from(MODELS),
                  n=st.integers(min_value=4, max_value=20),
                  rounds=st.integers(min_value=1, max_value=10),
                  seed=st.integers(min_value=0, max_value=2**31 - 1),
                  radio_range=st.floats(min_value=0.05, max_value=0.9))
def test_metropolis_rows_stochastic_on_sampled_graphs(model, n, rounds,
                                                      seed, radio_range):
    """Metropolis rows are a probability distribution on every graph
    the rollout can produce (uniform stationary walk stays well-posed)."""
    check_metropolis_stochastic(
        _rollout_graphs(model, n, rounds, seed, radio_range=radio_range))


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("seed", range(3))
def test_metropolis_rows_stochastic_sampled(model, seed):
    check_metropolis_stochastic(_rollout_graphs(model, 13, 8, seed))


# ------------------------------------------------ patcher postcondition ---
def check_patch_connected(n, seed):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(size=(n, 2))
    d2 = pairwise_sq_dists(pos)
    # arbitrary sparse adjacency, possibly fully disconnected
    adj = rng.uniform(size=(n, n)) < 0.08
    adj = adj | adj.T
    np.fill_diagonal(adj, False)
    patched = patch_connected(adj.copy(), d2)
    from repro.core.graph import adjacency_connected

    assert adjacency_connected(patched)
    assert (patched & ~adj).sum() >= 0      # only ever adds edges
    assert (adj & ~patched).sum() == 0


@hypothesis.given(n=st.integers(min_value=2, max_value=30),
                  seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_patch_connected_always_connects(n, seed):
    """patch_connected terminates and connects ANY adjacency, adding
    edges only."""
    check_patch_connected(n, seed)


@pytest.mark.parametrize("seed", range(5))
def test_patch_connected_always_connects_sampled(seed):
    check_patch_connected(16, seed)


# ----------------------------------------------- churn mask invariants ---
def check_churn_fraction(n, seed, duty_cycle, period, rounds):
    from repro.scenarios.churn import ChurnModel

    cm = ChurnModel(n, ChurnConfig(enabled=True, duty_cycle=duty_cycle,
                                   period=period))
    rng = np.random.default_rng(seed)
    cm.reset(rng)
    block = cm.rollout(1, rounds, rng)
    assert block.shape == (rounds, n)
    assert block.dtype == bool
    # duty cycling alone (no stragglers) wakes each client for exactly
    # ceil(duty_cycle * period) of every `period` consecutive rounds
    if rounds >= period:
        per_client = block[:period].sum(axis=0)
        # same comparison the model applies, over one full residue cycle
        expect = int((np.arange(period) < duty_cycle * period).sum())
        assert (per_client == expect).all()


@hypothesis.given(n=st.integers(min_value=1, max_value=40),
                  seed=st.integers(min_value=0, max_value=2**31 - 1),
                  duty_cycle=st.floats(min_value=0.05, max_value=1.0),
                  period=st.integers(min_value=1, max_value=30),
                  rounds=st.integers(min_value=1, max_value=60))
def test_churn_rollout_duty_cycle_exact(n, seed, duty_cycle, period,
                                        rounds):
    """Batched churn masks satisfy the duty-cycle contract exactly over
    any full period window."""
    check_churn_fraction(n, seed, duty_cycle, period, rounds)


@pytest.mark.parametrize("seed,duty,period", [(0, 0.6, 10), (1, 0.25, 4),
                                              (2, 1.0, 7)])
def test_churn_rollout_duty_cycle_sampled(seed, duty, period):
    check_churn_fraction(20, seed, duty, period, 2 * period)
