"""End-to-end behaviour tests for the whole system (paper claims level).

These validate the three paper headlines on offline data:
  1. RWSADMM converges fast and reaches high personalized accuracy under
     pathological non-IID (Fig. 2 / Table 1 directionally),
  2. it beats the non-personalized benchmark (FedAvg) decisively,
  3. its per-round communication is O(1) in the client count (§4).
Plus: hypothesis property tests on system invariants.
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st

from repro.baselines import FedAvgTrainer
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model


@pytest.fixture(scope="module")
def fed_setup():
    imgs, labels = make_image_dataset(1500, seed=0)
    parts = pathological_split(labels, 10, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))
    return data, model


def test_rwsadmm_beats_fedavg_under_non_iid(fed_setup):
    data, model = fed_setup
    rw = RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5),
        zone_size=6, batch_size=32)
    fa = FedAvgTrainer(model, data, clients_per_round=5)
    res_rw = run_simulation(rw, rounds=100, eval_every=100, seed=0)
    res_fa = run_simulation(fa, rounds=100, eval_every=100, seed=0)
    assert res_rw.final["acc_personalized"] > res_fa.final["acc_global"]
    assert res_rw.final["acc_personalized"] > 0.8


def test_comm_per_round_independent_of_n():
    accounts = []
    for n in (10, 40):
        imgs, labels = make_image_dataset(600, seed=1)
        parts = pathological_split(labels, n, seed=1)
        data = to_device_data(build_federated(imgs, labels, parts))
        model = get_model("mlr", (28, 28, 1))
        tr = RWSADMMTrainer(model, data, RWSADMMHparams(beta=1.0),
                            zone_size=4)
        accounts.append(tr.comm_bytes_per_round(4))
    assert accounts[0] == accounts[1]  # O(1): same zone ⇒ same bytes


def test_server_token_is_deployable_checkpoint(fed_setup, tmp_path):
    """The y token round-trips through the checkpoint layer and evaluates
    identically — the 'tactical vehicle hands the model over' path."""
    from repro.checkpoint import load_pytree, save_pytree

    data, model = fed_setup
    tr = RWSADMMTrainer(model, data, RWSADMMHparams(beta=1.0),
                        zone_size=4, batch_size=32)
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    for r in range(30):
        state, _ = tr.round(state, r, rng)
    path = str(tmp_path / "ckpt_30.npz")
    save_pytree(path, state.server.y)
    restored = load_pytree(path, state.server.y)
    import jax.numpy as jnp

    a1, _ = tr.eval_shared(state.server.y, jnp.arange(tr.n_clients))
    a2, _ = tr.eval_shared(restored, jnp.arange(tr.n_clients))
    np.testing.assert_allclose(a1, a2)


# ------------------------------------------------------ hypothesis --------
@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    n=st.integers(min_value=4, max_value=40),
    deg=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_graph_always_valid(n, deg, seed):
    """Invariant: any generated client graph is connected, symmetric, and
    meets the min-degree requirement (Assumption 3.1 needs irreducible)."""
    from repro.core.graph import random_geometric_graph

    g = random_geometric_graph(n, min_degree=deg,
                               rng=np.random.default_rng(seed))
    assert g.is_connected()
    assert (g.adjacency == g.adjacency.T).all()
    assert (g.degree() >= min(deg, n - 1)).all()


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    n_clients=st.integers(min_value=2, max_value=12),
    lpc=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_partition_label_budget(n_clients, lpc, seed):
    """Invariant: pathological split never exceeds labels_per_client."""
    from repro.data import pathological_split

    labels = np.random.default_rng(seed).integers(0, 10, 400).astype(
        np.int32)
    parts = pathological_split(labels, n_clients, labels_per_client=lpc,
                               seed=seed)
    for idx in parts:
        assert len(set(labels[idx].tolist())) <= lpc
        assert len(idx) > 0


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    beta=st.floats(min_value=0.5, max_value=50.0),
    kappa=st.floats(min_value=0.0, max_value=0.5),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_zone_round_preserves_finiteness(beta, kappa, seed):
    """Invariant: one zone round maps finite states to finite states for
    any admissible hyperparameters."""
    from repro.core import rwsadmm, tree

    hp = RWSADMMHparams(beta=beta, kappa=kappa, epsilon=1e-5)
    key = jax.random.PRNGKey(seed)
    template = {"w": jax.random.normal(key, (16,))}
    client, server = rwsadmm.init_states(template, hp, n_clients=3)
    grads = jax.tree_util.tree_map(
        lambda l: jax.random.normal(key, l.shape), client.x)
    new_clients, y = rwsadmm.zone_round(client, server.y, grads, hp,
                                        kappa, n_total=5)
    assert not bool(tree.any_nan(new_clients.x))
    assert not bool(tree.any_nan(y))
