"""Lazy client plane ≡ dense plane, pinned bit-for-bit.

The bounded LRU store (``repro.fl.client_store``) promises that a
trainer built on a :class:`~repro.data.loader.ClientDataFactory`
reproduces the dense ``(n, …)`` run exactly: identical init rows,
identical gather/scatter arithmetic on identical values, exact float32
host↔device round-trips on evict/restore. These tests pin that promise
across the eager and scan engines, dense and sparse graph backends, the
single walker and the K=3 fleet — plus a mid-run checkpoint round-trip
with spilled clients, and regression pins on the dense-plane eval path
the refactor touched.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    load_client_store,
    load_pytree,
    save_client_store,
    save_pytree,
)
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import (
    factory_from_federated,
    make_image_dataset,
    pathological_split,
)
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.fleet_trainer import FleetRWSADMMTrainer
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model
from repro.scenarios import get_scenario_config

N = 8


@pytest.fixture(scope="module")
def fed():
    imgs, labels = make_image_dataset(400, seed=0)
    parts = pathological_split(labels, N, seed=0)
    f = build_federated(imgs, labels, parts)
    model = get_model("mlr", (28, 28, 1))
    return to_device_data(f), factory_from_federated(f), model


def _scenario(backend):
    return dataclasses.replace(get_scenario_config("lossy_links"),
                               graph_backend=backend, neighbor_k_max=8)


def _make(fed, *, lazy, fleet=0, backend="dense", capacity=8,
          prefetch=False):
    dense, factory, model = fed
    data = factory if lazy else dense
    kw = dict(zone_size=4, batch_size=16, solver="closed_form",
              scenario=_scenario(backend), seed=0)
    if lazy:
        kw["store_capacity"] = capacity
        kw["prefetch"] = prefetch
    if fleet:
        return FleetRWSADMMTrainer(model, data, RWSADMMHparams(beta=10.0),
                                   n_walkers=fleet, sync_every=3, **kw)
    return RWSADMMTrainer(model, data, RWSADMMHparams(beta=10.0), **kw)


def _run(tr, *, engine, rounds=8):
    return run_simulation(tr, rounds=rounds, eval_every=4, seed=0,
                          engine=engine)


def _materialize_all(tr, state):
    """Reassemble the lazy run's client rows into dense (n, …) order
    from resident slots + the spill buffer + the init template."""
    clients = jax.device_get(tr._state_clients(state))
    leaves, treedef = jax.tree_util.tree_flatten(clients)
    tmpl = [np.asarray(l)
            for l in jax.tree_util.tree_leaves(tr.store._template)]
    rows = []
    for i in range(tr.n_clients):
        s = int(tr.store.slot_arr[i])
        if s >= 0:
            rows.append([np.asarray(leaf[s]) for leaf in leaves])
        elif i in tr.store._spill:
            rows.append([np.asarray(r) for r in tr.store._spill[i]])
        else:
            rows.append(tmpl)
    stacked = [np.stack([r[j] for r in rows]) for j in range(len(leaves))]
    return jax.tree_util.tree_unflatten(treedef, stacked)


# ------------------------------------------------------------------
# bit-identity pins
# ------------------------------------------------------------------
@pytest.mark.parametrize("fleet", [0, 3])
def test_eager_lazy_matches_dense_with_evictions(fed, fleet):
    """Eager engine, capacity 5 < n: the run churns through evictions
    and restores, and every per-round metric still matches the dense
    plane exactly (same draws, same floats)."""
    rd = _run(_make(fed, lazy=False, fleet=fleet), engine="eager")
    tl = _make(fed, lazy=True, fleet=fleet, capacity=5)
    rl = _run(tl, engine="eager")
    assert tl.store.counters["evictions"] > 0
    assert tl.store.counters["restores"] > 0
    assert len(rd.round_metrics) == len(rl.round_metrics)
    for m0, m1 in zip(rd.round_metrics, rl.round_metrics):
        assert m0 == m1
    assert rd.total_comm_bytes == rl.total_comm_bytes


@pytest.mark.parametrize("fleet", [0, 3])
def test_scan_lazy_matches_dense(fed, fleet):
    """Scan engine: chunks gather the whole chunk's visited set before
    entering lax.scan; the compiled body sees only the packed store."""
    rd = _run(_make(fed, lazy=False, fleet=fleet), engine="scan")
    tl = _make(fed, lazy=True, fleet=fleet, capacity=N)
    rl = _run(tl, engine="scan")
    for m0, m1 in zip(rd.round_metrics, rl.round_metrics):
        assert m0 == m1


def test_final_state_rows_match_dense(fed):
    """Beyond metrics: reassembling the lazy plane's rows (resident +
    spilled + never-visited template) reproduces the dense client stack
    leaf-for-leaf, and the server token matches exactly."""
    dense_tr = _make(fed, lazy=False)
    rng = np.random.default_rng(0)
    sd = dense_tr.init_state(jax.random.PRNGKey(0))
    lazy_tr = _make(fed, lazy=True, capacity=5)
    rng2 = np.random.default_rng(0)
    sl = lazy_tr.init_state(jax.random.PRNGKey(0))
    for r in range(10):
        sd, _ = dense_tr.round(sd, r, rng)
        sl, _ = lazy_tr.round(sl, r, rng2)
    assert lazy_tr.store.spilled_ids.size > 0
    rebuilt = _materialize_all(lazy_tr, sl)
    for a, b in zip(jax.tree_util.tree_leaves(rebuilt),
                    jax.tree_util.tree_leaves(jax.device_get(sd.clients))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(sl.server),
                    jax.tree_util.tree_leaves(sd.server)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(sl.visited),
                                  np.asarray(sd.visited))


def test_lazy_eval_full_residency_matches_dense(fed):
    """With capacity == n every visited client is resident, so the lazy
    resident-set metrics cover the full population: global metrics match
    the dense eval to float tolerance (summation order differs — slots
    are in visit order, the dense stack in id order)."""
    rd = _run(_make(fed, lazy=False), engine="eager", rounds=12)
    tl = _make(fed, lazy=True, capacity=N)
    rl = _run(tl, engine="eager", rounds=12)
    hd = {h["round"]: h for h in rd.history}
    hl = {h["round"]: h for h in rl.history}
    assert set(hd) == set(hl)
    final = max(hd)
    assert hl[final]["eval_clients"] == N
    for key in ("acc_global", "loss_global", "acc_personalized",
                "loss_personalized"):
        np.testing.assert_allclose(hl[final][key], hd[final][key],
                                   rtol=1e-5, atol=1e-6)


def test_lazy_checkpoint_roundtrip_with_spill(fed, tmp_path):
    """Interrupt a lazy run mid-churn (spilled clients present), persist
    trainer state + store to npz, restore into a freshly reset store,
    continue — losses and final rows match the uninterrupted run
    bit-for-bit."""
    # uninterrupted reference
    tru = _make(fed, lazy=True, capacity=5)
    rngu = np.random.default_rng(0)
    su = tru.init_state(jax.random.PRNGKey(0))
    ref_losses = []
    for r in range(13):
        su, m = tru.round(su, r, rngu)
        ref_losses.append(m["train_loss"])

    # interrupted at round 7
    tri = _make(fed, lazy=True, capacity=5)
    rngi = np.random.default_rng(0)
    si = tri.init_state(jax.random.PRNGKey(0))
    losses = []
    for r in range(7):
        si, m = tri.round(si, r, rngi)
        losses.append(m["train_loss"])
    assert tri.store.spilled_ids.size > 0, "interrupt must catch spill"
    save_pytree(str(tmp_path / "state.npz"), si, step=7)
    save_client_store(str(tmp_path / "store.npz"), tri.store)

    # restore: fresh template + freshly reset store (the new-process
    # path), walker continuity via the same trainer/rng as in
    # test_checkpoint.py
    template = _make(fed, lazy=True, capacity=5).init_state(
        jax.random.PRNGKey(0))
    si = load_pytree(str(tmp_path / "state.npz"), template)
    tri.init_state(jax.random.PRNGKey(0))      # resets tri.store
    load_client_store(str(tmp_path / "store.npz"), tri.store)
    for r in range(7, 13):
        si, m = tri.round(si, r, rngi)
        losses.append(m["train_loss"])
    np.testing.assert_array_equal(np.asarray(losses),
                                  np.asarray(ref_losses))
    for a, b in zip(jax.tree_util.tree_leaves(_materialize_all(tri, si)),
                    jax.tree_util.tree_leaves(_materialize_all(tru, su))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fleet", [0, 3])
def test_prefetch_on_matches_off_bit_identical(fed, fleet):
    """Async prefetch (the scan loop stages the next chunk's dataset
    rows on a host thread while the current chunk executes) must be a
    pure latency optimization: the factory is deterministic and
    ensure() fences on the worker, so metrics, eval history, and the
    base store counters are bit-identical with prefetch on — only the
    ``prefetch_{hits,misses}`` pair appears, and the pipeline actually
    staged something."""
    t0 = _make(fed, lazy=True, fleet=fleet, capacity=N)
    r0 = _run(t0, engine="scan", rounds=12)
    t1 = _make(fed, lazy=True, fleet=fleet, capacity=N, prefetch=True)
    r1 = _run(t1, engine="scan", rounds=12)
    for m0, m1 in zip(r0.round_metrics, r1.round_metrics):
        assert m0 == m1
    for h0, h1 in zip(r0.history, r1.history):
        assert h0 == h1
    from repro.fl.client_store import PREFETCH_COUNTERS, STORE_COUNTERS
    assert {k: t1.store.counters[k] for k in STORE_COUNTERS} \
        == t0.store.counters
    assert set(t1.store.counters) \
        == set(STORE_COUNTERS + PREFETCH_COUNTERS)
    assert (t1.store.counters["prefetch_hits"]
            + t1.store.counters["prefetch_misses"]) > 0


def test_fedavg_lazy_matches_dense(fed):
    """The FedAvg family rides the shared store via the lifted
    ``_evaluate_lazy``: with capacity == n the lazy round runs the
    dense gather arithmetic on packed rows — the global model
    trajectory is bit-identical, and eval at full residency matches to
    the usual slot-vs-id summation-order tolerance."""
    from repro.baselines import FedAvgTrainer

    dense, factory, model = fed
    kw = dict(lr=0.05, local_steps=3, clients_per_round=N,
              batch_size=16)
    td = FedAvgTrainer(model, dense, **kw)
    tl = FedAvgTrainer(model, factory, store_capacity=N, **kw)
    rngd, rngl = np.random.default_rng(0), np.random.default_rng(0)
    sd = td.init_state(jax.random.PRNGKey(0))
    sl = tl.init_state(jax.random.PRNGKey(0))
    for r in range(4):
        sd, md = td.round(sd, r, rngd)
        sl, ml = tl.round(sl, r, rngl)
        assert md == ml
    for a, b in zip(jax.tree_util.tree_leaves(sd.w),
                    jax.tree_util.tree_leaves(sl.w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ed, el = td.evaluate(sd), tl.evaluate(sl)
    assert el["eval_clients"] == N
    for key in ("acc_global", "loss_global"):
        np.testing.assert_allclose(el[key], ed[key],
                                   rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------
# regression pins on the refactored dense paths
# ------------------------------------------------------------------
def test_dense_eval_unchanged_by_row_refactor(fed):
    """The dense plane's evaluate() still runs the stacked closures; pin
    that the new row-based eval (what the lazy plane uses) computes the
    same per-client numbers on the same inputs, so the two paths can
    never drift apart silently."""
    dense, _, _ = fed
    tr = _make(fed, lazy=False)
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    for r in range(6):
        state, _ = tr.round(state, r, rng)
    out = tr.evaluate(state)
    assert set(out) >= {"acc_personalized", "acc_global", "acc"}

    pers = tr.personalized_params(state)
    acc_rows, loss_rows = tr.eval_rows_stacked(
        pers, dense.x_test, dense.y_test, dense.mask_test)
    np.testing.assert_allclose(float(jnp.mean(acc_rows)),
                               out["acc_personalized"], rtol=1e-6)
    np.testing.assert_allclose(float(jnp.mean(loss_rows)),
                               out["loss_personalized"], rtol=1e-6)
    acc_g, loss_g = tr.eval_rows_shared(
        tr.global_params(state), dense.x_test, dense.y_test,
        dense.mask_test)
    np.testing.assert_allclose(float(jnp.mean(acc_g)), out["acc_global"],
                               rtol=1e-6)
    np.testing.assert_allclose(float(jnp.mean(loss_g)),
                               out["loss_global"], rtol=1e-6)


def test_factory_rows_match_dense_stack(fed):
    """factory_from_federated materializes exactly the rows the dense
    to_device_data stacking produces — same padding, same dtypes."""
    dense, factory, _ = fed
    ids = np.arange(N)
    rows = factory.rows(ids)
    for got, want in zip(rows, dense):
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jax.device_get(want)))


def test_lazy_guards(fed):
    """APIs that would materialize (n, …) stacks refuse under the lazy
    plane instead of silently exploding memory."""
    tr = _make(fed, lazy=True, capacity=5)
    state = tr.init_state(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="lazy"):
        tr.personalized_params(state)
    with pytest.raises(NotImplementedError):
        tr.lyapunov(state, jax.random.PRNGKey(1))


# ------------------------------------------------------------------
# full cross-engine / cross-backend sweep (slow lane)
# ------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("engine", ["eager", "scan", "scan_fused"])
@pytest.mark.parametrize("backend", ["dense", "sparse"])
@pytest.mark.parametrize("fleet", [0, 3])
def test_lazy_equivalence_sweep(fed, engine, backend, fleet):
    rd = _run(_make(fed, lazy=False, fleet=fleet, backend=backend),
              engine=engine)
    cap = 5 if engine == "eager" else N
    tl = _make(fed, lazy=True, fleet=fleet, backend=backend, capacity=cap)
    rl = _run(tl, engine=engine)
    for m0, m1 in zip(rd.round_metrics, rl.round_metrics):
        assert m0 == m1
    assert [h["round"] for h in rd.history] \
        == [h["round"] for h in rl.history]
