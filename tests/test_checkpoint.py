"""Checkpoint round-trips, including RWSADMM state pytrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, restore_latest, save_pytree
from repro.core.rwsadmm import RWSADMMHparams, init_states


def test_roundtrip_nested(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    p = str(tmp_path / "ckpt_1.npz")
    save_pytree(p, tree, step=1)
    out = load_pytree(p, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_roundtrip_rwsadmm_state(tmp_path):
    hp = RWSADMMHparams()
    client, server = init_states({"w": jnp.ones((5,))}, hp, n_clients=3)
    p = str(tmp_path / "ckpt_2.npz")
    save_pytree(p, {"client": client._asdict(),
                    "server": server._asdict()})
    out = load_pytree(p, {"client": client._asdict(),
                          "server": server._asdict()})
    np.testing.assert_array_equal(out["client"]["x"]["w"], client.x["w"])


def test_restore_latest(tmp_path):
    tree = {"w": jnp.zeros((3,))}
    for step in (1, 5, 3):
        save_pytree(str(tmp_path / f"ckpt_{step}.npz"),
                    {"w": jnp.full((3,), float(step))})
    out, step = restore_latest(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(out["w"], jnp.full((3,), 5.0))


def test_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "ckpt_1.npz")
    save_pytree(p, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_pytree(p, {"w": jnp.zeros((4,))})


def test_fleet_state_checkpoint_roundtrip_continues_identically(tmp_path):
    """The "y-token IS a checkpoint" handoff claim, for the fleet: save
    the stacked (K, …) FleetState mid-run, restore it from disk into a
    fresh template, continue — the trajectory (losses, tokens, client
    states, visited set) must equal an uninterrupted run bit-for-bit.
    The chunk boundary crosses a rendezvous so the restored token stack
    demonstrably carries the walkers' distinct streams."""
    from repro.core.rwsadmm import RWSADMMHparams
    from repro.data import make_image_dataset, pathological_split
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data
    from repro.fl.fleet_trainer import FleetRWSADMMTrainer
    from repro.models.small import get_model

    imgs, labels = make_image_dataset(300, seed=0)
    parts = pathological_split(labels, 8, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))

    def make_trainer():
        return FleetRWSADMMTrainer(
            model, data,
            RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
            n_walkers=3, sync_every=5, zone_size=4, batch_size=16,
            solver="closed_form", seed=0)

    def run(interrupt: bool):
        tr = make_trainer()
        rng = np.random.default_rng(0)
        state = tr.init_state(jax.random.PRNGKey(0))
        losses = []
        sched = tr.schedule(7, rng, start_round=0)
        state, stacked = tr.run_chunk(state, sched, engine="scan")
        losses.extend(np.asarray(stacked["train_loss"]).tolist())
        if interrupt:
            path = str(tmp_path / "fleet_ckpt_7.npz")
            save_pytree(path, state, step=7)
            template = make_trainer().init_state(jax.random.PRNGKey(0))
            state = load_pytree(path, template)
        sched = tr.schedule(6, rng, start_round=7)
        state, stacked = tr.run_chunk(state, sched, engine="scan")
        losses.extend(np.asarray(stacked["train_loss"]).tolist())
        return state, losses

    st_plain, losses_plain = run(interrupt=False)
    st_ckpt, losses_ckpt = run(interrupt=True)
    np.testing.assert_array_equal(losses_plain, losses_ckpt)
    for a, b in zip(jax.tree_util.tree_leaves(st_plain),
                    jax.tree_util.tree_leaves(st_ckpt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
