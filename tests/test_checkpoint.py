"""Checkpoint round-trips, including RWSADMM state pytrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, restore_latest, save_pytree
from repro.core.rwsadmm import RWSADMMHparams, init_states


def test_roundtrip_nested(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    p = str(tmp_path / "ckpt_1.npz")
    save_pytree(p, tree, step=1)
    out = load_pytree(p, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_roundtrip_rwsadmm_state(tmp_path):
    hp = RWSADMMHparams()
    client, server = init_states({"w": jnp.ones((5,))}, hp, n_clients=3)
    p = str(tmp_path / "ckpt_2.npz")
    save_pytree(p, {"client": client._asdict(),
                    "server": server._asdict()})
    out = load_pytree(p, {"client": client._asdict(),
                          "server": server._asdict()})
    np.testing.assert_array_equal(out["client"]["x"]["w"], client.x["w"])


def test_restore_latest(tmp_path):
    tree = {"w": jnp.zeros((3,))}
    for step in (1, 5, 3):
        save_pytree(str(tmp_path / f"ckpt_{step}.npz"),
                    {"w": jnp.full((3,), float(step))})
    out, step = restore_latest(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_array_equal(out["w"], jnp.full((3,), 5.0))


def test_shape_mismatch_raises(tmp_path):
    p = str(tmp_path / "ckpt_1.npz")
    save_pytree(p, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_pytree(p, {"w": jnp.zeros((4,))})
