"""Device-sharded client plane ≡ single-device, pinned.

The FL trainers accept ``mesh=FLSharding(...)`` and place every
leading-client-axis array (dense stacked client pytrees, the lazy
store's packed ``(capacity, …)`` rows) over the mesh "data" axis, with
the chunk carry donated on the sharded path (``fl/sharding.py``,
docs/performance.md §8). These tests pin that sharding is a pure
placement decision:

* training trajectories (per-round metrics) are **bit-identical** to
  the unsharded run across eager/scan × dense/lazy × the K=3 fleet;
* eval history is bit-identical on the lazy path and equal to float
  tolerance on the dense path (the only divergence: the dense eval's
  ``jnp.mean`` over the sharded client axis reduces in per-device
  partial sums, reordering the float32 summation);
* async prefetch under sharding stays bit-identical to prefetch-off.

The real matrix needs ≥ 8 devices, which the tier-1 CPU run does not
have — so the sweep runs in a subprocess under
``--xla_force_host_platform_device_count=8`` (the multi-device CPU
harness the benchmarks use), following the ``test_dryrun_launch.py``
pattern. A single-device-mesh pin runs in-process so the sharded code
path (placements + donated chunk carry) is exercised by plain tier-1.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import dataclasses, json
import numpy as np
import jax

from repro.core.rwsadmm import RWSADMMHparams
from repro.data import (factory_from_federated, make_image_dataset,
                        pathological_split)
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.fleet_trainer import FleetRWSADMMTrainer
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.sharding import FLSharding
from repro.fl.simulation import run_simulation
from repro.models.small import get_model
from repro.scenarios import get_scenario_config

N = 8
assert jax.device_count() >= 8, jax.devices()
imgs, labels = make_image_dataset(400, seed=0)
parts = pathological_split(labels, N, seed=0)
f = build_federated(imgs, labels, parts)
dense, factory = to_device_data(f), factory_from_federated(f)
model = get_model("mlr", (28, 28, 1))
scen = dataclasses.replace(get_scenario_config("lossy_links"),
                           graph_backend="dense", neighbor_k_max=8)


def make(*, lazy, fleet=0, mesh=None, prefetch=False):
    kw = dict(zone_size=4, batch_size=16, solver="closed_form",
              scenario=scen, seed=0, mesh=mesh)
    data = factory if lazy else dense
    if lazy:
        kw["store_capacity"] = N
        kw["prefetch"] = prefetch
    if fleet:
        return FleetRWSADMMTrainer(model, data, RWSADMMHparams(beta=10.0),
                                   n_walkers=fleet, sync_every=3, **kw)
    return RWSADMMTrainer(model, data, RWSADMMHparams(beta=10.0), **kw)


def run(tr, engine):
    return run_simulation(tr, rounds=8, eval_every=4, seed=0,
                          engine=engine)


def devices_of(arr):
    return {s.device.id for s in arr.addressable_shards}


out = {"device_count": jax.device_count(), "configs": []}

# --- placement: (8, ...) rows really span all 8 devices -------------
sh = FLSharding()
tl = make(lazy=True, mesh=sh)
tl.init_state(jax.random.PRNGKey(0))
out["store_rows_devices"] = len(devices_of(tl.store.data.x_train))
td = make(lazy=False, mesh=sh)
sd = td.init_state(jax.random.PRNGKey(0))
leaf = jax.tree_util.tree_leaves(sd.clients.x)[0]
out["dense_rows_devices"] = len(devices_of(leaf))
out["server_replicated"] = bool(
    jax.tree_util.tree_leaves(sd.server.y)[0].sharding
    .is_fully_replicated)
# divisibility fallback: a leading dim that does not divide the device
# count replicates instead of breaking lowering
out["ragged_replicated"] = bool(
    sh.row_sharding(np.zeros((6, 3), np.float32)).is_fully_replicated)

# --- sharded == single across the engine/plane/fleet matrix ---------
EVAL_KEYS = ("acc_global", "loss_global", "acc_personalized",
             "loss_personalized")
for engine, lazy, fleet in [("eager", False, 0), ("scan", False, 0),
                            ("eager", True, 0), ("scan", True, 0),
                            ("scan", True, 3)]:
    r0 = run(make(lazy=lazy, fleet=fleet), engine)
    r1 = run(make(lazy=lazy, fleet=fleet, mesh=FLSharding()), engine)
    hdiff = max(abs(h0[k] - h1[k])
                for h0, h1 in zip(r0.history, r1.history)
                for k in EVAL_KEYS if k in h0)
    out["configs"].append({
        "engine": engine, "lazy": lazy, "fleet": fleet,
        "rounds": len(r0.round_metrics),
        "metrics_exact": all(
            m0 == m1 for m0, m1 in
            zip(r0.round_metrics, r1.round_metrics)),
        "max_hist_diff": float(hdiff),
    })

# --- prefetch on == off under sharding ------------------------------
r0 = run(make(lazy=True, mesh=FLSharding()), "scan")
tp = make(lazy=True, mesh=FLSharding(), prefetch=True)
r1 = run(tp, "scan")
out["prefetch_exact"] = (
    all(m0 == m1 for m0, m1 in zip(r0.round_metrics, r1.round_metrics))
    and all(h0 == h1 for h0, h1 in zip(r0.history, r1.history)))
out["prefetch_counters"] = {
    k: v for k, v in tp.store.counters.items() if "prefetch" in k}
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sweep():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=540,
                          env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_sharded_rows_span_all_devices(sweep):
    """Placement, not folklore: packed store rows and dense stacked
    client rows land on all 8 devices; server/token pytrees replicate;
    a ragged leading dim falls back to replication (the documented
    ``capacity % n_devices`` rule)."""
    assert sweep["device_count"] == 8
    assert sweep["store_rows_devices"] == 8
    assert sweep["dense_rows_devices"] == 8
    assert sweep["server_replicated"]
    assert sweep["ragged_replicated"]


def test_sharded_trajectories_match_single(sweep):
    """Per-round training metrics are bit-identical sharded vs single
    across eager/scan × dense/lazy × the K=3 fleet; eval history agrees
    within the dense-eval partial-sum tolerance."""
    assert len(sweep["configs"]) == 5
    for cfg in sweep["configs"]:
        assert cfg["rounds"] == 8, cfg
        assert cfg["metrics_exact"], cfg
        if cfg["lazy"]:
            # lazy eval reduces over gathered (replicated) rows — the
            # reduction order cannot change, so exact stays exact
            assert cfg["max_hist_diff"] == 0.0, cfg
        else:
            assert cfg["max_hist_diff"] < 1e-5, cfg


def test_sharded_prefetch_matches_off(sweep):
    """Async prefetch under the sharded plane: trajectory and eval
    history bit-identical to prefetch-off, and the pipeline actually
    staged rows (counters present and active)."""
    assert sweep["prefetch_exact"]
    counters = sweep["prefetch_counters"]
    assert set(counters) == {"prefetch_hits", "prefetch_misses"}
    assert counters["prefetch_hits"] + counters["prefetch_misses"] > 0


# ------------------------------------------------------------------
# in-process pins (tier-1: single device)
# ------------------------------------------------------------------
@pytest.fixture(scope="module")
def fed():
    import dataclasses

    from repro.data import (
        factory_from_federated,
        make_image_dataset,
        pathological_split,
    )
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data
    from repro.models.small import get_model
    from repro.scenarios import get_scenario_config

    imgs, labels = make_image_dataset(400, seed=0)
    parts = pathological_split(labels, 8, seed=0)
    f = build_federated(imgs, labels, parts)
    scen = dataclasses.replace(get_scenario_config("lossy_links"),
                               graph_backend="dense", neighbor_k_max=8)
    return (to_device_data(f), factory_from_federated(f),
            get_model("mlr", (28, 28, 1)), scen)


def _trainer(fed, *, lazy, mesh=None):
    from repro.core.rwsadmm import RWSADMMHparams
    from repro.fl.rwsadmm_trainer import RWSADMMTrainer

    dense, factory, model, scen = fed
    kw = dict(zone_size=4, batch_size=16, solver="closed_form",
              scenario=scen, seed=0, mesh=mesh)
    if lazy:
        kw["store_capacity"] = 8
    return RWSADMMTrainer(model, factory if lazy else dense,
                          RWSADMMHparams(beta=10.0), **kw)


@pytest.mark.parametrize("lazy", [False, True])
def test_single_device_mesh_is_identity(fed, lazy):
    """mesh=FLSharding() on however many devices the test session has
    (one, under tier-1) must be a no-op on the numbers: same schedule,
    same floats, same history — while still driving the sharded code
    path (NamedSharding placements + donated chunk carry)."""
    from repro.fl.sharding import FLSharding
    from repro.fl.simulation import run_simulation

    r0 = run_simulation(_trainer(fed, lazy=lazy), rounds=8,
                        eval_every=4, seed=0, engine="scan")
    r1 = run_simulation(_trainer(fed, lazy=lazy, mesh=FLSharding()),
                        rounds=8, eval_every=4, seed=0, engine="scan")
    for m0, m1 in zip(r0.round_metrics, r1.round_metrics):
        assert m0 == m1
    for h0, h1 in zip(r0.history, r1.history):
        assert h0 == h1


def test_mesh_needs_data_axis():
    from jax.sharding import Mesh

    from repro.fl.sharding import FLSharding

    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError, match="data"):
        FLSharding(mesh)


def test_scalars_replicate():
    """Leaves with no leading client axis (schedule scalars, token
    pytrees) get the replicated sharding."""
    from repro.fl.sharding import FLSharding

    sh = FLSharding()
    assert sh.row_sharding(jnp.float32(1.0)).is_fully_replicated
    tree = sh.replicate({"a": jnp.arange(3)})
    assert tree["a"].sharding.is_fully_replicated


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs >= 8 devices in-process (run under "
                           "--xla_force_host_platform_device_count=8)")
def test_direct_sharded_store_placement(fed):
    """When the session itself has >= 8 devices (the CI sharded-smoke
    harness), the lazy store's packed rows span them without the
    subprocess indirection."""
    from repro.fl.sharding import FLSharding

    tr = _trainer(fed, lazy=True, mesh=FLSharding(n_devices=8))
    tr.init_state(jax.random.PRNGKey(0))
    devs = {s.device.id
            for s in tr.store.data.x_train.addressable_shards}
    assert len(devs) == 8
