"""Layer-2 fixtures: the jaxpr auditor must flag a deliberately broken
toy closure (baked bulk constant, float64 leak, dropped donation,
leftover debug callback) and pass a clean one — plus one real matrix
cell audited end-to-end through the trainer capture hooks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jaxpr_audit import audit_closure, iter_eqns
from repro.analysis.registry import CellSpec, run_cell


def rules_of(report):
    return sorted(f.rule for f in report.findings)


# ------------------------------------------------------- toy closures --
def test_clean_closure_passes():
    fn = jax.jit(lambda x: jnp.tanh(x) * 2.0)
    rep = audit_closure("clean", fn, (jnp.ones((8,)),))
    assert rep.ok and rep.n_eqns >= 2 and rep.const_bytes == 0


def test_baked_constant_flagged():
    big = jnp.ones((100_000,))                 # 400 KB closure const
    fn = jax.jit(lambda x: x + big.sum())
    rep = audit_closure("baked", fn, (jnp.ones(()),),
                        const_budget=256 * 1024)
    assert "baked-constant" in rules_of(rep)
    assert rep.const_bytes >= 400_000


def test_baked_constant_within_budget_ok():
    big = jnp.ones((100_000,))
    fn = jax.jit(lambda x: x + big.sum())
    rep = audit_closure("dense", fn, (jnp.ones(()),),
                        const_budget=1 << 20)
    assert rep.ok


def test_float64_flagged():
    with jax.experimental.enable_x64():
        fn = jax.jit(lambda x: jnp.asarray(x, jnp.float64) * 2.0)
        rep = audit_closure("wide", fn, (jnp.ones((4,), jnp.float32),))
    assert "float64-op" in rules_of(rep)


def test_dropped_donation_flagged():
    fn = jax.jit(lambda s, x: s + x)           # no donate_argnums
    rep = audit_closure("chunk", fn,
                        (jnp.ones((8,)), jnp.ones((8,))),
                        expect_donation=True)
    assert rules_of(rep) == ["donation-mismatch"]
    assert rep.donated is False


def test_unexpected_donation_flagged():
    fn = jax.jit(lambda s, x: s + x, donate_argnums=(0,))
    rep = audit_closure("chunk", fn,
                        (jnp.ones((8,)), jnp.ones((8,))),
                        expect_donation=False)
    assert rules_of(rep) == ["donation-mismatch"]
    assert rep.donated is True


def test_donation_match_passes():
    fn = jax.jit(lambda s, x: s + x, donate_argnums=(0,))
    rep = audit_closure("chunk", fn,
                        (jnp.ones((8,)), jnp.ones((8,))),
                        expect_donation=True)
    assert rep.ok and rep.donated is True


def test_debug_callback_flagged():
    def f(x):
        # repro: allow(jax-debug) -- deliberately broken audit fixture
        jax.debug.print("x sum = {}", x.sum())
        return x * 2
    rep = audit_closure("dbg", jax.jit(f), (jnp.ones((4,)),))
    assert "callback-in-jit" in rules_of(rep)


def test_everything_broken_at_once():
    big = jnp.ones((100_000,))

    def f(s, x):
        # repro: allow(jax-debug) -- deliberately broken audit fixture
        jax.debug.print("s = {}", s.sum())
        return s + x + big.sum()

    fn = jax.jit(f)
    with jax.experimental.enable_x64():
        rep = audit_closure(
            "broken", fn,
            (jnp.ones((4,), jnp.float64), jnp.ones((4,), jnp.float64)),
            const_budget=256 * 1024, expect_donation=True)
    assert {"baked-constant", "float64-op", "callback-in-jit",
            "donation-mismatch"} <= set(rules_of(rep))


def test_iter_eqns_descends_into_scan():
    def f(xs):
        return jax.lax.scan(lambda c, x: (c + jnp.sin(x), x), 0.0, xs)
    closed = jax.jit(f).trace(jnp.ones((4,))).jaxpr
    prims = {e.primitive.name for e in iter_eqns(closed.jaxpr)}
    assert "scan" in prims and "sin" in prims


# ------------------------------------------------ real trainer matrix --
@pytest.mark.parametrize("spec", [
    CellSpec("single", "dense", False),
    CellSpec("single", "lazy", True),
])
def test_matrix_cell_audits_clean(spec):
    captured = run_cell(spec, engines=("eager", "scan"))
    names = {c.name for c in captured}
    assert "round" in names and "chunk:scan" in names
    for cap in captured:
        rep = cap.audit()
        assert rep.ok, (rep.name, rules_of(rep))
        if cap.name.startswith("chunk"):
            assert rep.donated is spec.sharded
    # the lazy plane must not bake the store's packed rows
    if spec.plane == "lazy":
        assert all(cap.audit().const_bytes < 256 * 1024
                   for cap in captured)
