"""Layer-1 fixtures: every lint rule has a positive (fires) and a
negative (stays quiet) inline fixture, plus the suppression contract
(justified allows suppress; unjustified and stale allows are findings
themselves) and the churn-stable fingerprint property."""
import textwrap

from repro.analysis.findings import Finding
from repro.analysis.lint import LintEngine, parse_suppressions


def lint(src: str):
    return LintEngine().lint_source(textwrap.dedent(src), "fix.py")


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------- RNG --
def test_ambient_np_random_fires():
    out = lint("""
        import numpy as np
        def f():
            return np.random.rand(3)
    """)
    assert "ambient-np-random" in rules_of(out)


def test_generator_api_is_quiet():
    out = lint("""
        import numpy as np
        def f():
            rng = np.random.default_rng(0)
            return rng.normal(size=3)
    """)
    assert out == []


def test_unseeded_default_rng_fires():
    out = lint("""
        import numpy as np
        rng = np.random.default_rng()
    """)
    assert rules_of(out) == ["unseeded-default-rng"]


def test_seeded_default_rng_quiet():
    assert lint("""
        import numpy as np
        rng = np.random.default_rng(1234)
    """) == []


def test_import_alias_resolution():
    # `from numpy import random as npr` still resolves to numpy.random
    out = lint("""
        from numpy import random as npr
        x = npr.rand(3)
    """)
    assert "ambient-np-random" in rules_of(out)


# ---------------------------------------------------------- PRNG keys --
def test_key_reuse_fires():
    out = lint("""
        import jax
        def f():
            key = jax.random.PRNGKey(0)
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a, b
    """)
    assert "prng-key-reuse" in rules_of(out)


def test_split_then_use_quiet():
    assert lint("""
        import jax
        def f():
            key = jax.random.PRNGKey(0)
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.normal(k2, (3,))
            return a, b
    """) == []


def test_reassigned_key_quiet():
    assert lint("""
        import jax
        def f(key):
            a = jax.random.normal(key, (3,))
            key = jax.random.fold_in(key, 1)
            b = jax.random.normal(key, (3,))
            return a, b
    """) == []


def test_consume_in_loop_fires():
    out = lint("""
        import jax
        def f(key):
            out = []
            for i in range(4):
                out.append(jax.random.normal(key, (3,)))
            return out
    """)
    assert "prng-key-reuse" in rules_of(out)


def test_loop_with_per_iteration_split_quiet():
    assert lint("""
        import jax
        def f(key):
            out = []
            for i in range(4):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, (3,)))
            return out
    """) == []


def test_loop_over_split_keys_quiet():
    assert lint("""
        import jax
        def f(key):
            return [jax.random.normal(k, (3,))
                    for k in jax.random.split(key, 4)]
    """) == []


# ------------------------------------------------- host syncs in jit --
def test_host_sync_inside_jit_fires():
    out = lint("""
        import jax
        import numpy as np
        @jax.jit
        def step(x):
            return np.asarray(x) + 1
    """)
    assert "host-sync-in-jit" in rules_of(out)


def test_item_inside_scan_body_fires():
    out = lint("""
        import jax
        def run(xs):
            def body(carry, x):
                carry = carry + x.item()
                return carry, x
            return jax.lax.scan(body, 0.0, xs)
    """)
    assert "host-sync-in-jit" in rules_of(out)


def test_float_on_param_inside_jit_fires():
    out = lint("""
        import jax
        @jax.jit
        def step(x):
            return float(x)
    """)
    assert "host-sync-in-jit" in rules_of(out)


def test_host_sync_outside_jit_quiet():
    assert lint("""
        import numpy as np
        def metrics(x):
            return float(np.asarray(x).mean())
    """) == []


def test_reachability_via_local_alias():
    # impl = a if cond else b; jax.jit(functools.partial(impl)) — both
    # impls are jit-reachable through the local alias.
    out = lint("""
        import functools
        import jax
        import numpy as np
        class T:
            def _a_impl(self, x):
                return np.asarray(x)
            def _b_impl(self, x):
                return x
            def step(self, mode, x):
                impl = self._a_impl if mode else self._b_impl
                return jax.jit(functools.partial(impl))(x)
    """)
    assert "host-sync-in-jit" in rules_of(out)


# ------------------------------------------------------ traced branch --
def test_traced_branch_fires():
    out = lint("""
        import jax
        import jax.numpy as jnp
        @jax.jit
        def step(x):
            if jnp.sum(x) > 0:
                return x
            return -x
    """)
    assert "traced-branch" in rules_of(out)


def test_python_branch_outside_jit_quiet():
    assert lint("""
        def pick(n):
            if n > 0:
                return 1
            return 2
    """) == []


# ------------------------------------------------------ jax.debug etc --
def test_leftover_jax_debug_fires():
    out = lint("""
        import jax
        def f(x):
            jax.debug.print("x={}", x)
            return x
    """)
    assert rules_of(out) == ["jax-debug"]


def test_mutable_default_fires():
    out = lint("""
        def f(items=[]):
            return items
    """)
    assert rules_of(out) == ["mutable-default"]


def test_immutable_default_quiet():
    assert lint("""
        def f(items=(), other=None):
            return items, other
    """) == []


# ------------------------------------------------------- suppressions --
def test_justified_allow_suppresses():
    assert lint("""
        import numpy as np
        x = np.random.rand(3)  # repro: allow(ambient-np-random) -- fixture
    """) == []


def test_allow_on_line_above():
    assert lint("""
        import numpy as np
        # repro: allow(ambient-np-random) -- fixture
        x = np.random.rand(3)
    """) == []


def test_unjustified_allow_is_a_finding():
    out = lint("""
        import numpy as np
        x = np.random.rand(3)  # repro: allow(ambient-np-random)
    """)
    assert rules_of(out) == ["unjustified-suppression"]


def test_stale_allow_is_a_finding():
    out = lint("""
        x = 1  # repro: allow(ambient-np-random) -- nothing here
    """)
    assert rules_of(out) == ["unused-suppression"]


def test_file_wide_allow():
    assert lint("""
        # repro: allow-file(ambient-np-random) -- generator fixture file
        import numpy as np
        a = np.random.rand(3)
        b = np.random.rand(3)
    """) == []


def test_docstring_allow_is_inert():
    # allow() syntax quoted in a docstring must not register
    out = lint('''
        def f():
            """Example: # repro: allow(ambient-np-random) -- doc"""
            return 1
    ''')
    assert out == []


def test_suppressions_parse_lines():
    sups = parse_suppressions("p.py", "x = 1\n# repro: allow(a-b) -- y\n")
    assert len(sups) == 1 and sups[0].line == 2 and sups[0].justified


# -------------------------------------------------------- fingerprint --
def test_fingerprint_survives_line_churn():
    a = Finding(rule="r", path="p.py", line=10, col=0, message="m",
                snippet="x = np.random.rand(3)")
    b = Finding(rule="r", path="p.py", line=99, col=4, message="m",
                snippet="x  =  np.random.rand(3)")
    assert a.fingerprint == b.fingerprint
    c = Finding(rule="r2", path="p.py", line=10, col=0, message="m",
                snippet="x = np.random.rand(3)")
    assert a.fingerprint != c.fingerprint


def test_syntax_error_is_reported_not_raised():
    out = LintEngine().lint_source("def broken(:\n", "bad.py")
    assert rules_of(out) == ["syntax-error"]
