"""Graph + Markov-chain machinery (paper §3, Assumption 3.1, Eq. 2-6)."""
import numpy as np

from repro.core import graph as G
from repro.core import markov as M


def test_random_geometric_graph_properties():
    g = G.random_geometric_graph(20, min_degree=5,
                                 rng=np.random.default_rng(0))
    assert g.n == 20
    assert g.is_connected()
    assert (g.degree() >= 5).all()          # paper App. D.2 requirement
    assert (g.adjacency == g.adjacency.T).all()
    assert not g.adjacency.diagonal().any()


def test_neighborhood_contains_self():
    g = G.random_geometric_graph(10, min_degree=3,
                                 rng=np.random.default_rng(1))
    nb = g.neighborhood(4)
    assert 4 in nb
    assert len(nb) == g.degree(4) + 1


def test_dynamic_graph_regeneration():
    dg = G.DynamicGraph(15, min_degree=4, regen_every=10, seed=0)
    a0 = dg.current().adjacency.copy()
    for _ in range(9):
        dg.step()
    assert (dg.current().adjacency == a0).all()  # unchanged before regen
    dg.step()
    assert dg.n_regens == 1
    assert dg.current().is_connected()


def test_degree_transition_matrix_row_stochastic():
    g = G.random_geometric_graph(12, min_degree=4,
                                 rng=np.random.default_rng(2))
    p = M.degree_transition_matrix(g)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-12)
    assert (p >= 0).all()


def test_is_connected_dense_no_overflow():
    """A node whose seen-neighbor count hits a multiple of 256 must not
    wrap the BFS matvec accumulator (dense radio-range graphs at large
    n reach such degrees)."""
    n = 258
    adj = np.zeros((n, n), dtype=bool)
    for i in range(256):                      # ring of 256
        adj[i, (i + 1) % 256] = adj[(i + 1) % 256, i] = True
    adj[257, :256] = adj[:256, 257] = True    # linked to exactly 256
    adj[256, 0] = adj[0, 256] = True
    g = G.ClientGraph(adjacency=adj, positions=np.zeros((n, 2)))
    assert g.is_connected()


def test_metropolis_vectorized_matches_loop():
    """Pin the vectorized Metropolis-Hastings construction against the
    literal double-loop form (P_ij = min(1/deg i, 1/deg j), self-loop
    absorbs the remainder)."""
    for seed in range(4):
        g = G.random_geometric_graph(17, min_degree=4,
                                     rng=np.random.default_rng(seed))
        adj = g.adjacency.astype(np.float64)
        deg = adj.sum(axis=1)
        ref = np.zeros((g.n, g.n))
        for i in range(g.n):
            for j in np.flatnonzero(adj[i]):
                ref[i, j] = min(1.0 / deg[i], 1.0 / deg[j])
            ref[i, i] = 1.0 - ref[i].sum()
        np.testing.assert_allclose(M.metropolis_transition_matrix(g), ref,
                                   atol=1e-15)
    # isolated node: self-loop of 1 (loop form's convention)
    adj = np.zeros((3, 3), dtype=bool)
    adj[0, 1] = adj[1, 0] = True
    iso = G.ClientGraph(adjacency=adj, positions=np.zeros((3, 2)))
    p = M.metropolis_transition_matrix(iso)
    np.testing.assert_allclose(p.sum(axis=1), 1.0)
    assert p[2, 2] == 1.0


def test_metropolis_uniform_stationary():
    g = G.random_geometric_graph(12, min_degree=4,
                                 rng=np.random.default_rng(3))
    p = M.metropolis_transition_matrix(g)
    pi = M.stationary_distribution(p)
    np.testing.assert_allclose(pi, 1.0 / 12, atol=1e-6)


def test_degree_chain_stationary_proportional_to_degree():
    g = G.random_geometric_graph(12, min_degree=4,
                                 rng=np.random.default_rng(4))
    p = M.degree_transition_matrix(g)
    pi = M.stationary_distribution(p)
    deg = g.degree().astype(float)
    np.testing.assert_allclose(pi, deg / deg.sum(), atol=1e-6)


def test_mixing_time_inequality_eq3():
    """Assumption 3.1: ||P^τ(δ)_i − π|| ≤ δ π_* must hold at the τ(δ)
    computed from Eq. (6)."""
    g = G.random_geometric_graph(15, min_degree=5,
                                 rng=np.random.default_rng(5))
    for make in (M.degree_transition_matrix, M.metropolis_transition_matrix):
        rep = M.verify_assumption_3_1(make(g), delta=0.5)
        assert rep["holds"], rep


def test_mixing_time_monotone_in_connectivity():
    """Complete graph mixes faster than a line (sanity on σ(P))."""
    line = M.metropolis_transition_matrix(G.line_graph(10))
    comp = M.metropolis_transition_matrix(G.complete_graph(10))
    assert M.mixing_time(comp) <= M.mixing_time(line)


def test_p_max_envelope():
    ps = [np.eye(3) * 0.5 + 0.5 / 3, np.full((3, 3), 1 / 3)]
    env = M.p_max_envelope(ps)
    assert (env >= ps[0] - 1e-12).all() and (env >= ps[1] - 1e-12).all()


def test_random_walk_visits_all_and_hitting_time():
    dg = G.DynamicGraph(10, min_degree=4, regen_every=10, seed=0)
    w = M.RandomWalkServer(seed=1)
    w.reset(dg.current())
    for _ in range(400):
        w.step(dg.step())
    assert (w.visit_counts > 0).all()
    t = w.hitting_time()
    assert t is not None and t < 400


def test_walk_empirical_frequency_matches_stationary():
    """Long-run visit frequencies ≈ π (ergodic theorem) on a static graph."""
    g = G.random_geometric_graph(8, min_degree=3,
                                 rng=np.random.default_rng(7))
    w = M.RandomWalkServer(transition="metropolis", seed=2)
    w.reset(g)
    for _ in range(6000):
        w.step(g)
    freq = w.visit_counts / w.visit_counts.sum()
    np.testing.assert_allclose(freq, 1.0 / 8, atol=0.03)
