"""Batched scenario rollout engine: the vectorized ``schedule()`` path
must replay the legacy per-round stepping bit-for-bit — graphs,
availability masks, the compiled ``ZoneSchedule`` (incl. the
latency_s/energy_j pricing columns), and the post-window continuation
state — for every mobility × links × churn combination, at every
chunking. Plus the positions-only baseline mode (identical
selection/pricing, zero connectivity work) and the seed-stability pin
for the derived RNG streams.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import markov
from repro.core.markov import RandomWalkServer
from repro.scenarios import (
    ChurnConfig,
    LinkConfig,
    MobilityConfig,
    Scenario,
    ScenarioConfig,
    build_scenario,
    get_scenario_config,
)

N = 18
ROUNDS = 23          # crosses static_regen epochs at 10 and 20

ALL_SCENARIOS = [
    "static_regen",
    "random_waypoint",
    "gauss_markov",
    "lossy_links",    # link dropouts ON
    "duty_cycle",     # churn ON
    "field_trial",    # dropouts + churn together
]


def chunked(name, *, rollout_chunk=None, **over):
    cfg = get_scenario_config(name)
    if rollout_chunk is not None:
        cfg = dataclasses.replace(cfg, rollout_chunk=rollout_chunk)
    return dataclasses.replace(cfg, **over) if over else cfg


class SteppedFacade:
    """DynamicGraph-contract view of a Scenario that forces the legacy
    per-round stepping — the oracle the batched engine is pinned to."""

    def __init__(self, scn: Scenario):
        self._scn = scn

    def schedule(self, rounds, *, include_current=False):
        return self._scn.schedule(rounds, include_current=include_current,
                                  batched=False)

    def pop_avail_trace(self):
        return self._scn.pop_avail_trace()

    def current(self):
        return self._scn.current()


def assert_graphs_equal(ga, gb):
    np.testing.assert_array_equal(ga.adjacency, gb.adjacency)
    np.testing.assert_array_equal(ga.positions, gb.positions)


# ------------------------------------------------ schedule bit-identity ---
@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
@pytest.mark.parametrize("chunk", [4, 128])
def test_batched_schedule_bit_identical_to_stepped(scenario, chunk):
    """Batched rollout ≡ per-round stepping: graphs, availability
    traces, and regen counters, with chunk boundaries mid-window."""
    a = Scenario(N, chunked(scenario, rollout_chunk=chunk), seed=3)
    b = Scenario(N, chunked(scenario), seed=3)
    gs_a = a.schedule(ROUNDS, include_current=True)
    gs_b = b.schedule(ROUNDS, include_current=True, batched=False)
    assert len(gs_a) == len(gs_b) == ROUNDS
    for ga, gb in zip(gs_a, gs_b):
        assert_graphs_equal(ga, gb)
    ta, tb = a.pop_avail_trace(), b.pop_avail_trace()
    if ta is None:
        assert tb is None
    else:
        np.testing.assert_array_equal(ta, tb)
    assert a.n_regens == b.n_regens


@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_batched_schedule_continuation_state(scenario):
    """After a batched window the scenario steps on exactly like its
    stepped twin: mobility state, link stream, and churn stream all
    land in the same place."""
    a = Scenario(N, scenario, seed=5)
    b = Scenario(N, scenario, seed=5)
    a.schedule(11, include_current=True)
    b.schedule(11, include_current=True, batched=False)
    for _ in range(6):
        ga, gb = a.step(), b.step()
        assert_graphs_equal(ga, gb)
        if a.availability() is not None:
            np.testing.assert_array_equal(a.availability(),
                                          b.availability())


@pytest.mark.parametrize("scenario", ["gauss_markov", "field_trial"])
def test_copy_on_seed_detaches_retained_graphs(scenario):
    """Copy-on-seed (memory): the graphs the scenario retains past a
    chunk window must not hold views into the window's (R, n, n)
    rollout stacks — and detaching them must leave every trajectory
    bit-identical (the values are copied, never recomputed)."""
    scn = Scenario(N, scenario, seed=5)
    scn.schedule(11, include_current=True)
    g = scn.current()
    assert g.adjacency.base is None            # stacks freed, not pinned
    assert g.positions.base is None
    assert scn.positions.base is None
    d2 = getattr(g, "_sq_dists", None)
    assert d2 is None or d2.base is None
    # the retained-and-detached graph continues the run exactly like the
    # stepped twin (which never built stacks in the first place)
    twin = Scenario(N, scenario, seed=5)
    twin.schedule(11, include_current=True, batched=False)
    for _ in range(5):
        assert_graphs_equal(scn.step(), twin.step())


def test_rollout_chunk_size_never_changes_trajectories():
    """RNG consumption is chunk-size-invariant (the docs' promise)."""
    runs = []
    for chunk in (1, 5, 7, 64):
        scn = Scenario(N, chunked("field_trial", rollout_chunk=chunk),
                       seed=9)
        runs.append(scn.schedule(17, include_current=True))
    for other in runs[1:]:
        for ga, gb in zip(runs[0], other):
            assert_graphs_equal(ga, gb)


@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_zone_schedule_bit_identical(scenario):
    """The full compiled artifact: ZoneSchedule from the batched engine
    == ZoneSchedule from per-round stepping, every column, including
    the wireless pricing ones."""
    payload = 10_000

    def build(stepped):
        scn = Scenario(N, scenario, seed=2)
        walker = RandomWalkServer(seed=3)
        walker.reset(scn.current())
        rng = np.random.default_rng(4)
        dyn = SteppedFacade(scn) if stepped else scn
        price = lambda graphs, clients, idx, mask: scn.price_schedule(
            graphs, clients, idx, mask, payload)
        out, r = [], 0
        for m in (9, 8, 6):   # chunk boundaries cross a regen epoch
            out.append(markov.zone_schedule(dyn, walker, m, 5, rng,
                                            start_round=r, price=price))
            r += m
        return out

    for sa, sb in zip(build(stepped=False), build(stepped=True)):
        np.testing.assert_array_equal(sa.idx, sb.idx)
        np.testing.assert_array_equal(sa.mask, sb.mask)
        np.testing.assert_array_equal(sa.n_i, sb.n_i)
        np.testing.assert_array_equal(sa.keys, sb.keys)
        np.testing.assert_array_equal(sa.clients, sb.clients)
        np.testing.assert_array_equal(sa.active, sb.active)
        np.testing.assert_array_equal(sa.latency_s, sb.latency_s)
        np.testing.assert_array_equal(sa.energy_j, sb.energy_j)


# ------------------------------------------------- positions-only mode ---
def _no_connectivity(monkeypatch):
    """Make every connectivity-stack entry point explode."""
    def boom(*a, **k):
        raise AssertionError("connectivity stack used in positions-only "
                             "mode")

    import repro.core.graph as graph_mod
    import repro.scenarios.links as links_mod
    import repro.scenarios.mobility as mob_mod

    for mod, names in ((graph_mod, ("patch_connected", "knn_adjacency",
                                    "random_geometric_graph")),
                       (mob_mod, ("patch_connected", "range_graph",
                                  "range_graphs_batch",
                                  "random_geometric_graph")),
                       (links_mod, ("patch_connected",))):
        for name in names:
            monkeypatch.setattr(mod, name, boom)


@pytest.mark.parametrize("scenario", ["static_regen", "duty_cycle",
                                      "field_trial"])
def test_positions_only_never_touches_connectivity(monkeypatch, scenario):
    _no_connectivity(monkeypatch)
    scn = build_scenario(scenario, N, seed=0, positions_only=True)
    members = np.asarray([0, 3, 5])
    for _ in range(12):
        scn.step()
        assert scn.positions.shape == (N, 2)
        lat, en = scn.price_star_round(members, 10_000)
        assert lat > 0 and en > 0
    with pytest.raises(RuntimeError, match="positions-only"):
        scn.current()
    with pytest.raises(RuntimeError, match="positions-only"):
        scn.schedule(3)


@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
def test_positions_only_tracks_full_stack(scenario):
    """Positions-only stepping consumes the mobility/churn streams
    exactly like the full stack: same positions, same availability,
    same base-station prices, round for round."""
    full = build_scenario(scenario, N, seed=6, positions_only=False)
    lite = build_scenario(scenario, N, seed=6, positions_only=True)
    members = np.asarray([1, 4, 9, 13])
    for _ in range(ROUNDS):
        np.testing.assert_array_equal(full.positions, lite.positions)
        af, al = full.availability(), lite.availability()
        if af is None:
            assert al is None
        else:
            np.testing.assert_array_equal(af, al)
        assert full.price_star_round(members, 10_000) \
            == lite.price_star_round(members, 10_000)
        full.step()
        lite.step()


def test_baseline_select_and_pricing_unchanged_by_positions_only(fed_small):
    """FedAvg-family behavior is identical whether its scenario carries
    the connectivity stack or not."""
    import jax

    from repro.baselines import FedAvgTrainer
    from repro.models.small import get_model

    data, shape = fed_small

    def run(positions_only):
        tr = FedAvgTrainer(get_model("mlr", shape), data,
                           clients_per_round=4)
        tr.scenario = build_scenario("field_trial", tr.n_clients, seed=0,
                                     positions_only=positions_only)
        rng = np.random.default_rng(0)
        state = tr.init_state(jax.random.PRNGKey(0))
        sels, costs = [], []
        for r in range(6):
            state, m = tr.round(state, r, rng)
            costs.append((m["latency_s"], m["energy_j"]))
        sels.append(tr.select_clients(6, rng, 4))
        return sels, costs

    sel_a, costs_a = run(True)
    sel_b, costs_b = run(False)
    for a, b in zip(sel_a, sel_b):
        np.testing.assert_array_equal(a, b)
    assert costs_a == costs_b


@pytest.fixture(scope="module")
def fed_small():
    from repro.data import make_image_dataset, pathological_split
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data

    imgs, labels = make_image_dataset(200, seed=0)
    parts = pathological_split(labels, 10, seed=0)
    return to_device_data(build_federated(imgs, labels, parts)), (28, 28, 1)


def test_baseline_attach_scenario_defaults_to_positions_only(fed_small):
    from repro.baselines import FedAvgTrainer
    from repro.models.small import get_model

    data, shape = fed_small
    tr = FedAvgTrainer(get_model("mlr", shape), data, clients_per_round=4)
    tr.attach_scenario("duty_cycle", seed=0)
    assert tr.scenario.positions_only
    assert tr.scenario.graph is None


# ------------------------------------------------- stream derivation ----
def test_seed_stream_derivation_stable():
    """The three per-layer streams are pinned: mobility mirrors
    default_rng(seed) (DynamicGraph bit-compat), links/churn derive from
    SeedSequence([seed, 1]) / ([seed, 2]). Hardcoded draws make any
    change to the derivation (e.g. re-adding the dead ``max(seed, 0)``
    as something meaningful) fail loudly instead of silently reseeding
    every experiment."""
    scn = Scenario(4, "static_regen", seed=7)
    # Mobility stream: reset() consumed exactly one (n, 2) uniform block
    # (DynamicGraph bit-compat), so the next draw matches a fresh
    # default_rng(seed) advanced by the same block.
    ref_mob = np.random.default_rng(7)
    ref_mob.uniform(size=(4, 2))
    assert scn._rng_mob.uniform() == ref_mob.uniform()
    # Derived streams, pinned to hardcoded values:
    assert np.random.default_rng(
        np.random.SeedSequence([0, 1])).uniform() == 0.8897387912781343
    assert np.random.default_rng(
        np.random.SeedSequence([0, 2])).uniform() == 0.08082403917318748
    assert np.random.default_rng(
        np.random.SeedSequence([7, 1])).uniform() == 0.7701409510034741
    assert np.random.default_rng(
        np.random.SeedSequence([7, 2])).uniform() == 0.277970282193581
    # The scenario's own link stream matches the pinned derivation
    # (links disabled for static_regen → stream untouched since init).
    assert scn._rng_link.uniform() == 0.7701409510034741
    # Negative seeds are rejected up front (the reason max(seed, 0)
    # was dead code: default_rng(seed) raises first).
    with pytest.raises(ValueError):
        Scenario(4, "static_regen", seed=-1)


def test_scenario_config_knob_combo_still_composes():
    """Sanity: explicit configs with all layers on still roll out."""
    cfg = ScenarioConfig(
        name="combo",
        mobility=MobilityConfig(model="gauss_markov", mean_speed=0.05),
        links=LinkConfig(enabled=True),
        churn=ChurnConfig(enabled=True, straggler_frac=0.3),
        rollout_chunk=6,
    )
    scn = Scenario(N, cfg, seed=1)
    graphs = scn.schedule(13, include_current=True)
    trace = scn.pop_avail_trace()
    assert len(graphs) == 13 and trace.shape == (13, N)
    for g in graphs:
        assert g.is_connected()


# ------------------------------------------------ trace replay model -----
def _demo_trace(rounds=30, n=N, seed=17):
    from repro.scenarios import register_trace

    pos = np.random.default_rng(seed).uniform(0.0, 1.0, (rounds, n, 2))
    register_trace("rollout-demo", pos)
    return pos


def trace_cfg(**over):
    return ScenarioConfig(
        name="trace-test",
        mobility=MobilityConfig(model="trace", trace_path="rollout-demo",
                                min_degree=4, **over),
    )


@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_trace_batched_equals_stepped_and_wraps(backend):
    """The trace model rides the shared batched rollout tail: batched ≡
    stepped on both backends, round t replays frame t mod R (wrap-
    around), and the mobility RNG stream is never consumed."""
    pos = _demo_trace(rounds=9)
    cfg = dataclasses.replace(trace_cfg(), graph_backend=backend,
                              neighbor_k_max=N)
    a = Scenario(N, cfg, seed=3)
    b = Scenario(N, cfg, seed=3)
    gs_a = a.schedule(ROUNDS, include_current=True)
    gs_b = b.schedule(ROUNDS, include_current=True, batched=False)
    for t, (ga, gb) in enumerate(zip(gs_a, gs_b)):
        np.testing.assert_array_equal(ga.positions, gb.positions)
        np.testing.assert_array_equal(ga.positions, pos[t % 9])
    # zero RNG consumption: the mobility stream sits at its seed state
    assert a._rng_mob.uniform() == np.random.default_rng(3).uniform()


def test_trace_composes_with_links_and_churn():
    """Replayed positions feed the full stack (dropouts, churn, zone
    schedules) exactly like synthetic mobility."""
    _demo_trace()
    cfg = dataclasses.replace(
        trace_cfg(), links=LinkConfig(enabled=True),
        churn=ChurnConfig(enabled=True, straggler_frac=0.2))
    scn = Scenario(N, cfg, seed=4)
    w = RandomWalkServer(seed=5)
    w.reset(scn.current())
    sched = markov.zone_schedule(scn, w, 12, 4, np.random.default_rng(6))
    assert sched.rounds == 12
    assert (sched.active >= 1).all()      # zones formed every round
    # churn produced a real availability trace over the replayed graphs
    scn2 = Scenario(N, cfg, seed=4)
    scn2.schedule(12, include_current=True)
    trace = scn2.pop_avail_trace()
    assert trace.shape == (12, N)
    assert 0 < trace.sum() < trace.size   # some offline, some online


def test_trace_file_roundtrip(tmp_path):
    """.npz (key 'positions') and .npy files load into identical models;
    bad shapes, out-of-square values, and client-count mismatches are
    rejected with clear errors."""
    from repro.scenarios import TraceMobility, build_mobility, load_trace

    pos = np.random.default_rng(2).uniform(0, 1, (5, N, 2))
    npz, npy = tmp_path / "t.npz", tmp_path / "t.npy"
    np.savez(npz, positions=pos)
    np.save(npy, pos)
    np.testing.assert_array_equal(load_trace(str(npz)), pos)
    np.testing.assert_array_equal(load_trace(str(npy)), pos)
    m = build_mobility(N, MobilityConfig(model="trace",
                                         trace_path=str(npz)))
    assert isinstance(m, TraceMobility)
    rng = np.random.default_rng(0)
    np.testing.assert_array_equal(m.reset_positions(rng), pos[0])
    np.testing.assert_array_equal(m.step_positions(rng), pos[1])

    with pytest.raises(ValueError, match="unknown trace"):
        load_trace("never-registered")
    with pytest.raises(ValueError, match="trace_path"):
        build_mobility(N, MobilityConfig(model="trace"))
    with pytest.raises(ValueError, match="unit square"):
        from repro.scenarios import register_trace
        register_trace("bad", np.full((3, N, 2), 1.5))
    with pytest.raises(ValueError, match="clients"):
        build_mobility(N + 1, MobilityConfig(model="trace",
                                             trace_path=str(npz)))


def test_trace_scan_driver_equals_eager():
    """End-to-end: a trainer on a trace scenario runs both engines to
    the same trajectory (the trace is host-side control plane like any
    other mobility model)."""
    import jax

    from repro.core.rwsadmm import RWSADMMHparams
    from repro.data import make_image_dataset, pathological_split
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data
    from repro.fl.rwsadmm_trainer import RWSADMMTrainer
    from repro.models.small import get_model
    from repro.scenarios import register_trace

    n_clients = 10
    register_trace(
        "trainer-demo",
        np.random.default_rng(9).uniform(0, 1, (7, n_clients, 2)))
    cfg = ScenarioConfig(
        name="trace-trainer",
        mobility=MobilityConfig(model="trace", trace_path="trainer-demo",
                                min_degree=4),
    )
    imgs, labels = make_image_dataset(400, seed=0)
    parts = pathological_split(labels, n_clients, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))

    def mk():
        return RWSADMMTrainer(
            model, data, RWSADMMHparams(beta=10.0), zone_size=4,
            batch_size=20, solver="closed_form", scenario=cfg, seed=0)

    tr_e = mk()
    rng = np.random.default_rng(0)
    st_e = tr_e.init_state(jax.random.PRNGKey(0))
    losses_e = []
    for r in range(10):
        st_e, m = tr_e.round(st_e, r, rng)
        losses_e.append(m["train_loss"])

    tr_s = mk()
    rng = np.random.default_rng(0)
    st_s = tr_s.init_state(jax.random.PRNGKey(0))
    sched = tr_s.schedule(10, rng, start_round=0)
    st_s, stacked = tr_s.run_chunk(st_s, sched, engine="scan")
    np.testing.assert_allclose(
        losses_e, np.asarray(stacked["train_loss"]), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(st_e.visited),
                                  np.asarray(st_s.visited))
