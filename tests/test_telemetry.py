"""Telemetry subsystem: recorder/event-schema round-trips, the report
CLI, and — the hard requirement — telemetry-on runs bit-identical to
telemetry-off (single walker + K=3 fleet, eager + scan engines, dense +
sparse graph backends): the recorder must never touch an RNG stream or
perturb the computation graph.
"""
import dataclasses
import json
import os

import pytest

from repro.core.rwsadmm import RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import (
    to_device_data,
    validate_round_metrics,
)
from repro.fl.fleet_trainer import FleetRWSADMMTrainer
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model
from repro.scenarios import get_scenario_config
from repro.telemetry import (
    TelemetryError,
    TelemetryRun,
    atomic_write_json,
    load_bench_rows,
    manifest_fingerprint,
    merge_bench_rows,
    read_events,
    split_by_type,
    validate_event,
)
from repro.telemetry.report import render_report, summarize
from repro.telemetry.smoke import smoke_run


@pytest.fixture(scope="module")
def fed():
    imgs, labels = make_image_dataset(400, seed=0)
    parts = pathological_split(labels, 8, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))
    return data, model


def _scenario(backend: str):
    return dataclasses.replace(get_scenario_config("lossy_links"),
                               graph_backend=backend, neighbor_k_max=8)


def _make_trainer(fed, backend: str, fleet: int = 0):
    data, model = fed
    kw = dict(zone_size=4, batch_size=16, solver="closed_form",
              scenario=_scenario(backend), seed=0)
    if fleet:
        return FleetRWSADMMTrainer(model, data, RWSADMMHparams(beta=10.0),
                                   n_walkers=fleet, sync_every=3, **kw)
    return RWSADMMTrainer(model, data, RWSADMMHparams(beta=10.0), **kw)


def _run(fed, *, engine, backend, fleet=0, telemetry=None, rounds=8):
    tr = _make_trainer(fed, backend, fleet)
    return run_simulation(tr, rounds=rounds, eval_every=4, seed=0,
                          engine=engine, telemetry=telemetry)


# ------------------------------------------------ bit-identical pins ----
@pytest.mark.parametrize("engine", ["eager", "scan"])
@pytest.mark.parametrize("backend", ["dense", "sparse"])
@pytest.mark.parametrize("fleet", [0, 3])
def test_telemetry_on_is_bit_identical(fed, tmp_path, engine, backend,
                                       fleet):
    """Recording a run must not change it: identical histories and
    round_metrics (exact float equality — same draws, same executables)
    with telemetry on vs off, across engines, backends, and the K=3
    fleet."""
    res_off = _run(fed, engine=engine, backend=backend, fleet=fleet)
    with TelemetryRun(str(tmp_path / "run"), seed=0) as tel:
        res_on = _run(fed, engine=engine, backend=backend, fleet=fleet,
                      telemetry=tel)
    assert len(res_off.round_metrics) == len(res_on.round_metrics)
    for m0, m1 in zip(res_off.round_metrics, res_on.round_metrics):
        assert m0 == m1
    assert [h["round"] for h in res_off.history] \
        == [h["round"] for h in res_on.history]
    for h0, h1 in zip(res_off.history, res_on.history):
        assert h0 == h1
    assert res_off.total_comm_bytes == res_on.total_comm_bytes
    # ...and the recorder actually recorded every event type.
    b = split_by_type(read_events(tel.events_path))
    assert len(b["round"]) == 8
    assert b["visit"], "walk trace missing"
    assert b["snapshot"] and b["phase"] and b["counter"]


def test_visit_trace_identical_across_engines(fed, tmp_path):
    """The walk/zone trace is engine-invariant: eager and scan emit the
    same visit events (clients, zones, pricing) for the same seed."""
    streams = {}
    for engine in ("eager", "scan"):
        with TelemetryRun(str(tmp_path / engine), seed=0) as tel:
            _run(fed, engine=engine, backend="dense", telemetry=tel)
        streams[engine] = [e for e in read_events(tel.events_path)
                           if e["t"] == "visit"]
    assert streams["eager"] == streams["scan"]


# ------------------------------------------------ event schema ----------
def test_event_validation():
    validate_event({"t": "visit", "round": 0, "client": 3})
    with pytest.raises(TelemetryError, match="unknown event type"):
        validate_event({"t": "nope"})
    with pytest.raises(TelemetryError, match="missing required"):
        validate_event({"t": "phase", "name": "x"})


def test_event_roundtrip_and_report(tmp_path):
    """write → read → report on a recorded 5-round run: every event
    re-validates, counts line up with the manifest, and the rendered
    summary carries all required sections."""
    run_dir = str(tmp_path / "run")
    tel = smoke_run(run_dir, rounds=5, eval_every=5)
    events = list(read_events(tel.events_path))
    for e in events:
        validate_event(e)
    b = split_by_type(events)
    assert len(b["round"]) == 5
    assert len(b["visit"]) == 5
    assert len(b["snapshot"]) == 1
    counts = tel.manifest["event_counts"]
    assert counts["round"] == 5 and counts["visit"] == 5
    assert tel.manifest["status"] == "finalized"

    report = render_report(run_dir)
    for section in ("== Run ==", "== Convergence ==",
                    "== Coverage & staleness ==", "== Communication ==",
                    "== Phase times ==", "== Counters =="):
        assert section in report, report
    assert "scan_chunk" in report and "scenario_rollout" in report

    s = summarize(run_dir)
    assert s["n_rounds"] == 5
    assert s["comm_bytes_total"] > 0
    assert s["latency_s_total"] > 0          # lossy_links prices comm
    assert s["unique_clients"] >= 1
    assert any(p["name"] == "scan_chunk" and p["includes_compile"]
               for p in s["phases"])


def test_fleet_report_has_walker_table(tmp_path):
    run_dir = str(tmp_path / "fleet")
    smoke_run(run_dir, rounds=6, eval_every=3, fleet=3)
    report = render_report(run_dir)
    assert "== Walkers ==" in report
    s = summarize(run_dir)
    assert set(s["walkers"]) == {0, 1, 2}
    assert sum(w["visits"] for w in s["walkers"].values()) == 6


# ------------------------------------------------ manifest --------------
def test_manifest_determinism_under_fixed_seed(tmp_path):
    """Two runs of the same seeded workload agree on the deterministic
    manifest fingerprint (config/seed/git/jax/packages) even though run
    ids and timestamps differ; a different seed changes it."""
    t1 = smoke_run(str(tmp_path / "a"), rounds=2, eval_every=2)
    t2 = smoke_run(str(tmp_path / "b"), rounds=2, eval_every=2)
    assert t1.manifest["fingerprint"] == t2.manifest["fingerprint"]
    assert t1.manifest["fingerprint"] == manifest_fingerprint(t1.manifest)
    t3 = smoke_run(str(tmp_path / "c"), rounds=2, eval_every=2, seed=1)
    assert t3.manifest["fingerprint"] != t1.manifest["fingerprint"]
    # events are identical too: sorted keys, no wall-clock fields
    # outside phase spans and the wall_time_s counter
    def det(tel):
        return [e for e in read_events(tel.events_path)
                if e["t"] != "phase"
                and e.get("name") != "wall_time_s"]

    assert det(t1) == det(t2)


def test_manifest_atomic_and_updatable(tmp_path):
    run_dir = str(tmp_path / "m")
    tel = TelemetryRun(run_dir, seed=7, config={"a": 1})
    with open(tel.manifest_path) as f:
        m = json.load(f)
    assert m["seed"] == 7 and m["config"] == {"a": 1}
    assert m["status"] == "open"
    tel.update_manifest(config={"b": 2})
    tel.close()
    with open(tel.manifest_path) as f:
        m = json.load(f)
    assert m["config"] == {"a": 1, "b": 2}    # merged, not clobbered
    assert m["status"] == "finalized"
    assert not [p for p in os.listdir(run_dir) if p.endswith(".tmp")]
    with pytest.raises(TelemetryError, match="closed"):
        tel.emit("counter", name="x", value=1)


# ------------------------------------------------ artifacts -------------
def test_bench_rows_merge_by_identity(tmp_path):
    """BENCH rows merge by (name, n, K, engine): re-measuring one row
    updates it in place, rows differing only in n/K/engine coexist."""
    path = str(tmp_path / "bench.json")
    r1 = {"name": "x", "n": 10, "K": 1, "engine": "scan",
          "us_per_round": 1.0}
    r2 = {"name": "x", "n": 20, "K": 1, "engine": "scan",
          "us_per_round": 2.0}
    atomic_write_json(path, merge_bench_rows([], [r1, r2]))
    update = {**r1, "us_per_round": 9.0}
    rows = merge_bench_rows(load_bench_rows(path), [update])
    atomic_write_json(path, rows)
    out = load_bench_rows(path)
    assert len(out) == 2
    by_n = {r["n"]: r for r in out}
    assert by_n[10]["us_per_round"] == 9.0    # updated
    assert by_n[20]["us_per_round"] == 2.0    # preserved
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]


def test_write_bench_rows_is_atomic_and_merging(tmp_path):
    from benchmarks import common

    path = str(tmp_path / "BENCH.json")
    common.write_bench_rows(
        [{"name": "a", "n": 1, "K": 1, "engine": "e", "us_per_round": 1}],
        path)
    common.write_bench_rows(
        [{"name": "b", "n": 1, "K": 1, "engine": "e", "us_per_round": 2}],
        path)
    rows = load_bench_rows(path)
    assert {r["name"] for r in rows} == {"a", "b"}


# ------------------------------------------------ schema validator ------
def test_round_metrics_validator(fed):
    res = _run(fed, engine="eager", backend="dense", rounds=4)
    keys = validate_round_metrics(res.round_metrics)
    assert {"round", "comm_bytes", "client", "train_loss"} <= keys
    with pytest.raises(AssertionError, match="missing required"):
        validate_round_metrics([{"round": 0}])
    with pytest.raises(AssertionError, match="key set"):
        validate_round_metrics([
            {"round": 0, "comm_bytes": 1},
            {"round": 1, "comm_bytes": 1, "extra": 2}])
    with pytest.raises(AssertionError, match="expected int"):
        validate_round_metrics([{"round": 0, "comm_bytes": 1.5}])
    with pytest.raises(AssertionError, match="round=3"):
        validate_round_metrics([{"round": 3, "comm_bytes": 1}])


# ------------------------------------------------ baselines hook --------
def test_baseline_telemetry_hook(fed, tmp_path):
    """The FedAvg-family baselines record through the same hook, and the
    snapshot print path tolerates snapshots without 'acc'."""
    from repro.baselines import FedAvgTrainer

    data, model = fed
    with TelemetryRun(str(tmp_path / "fa"), seed=0) as tel:
        tr = FedAvgTrainer(model, data, clients_per_round=4,
                           local_steps=2, telemetry=tel)
        res = run_simulation(tr, rounds=3, eval_every=3, seed=0,
                             telemetry=tel, verbose=True)
    assert len(res.round_metrics) == 3
    b = split_by_type(read_events(tel.events_path))
    assert len(b["round"]) == 3
    assert b["snapshot"]
    assert tel.manifest["config"]["algo"] == "fedavg"


def test_snapshot_without_acc_does_not_crash(fed, tmp_path, capsys):
    """verbose snapshot formatting with eval-less snapshots (no 'acc'):
    regression for the KeyError-prone f-string."""
    from repro.fl import simulation as sim

    class NoAccTrainer:
        name = "noacc"

        def evaluate(self, state):
            return {"loss_global": 1.0}

        def _phase(self, name, **meta):
            from repro.telemetry import null_phase

            return null_phase()

    hist = []
    sim._snapshot(NoAccTrainer(), None, 5, 123, hist, True, "noacc")
    assert hist[0]["round"] == 5
    assert "acc" not in hist[0]


# ------------------------------------------------ lazy-plane counters ---
@pytest.fixture(scope="module")
def fed_lazy():
    """Same partition as ``fed`` but kept as a ClientDataFactory, for
    store-backed (client_plane='lazy') trainers."""
    from repro.data import factory_from_federated

    imgs, labels = make_image_dataset(400, seed=0)
    parts = pathological_split(labels, 8, seed=0)
    f = build_federated(imgs, labels, parts)
    model = get_model("mlr", (28, 28, 1))
    return factory_from_federated(f), model


def _make_lazy_trainer(fed_lazy, capacity):
    factory, model = fed_lazy
    return RWSADMMTrainer(model, factory, RWSADMMHparams(beta=10.0),
                          zone_size=4, batch_size=16,
                          solver="closed_form",
                          scenario=_scenario("dense"), seed=0,
                          store_capacity=capacity)


def _store_counter_events(events_path):
    from repro.fl.client_store import STORE_COUNTERS

    prefix = "client_store_"
    evs = [e for e in read_events(events_path)
           if e["t"] == "counter" and e["name"].startswith(prefix)]
    order = [prefix + k for k in STORE_COUNTERS]
    # one ensure call emits the four counters in STORE_COUNTERS order
    assert [e["name"] for e in evs] \
        == order * (len(evs) // len(order))
    return evs


@pytest.mark.parametrize("engine,capacity", [("eager", 5), ("scan", 8)])
def test_lazy_telemetry_on_is_bit_identical(fed_lazy, tmp_path, engine,
                                            capacity):
    """The store's hit/miss/evict/restore counters are host-side only:
    recording them must not change a lazy run (exact float equality),
    and the counter stream must actually be present."""
    from repro.fl.client_store import STORE_COUNTERS

    res_off = run_simulation(_make_lazy_trainer(fed_lazy, capacity),
                             rounds=8, eval_every=4, seed=0,
                             engine=engine)
    with TelemetryRun(str(tmp_path / engine), seed=0) as tel:
        res_on = run_simulation(_make_lazy_trainer(fed_lazy, capacity),
                                rounds=8, eval_every=4, seed=0,
                                engine=engine, telemetry=tel)
    for m0, m1 in zip(res_off.round_metrics, res_on.round_metrics):
        assert m0 == m1
    for h0, h1 in zip(res_off.history, res_on.history):
        assert h0 == h1
    names = {e["name"] for e in _store_counter_events(tel.events_path)}
    assert names == {f"client_store_{k}" for k in STORE_COUNTERS}


def test_lazy_store_counters_match_oracle(fed_lazy, fed, tmp_path):
    """Counter exactness: the recorded per-round deltas must equal an
    independent LRU-oracle replay of the schedule's visited set (raw
    padded zone rows — padding id 0 counts, by design), and the stream
    totals must equal the store's cumulative counters."""
    import collections

    import numpy as np

    from repro.fl.client_store import STORE_COUNTERS

    capacity, rounds = 5, 8
    tr = _make_lazy_trainer(fed_lazy, capacity)
    with TelemetryRun(str(tmp_path / "run"), seed=0) as tel:
        run_simulation(tr, rounds=rounds, eval_every=4, seed=0,
                       engine="eager", telemetry=tel)
    evs = _store_counter_events(tel.events_path)
    assert len(evs) == rounds * len(STORE_COUNTERS)
    got = [{k: evs[4 * r + j]["value"]
            for j, k in enumerate(STORE_COUNTERS)}
           for r in range(rounds)]
    totals = collections.Counter()
    for d in got:
        totals.update(d)
    assert dict(totals) == tr.store.counters
    assert totals["evictions"] > 0 and totals["restores"] > 0

    # Oracle: a dense twin's schedule replays the same walk draws, so
    # its padded zone rows are exactly what the lazy run ensured.
    twin = _make_trainer(fed, "dense")
    sched = twin.schedule(rounds, np.random.default_rng(0))
    oracle: collections.OrderedDict = collections.OrderedDict()
    spilled: set = set()
    expect = []
    for r in range(rounds):
        row = np.asarray(sched.idx)[r].reshape(-1)
        uniq = list(dict.fromkeys(int(i) for i in row))
        missing = [i for i in uniq if i not in oracle]
        d = {"hits": len(uniq) - len(missing), "misses": len(missing),
             "evictions": 0, "restores": 0}
        need = len(missing) - (capacity - len(oracle))
        if need > 0:
            victims = [i for i in oracle if i not in set(uniq)][:need]
            for v in victims:
                del oracle[v]
                spilled.add(v)
            d["evictions"] = need
        for i in missing:
            if i in spilled:
                d["restores"] += 1
                spilled.discard(i)
            oracle[i] = None
        for i in uniq:
            oracle.move_to_end(i)
        expect.append(d)
    assert got == expect
