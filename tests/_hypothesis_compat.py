"""Optional-hypothesis shim: property tests degrade to skips when
hypothesis is not installed (minimal environments), instead of aborting
collection of the whole module and losing its non-property tests.

Usage (in a test module):

    from _hypothesis_compat import hypothesis, st

``hypothesis.given/settings`` and the ``st`` strategies namespace behave
normally when hypothesis is importable; otherwise ``given`` replaces the
test with a zero-arg stub that calls ``pytest.skip``. Install the real
package via ``pip install -r requirements-dev.txt``.
"""
from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal envs
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    class _HealthCheck:
        too_slow = None

    class _Hypothesis:
        HealthCheck = _HealthCheck

        @staticmethod
        def settings(*a, **k):
            return lambda f: f

        @staticmethod
        def given(*a, **k):
            def deco(f):
                def stub():
                    pytest.skip("hypothesis not installed "
                                "(pip install -r requirements-dev.txt)")

                stub.__name__ = f.__name__
                stub.__doc__ = f.__doc__
                return stub

            return deco

    hypothesis = _Hypothesis()
    st = _Strategies()
