"""FL trainers: RWSADMM + all five baselines + Walkman learn on a small
non-IID problem; communication accounting matches the O(1) claim."""
import jax
import numpy as np
import pytest

from repro.baselines import (
    APFLTrainer,
    DittoTrainer,
    FedAvgTrainer,
    PerFedAvgTrainer,
    PFedMeTrainer,
    WalkmanTrainer,
)
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model


@pytest.fixture(scope="module")
def data():
    imgs, labels = make_image_dataset(1200, seed=0)
    idx = pathological_split(labels, 10, seed=0)
    fed = build_federated(imgs, labels, idx)
    return to_device_data(fed)


@pytest.fixture(scope="module")
def model():
    return get_model("mlr", (28, 28, 1))


def test_rwsadmm_learns_personalized(data, model):
    tr = RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5),
        zone_size=6, batch_size=32,
    )
    res = run_simulation(tr, rounds=80, eval_every=80, seed=0)
    assert res.final["acc_personalized"] > 0.75
    # visited clients have genuinely personalized (distinct) models
    assert res.final["acc_personalized"] >= res.final["acc_global"] - 0.02


def test_rwsadmm_closed_form_solver_runs(data, model):
    tr = RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        zone_size=4, solver="closed_form",
    )
    res = run_simulation(tr, rounds=40, eval_every=40, seed=0)
    assert np.isfinite(res.final["loss_personalized"])
    assert res.final["acc_personalized"] > 0.15  # beats random


@pytest.mark.parametrize("cls,kwargs,thresh", [
    (FedAvgTrainer, dict(lr=0.05, local_steps=10), 0.6),
    (PerFedAvgTrainer, dict(), 0.5),
    (PFedMeTrainer, dict(), 0.6),
    (DittoTrainer, dict(), 0.6),
    (APFLTrainer, dict(), 0.6),
])
def test_baselines_learn(data, model, cls, kwargs, thresh):
    tr = cls(model, data, clients_per_round=5, **kwargs)
    res = run_simulation(tr, rounds=60, eval_every=60, seed=0)
    assert res.final["acc"] > thresh, (cls.__name__, res.final)


def test_walkman_consensus_learns(data, model):
    # Walkman activates ONE client per round (the paper's O(1)/round
    # prior) — it needs many more rounds than zone-based RWSADMM.
    tr = WalkmanTrainer(model, data, beta=3.0)
    res = run_simulation(tr, rounds=900, eval_every=900, seed=0)
    assert res.final["acc_global"] > 0.35


def test_communication_o1_vs_on(data, model):
    """RWSADMM comm/round is (1 + |S|)·P — independent of n; FedAvg-family
    is 2·m·P with m clients/round."""
    hp = RWSADMMHparams(beta=1.0)
    rw = RWSADMMTrainer(model, data, hp, zone_size=3)
    fa = FedAvgTrainer(model, data, clients_per_round=10)
    assert rw.comm_bytes_per_round(1) < fa.comm_bytes_per_round(10) / 4
    # zone participation scales with S, not n
    assert rw.comm_bytes_per_round(3) == 4 * rw.comm_bytes_per_round(1) / 2


def test_rwsadmm_lyapunov_and_constraints(data, model):
    """After training, the hard-constraint residual is bounded and L_β is
    finite (Lemma 4.7 boundedness)."""
    tr = RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5),
        zone_size=6, batch_size=32,
    )
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    for r in range(50):
        state, _ = tr.round(state, r, rng)
    diag = tr.lyapunov(state, jax.random.PRNGKey(1))
    assert np.isfinite(diag["L_beta"])
    assert diag["violation"] < 1.0  # bounded deviation from the token


def test_simulation_records_history(data, model):
    tr = FedAvgTrainer(model, data, clients_per_round=3)
    res = run_simulation(tr, rounds=20, eval_every=5, seed=0)
    assert len(res.history) == 4
    rounds, accs = res.curve("acc")
    assert rounds[-1] == 20
    assert res.total_comm_bytes > 0
