"""Layer-3 fixtures: the compile counter sees exactly the real XLA
compilations, the drift comparator reports both directions, and an
injected retrace (cleared chunk cache between calls) fails loudly."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.compile_budget import compare_budget, compile_log
from repro.analysis.registry import CellSpec, build_cell


def test_counter_sees_one_compile_per_closure():
    def step_probe(x):
        return jnp.tanh(x) @ x.T

    with compile_log() as counts:
        fn = jax.jit(step_probe)
        fn(jnp.ones((7, 7)))
        fn(jnp.ones((7, 7)))      # cache hit: no second compile
    assert counts["step_probe"] == 1


def test_counter_sees_retrace_on_new_shape():
    def shape_probe(x):
        return x * 2.0

    with compile_log() as counts:
        fn = jax.jit(shape_probe)
        fn(jnp.ones((3,)))
        fn(jnp.ones((5,)))        # new shape: distinct compilation
    assert counts["shape_probe"] == 2


def test_compare_budget_reports_both_directions():
    golden = {"chunk": 6, "_round_impl": 2}
    assert compare_budget({"chunk": 6, "_round_impl": 2}, golden) == []
    up = compare_budget({"chunk": 7, "_round_impl": 2}, golden)
    assert len(up) == 1 and "retrace" in up[0]
    down = compare_budget({"chunk": 6}, golden)
    assert len(down) == 1 and "_round_impl" in down[0]
    new = compare_budget({"chunk": 6, "_round_impl": 2, "body": 1},
                         golden)
    assert len(new) == 1 and "body" in new[0]


def test_injected_retrace_fails_loudly():
    """Clearing the chunk-fn cache between two same-shape chunks is the
    canonical silent-retrace bug — the sentinel must see 2 compiles
    where the golden run sees 1."""
    trainer = build_cell(CellSpec("single", "dense", False))
    state = trainer.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    sched = trainer.schedule(3, rng)

    with compile_log() as counts:
        trainer.run_chunk(state, sched, engine="scan")
        trainer._chunk_fns.clear()          # the injected bug
        trainer.run_chunk(state, sched, engine="scan")
    measured = {"chunk": counts["chunk"]}
    assert measured["chunk"] == 2
    problems = compare_budget(measured, {"chunk": 1})
    assert len(problems) == 1 and "retrace" in problems[0]


def test_healthy_cache_stays_on_budget():
    trainer = build_cell(CellSpec("single", "dense", False))
    state = trainer.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    sched = trainer.schedule(3, rng)

    with compile_log() as counts:
        trainer.run_chunk(state, sched, engine="scan")
        trainer.run_chunk(state, sched, engine="scan")   # cache hit
    assert counts["chunk"] == 1
    assert compare_budget({"chunk": counts["chunk"]}, {"chunk": 1}) == []
