"""Unit tests for the RWSADMM core math (paper Eq. 9/10/11/13/14/15)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rwsadmm, tree
from repro.core.rwsadmm import RWSADMMHparams


@pytest.fixture
def hp():
    return RWSADMMHparams(beta=2.0, kappa=0.01, epsilon=1e-3)


def _rand_tree(key, like_shapes=((5,), (3, 4))):
    ks = jax.random.split(key, len(like_shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, like_shapes))}


def test_init_states_zero(hp):
    template = _rand_tree(jax.random.PRNGKey(0))
    client, server = rwsadmm.init_states(template, hp, n_clients=3)
    assert float(tree.sq_norm(client.x)) == 0.0
    assert float(tree.sq_norm(server.y)) == 0.0  # Eq. (32)
    # stacked leading axis
    assert client.x["p0"].shape == (3, 5)


def test_x_update_reduces_subproblem_objective(hp):
    """The derived x-update must (weakly) decrease the linearized
    subproblem objective of Eq. (10) vs staying at x'."""
    key = jax.random.PRNGKey(1)
    y = _rand_tree(key)
    x_prev = tree.add_scaled(y, _rand_tree(jax.random.PRNGKey(2)), 0.1)
    z = tree.scale(_rand_tree(jax.random.PRNGKey(3)), 0.01)
    g = _rand_tree(jax.random.PRNGKey(4))

    def obj(x):
        beta, eps = hp.beta, hp.eps_half
        val = tree.dot(g, tree.sub(x, x_prev))
        r = jax.tree_util.tree_map(
            lambda yy, xx: jnp.abs(yy - xx) - eps, y, x)
        val += tree.dot(z, r)
        val += (beta / 2.0) * tree.sq_norm(r)
        return float(val)

    x_new = rwsadmm.x_update(y, x_prev, z, g, hp)
    assert obj(x_new) <= obj(x_prev) + 1e-6


def test_x_update_first_visit_is_prox_gradient_step(hp):
    """With x' = y (t' = 0) and z = 0, the derived solver reduces to the
    stochastic proximal step x = y − g/β."""
    y = _rand_tree(jax.random.PRNGKey(0))
    z = tree.zeros_like(y)
    g = _rand_tree(jax.random.PRNGKey(5))
    x_new = rwsadmm.x_update(y, y, z, g, hp)
    expected = tree.add_scaled(y, g, -1.0 / hp.beta)
    np.testing.assert_allclose(
        tree.flatten(x_new), tree.flatten(expected), rtol=1e-6)


def test_literal_eq11_degenerate_at_init(hp):
    """Documents the paper bug: the printed Eq. (11) with the paper's own
    initialization (t' = 0) produces x = y' — no movement, ever."""
    y = _rand_tree(jax.random.PRNGKey(0))
    g = _rand_tree(jax.random.PRNGKey(5))
    x_new = rwsadmm.x_update(y, y, tree.zeros_like(y), g, hp,
                             literal_eq11=True)
    np.testing.assert_allclose(tree.flatten(x_new), tree.flatten(y))


def test_z_update_matches_eq15(hp):
    x = _rand_tree(jax.random.PRNGKey(6))
    y = _rand_tree(jax.random.PRNGKey(7))
    z = _rand_tree(jax.random.PRNGKey(8))
    kappa = 0.5
    z_new = rwsadmm.z_update(x, y, z, hp, kappa)
    expected = jax.tree_util.tree_map(
        lambda zz, xx, yy: zz + kappa * hp.beta * (xx - yy - hp.eps_half),
        z, x, y)
    np.testing.assert_allclose(
        tree.flatten(z_new), tree.flatten(expected), rtol=1e-6)


def test_y_update_maintains_running_average(hp):
    """y must track (1/n)Σ c_j under incremental replacement (the Eq. 32
    invariant; see y_update docstring on the 1/n vs 1/n_i fix)."""
    n = 6
    key = jax.random.PRNGKey(9)
    contribs = [_rand_tree(jax.random.fold_in(key, i)) for i in range(n)]
    y = tree.mean(contribs)
    # replace contribution of client 2
    new_c2 = _rand_tree(jax.random.fold_in(key, 100))
    y_new = rwsadmm.y_update(y, new_c2, contribs[2], n_total=n)
    contribs[2] = new_c2
    np.testing.assert_allclose(
        tree.flatten(y_new), tree.flatten(tree.mean(contribs)), rtol=1e-5)


def test_zone_round_masks_and_shapes(hp):
    """Multi-client zone update (Eq. 31): stacked states update, y folds."""
    template = _rand_tree(jax.random.PRNGKey(0))
    client, server = rwsadmm.init_states(template, hp, n_clients=4)
    grads = jax.tree_util.tree_map(
        lambda l: jnp.ones((4,) + l.shape[1:], l.dtype), client.x)
    new_clients, y_new = rwsadmm.zone_round(
        client, server.y, grads, hp, kappa=0.01, n_total=10)
    assert new_clients.x["p0"].shape == (4, 5)
    assert not bool(tree.any_nan(y_new))


def test_subproblem_grad_zero_at_unconstrained_min(hp):
    """∇F from Eq. (9) with z=0, ε=0: zero iff g + β(x−y) = 0."""
    hp0 = RWSADMMHparams(beta=2.0, kappa=0.0, epsilon=0.0)
    y = _rand_tree(jax.random.PRNGKey(1))
    g = _rand_tree(jax.random.PRNGKey(2))
    x_star = tree.add_scaled(y, g, -1.0 / hp0.beta)
    gf = rwsadmm.subproblem_grad(x_star, y, tree.zeros_like(y), g, hp0)
    assert float(tree.linf(gf)) < 1e-5


def test_constraint_violation_metric(hp):
    y = {"p": jnp.zeros((4,))}
    xs = {"p": jnp.stack([jnp.full((4,), 0.0), jnp.full((4,), 1.0)])}
    v = rwsadmm.constraint_violation(y, xs, hp)
    assert abs(float(v) - (1.0 - hp.eps_half)) < 1e-6


def test_beta_lower_bound():
    assert rwsadmm.beta_lower_bound(1.0) == 5.0  # 2L²+L+2


def test_convergence_on_convex_quadratics():
    """End-to-end core sanity: RWSADMM on n quadratic clients converges to
    a point where the average gradient at y vanishes (Theorem 4.8's
    stationarity) and the hard constraints are satisfied."""
    n, d = 6, 8
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    hp = RWSADMMHparams(beta=4.0, kappa=0.01, epsilon=1e-3)

    template = {"w": jnp.zeros((d,))}
    client, server = rwsadmm.init_states(template, hp, n_clients=n)
    kappa = hp.kappa
    y = server.y
    for k in range(600):
        i = k % n  # cyclic visiting (a valid ergodic chain)
        xi = jax.tree_util.tree_map(lambda l: l[i], client.x)
        zi = jax.tree_util.tree_map(lambda l: l[i], client.z)
        grad = {"w": xi["w"] - targets[i]}
        (new_c, c_new, c_old) = rwsadmm.client_round(
            rwsadmm.ClientState(xi, zi), y, grad, hp, kappa)
        y = rwsadmm.y_update(y, c_new, c_old, n_total=n)
        client = rwsadmm.ClientState(
            x=jax.tree_util.tree_map(
                lambda full, newv: full.at[i].set(newv),
                client.x, new_c.x),
            z=jax.tree_util.tree_map(
                lambda full, newv: full.at[i].set(newv),
                client.z, new_c.z),
        )
        kappa *= hp.kappa_decay
    avg_grad = jnp.mean(client.x["w"] - targets, axis=0)
    assert float(jnp.max(jnp.abs(avg_grad))) < 0.05
    # personalized x_i stay close to their targets relative to consensus
    mean_target = jnp.mean(targets, axis=0)
    err_pers = float(jnp.mean(jnp.abs(client.x["w"] - targets)))
    err_consensus = float(jnp.mean(jnp.abs(mean_target[None] - targets)))
    assert err_pers < err_consensus  # personalization beats consensus
