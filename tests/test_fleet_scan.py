"""Compiled fleet driver: K-walker schedules, stacked-token scan chunks,
and the batched multi-zone kernel must reproduce the eager fleet exactly.

Covers the acceptance bar: run_chunk(engine=scan|scan_fused) trajectory-
identical to eager for K ∈ {1, 3, 5} across mobility × links × churn
scenarios, plus the fleet degenerate cases (n_walkers=1 ≡ single-walker
trainer, sync_every → ∞, walker-order-invariant rendezvous), the fleet
hitting time, the multi-zone kernel vs its jnp oracle, and the opt-in
batched walk sampler's seed-stability pin.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import markov, rwsadmm
from repro.core.graph import DynamicGraph
from repro.core.markov import RandomWalkServer
from repro.core.rwsadmm import ClientState, RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.fleet_trainer import FleetRWSADMMTrainer
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model

ROUNDS = 13  # chunk split (6, 7) crosses the regen epoch at round 10


@pytest.fixture(scope="module")
def fed():
    imgs, labels = make_image_dataset(600, seed=0)
    parts = pathological_split(labels, 10, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))
    return data, model


def make_fleet(fed, n_walkers=3, mode="roundrobin", scenario=None,
               sync_every=7, **kw):
    data, model = fed
    return FleetRWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        n_walkers=n_walkers, sync_every=sync_every, fleet_mode=mode,
        zone_size=4, batch_size=20, regen_every=10, solver="closed_form",
        scenario=scenario, seed=0, **kw)


def run_eager(tr, rounds=ROUNDS):
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    metrics = []
    for r in range(rounds):
        state, m = tr.round(state, r, rng)
        metrics.append(m)
    return state, metrics


def run_scan(tr, engine, chunks=(6, 7)):
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    metrics = []
    r = 0
    for n in chunks:
        sched = tr.schedule(n, rng, start_round=r)
        state, stacked = tr.run_chunk(state, sched, engine=engine)
        metrics.extend(tr.chunk_round_metrics(sched, stacked, r))
        r += n
    return state, metrics


def assert_trees_equal(a, b, atol=0.0):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        if atol:
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=atol)
        else:
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------ acceptance: scan≡eager --
@pytest.mark.slow
@pytest.mark.parametrize("n_walkers", [1, 3, 5])
@pytest.mark.parametrize("mode", ["roundrobin", "simultaneous"])
def test_fleet_scan_equals_eager(fed, n_walkers, mode):
    """Bit-identical trajectories (clients, tokens, visited, metrics incl.
    latency/energy) between the eager fleet and the compiled scan, chunk
    boundary crossing a regen epoch and a rendezvous."""
    st_e, me = run_eager(make_fleet(fed, n_walkers, mode))
    st_s, ms = run_scan(make_fleet(fed, n_walkers, mode), "scan")
    assert_trees_equal(st_e.base.clients, st_s.base.clients)
    assert_trees_equal(st_e.tokens, st_s.tokens)
    np.testing.assert_array_equal(np.asarray(st_e.base.visited),
                                  np.asarray(st_s.base.visited))
    assert int(st_s.base.server.round) == ROUNDS
    for a, b in zip(me, ms):
        assert set(a) == set(b), (sorted(a), sorted(b))
        for key in a:
            assert a[key] == b[key], (key, a[key], b[key])


SCENARIOS = ["random_waypoint", "lossy_links", "duty_cycle", "field_trial"]


@pytest.mark.slow
@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("mode", ["roundrobin", "simultaneous"])
def test_fleet_scan_equals_eager_under_scenario(fed, scenario, mode):
    """The whole environment (mobility, link dropouts, churn) is host-side
    control plane: the compiled fleet must replay the eager fleet under
    every scenario, availability masks composing with the K zones."""
    st_e, me = run_eager(make_fleet(fed, 3, mode, scenario))
    st_s, ms = run_scan(make_fleet(fed, 3, mode, scenario), "scan")
    assert_trees_equal(st_e.base.clients, st_s.base.clients)
    assert_trees_equal(st_e.tokens, st_s.tokens)
    np.testing.assert_array_equal(np.asarray(st_e.base.visited),
                                  np.asarray(st_s.base.visited))
    for a, b in zip(me, ms):
        assert set(a) == set(b)
        assert a["train_loss"] == b["train_loss"]
        assert a["latency_s"] == b["latency_s"]
        assert a["energy_j"] == b["energy_j"]
        assert a["comm_bytes"] == b["comm_bytes"]


@pytest.mark.parametrize("mode", ["roundrobin", "simultaneous"])
def test_fleet_scan_fused_matches_eager(fed, mode):
    """scan_fused (the multi-zone Pallas kernel in simultaneous mode,
    the masked zone kernel in round-robin) tracks the eager fleet to fp
    tolerance."""
    st_e, me = run_eager(make_fleet(fed, 3, mode))
    st_f, mf = run_scan(make_fleet(fed, 3, mode), "scan_fused",
                        chunks=(ROUNDS,))
    assert_trees_equal(st_e.base.clients.x, st_f.base.clients.x, atol=5e-6)
    assert_trees_equal(st_e.tokens, st_f.tokens, atol=5e-6)
    np.testing.assert_allclose([m["train_loss"] for m in me],
                               [m["train_loss"] for m in mf], atol=1e-4)


def test_fleet_run_simulation_engines_agree(fed):
    """run_simulation(engine='scan') accepts the fleet and reproduces the
    eager history, totals, and per-round schema."""
    def mk():
        return make_fleet(fed, 3, "roundrobin", "field_trial")

    res_e = run_simulation(mk(), rounds=12, eval_every=6, seed=0)
    res_s = run_simulation(mk(), rounds=12, eval_every=6, seed=0,
                           engine="scan")
    assert [h["round"] for h in res_e.history] \
        == [h["round"] for h in res_s.history]
    for he, hs in zip(res_e.history, res_s.history):
        np.testing.assert_allclose(he["acc_personalized"],
                                   hs["acc_personalized"], atol=1e-6)
    assert res_e.total_comm_bytes == res_s.total_comm_bytes
    assert res_e.total_latency_s == res_s.total_latency_s
    assert res_e.total_energy_j == res_s.total_energy_j
    for a, b in zip(res_e.round_metrics, res_s.round_metrics):
        assert set(a) == set(b)
        assert a["walker"] == b["walker"]
        assert a["client"] == b["client"]


# ------------------------------------------------- degenerate cases ------
def test_single_walker_fleet_matches_single_trainer(fed):
    """n_walkers=1 degenerates to the single-walker RWSADMM trajectory
    exactly: same walk stream (walker 0 reuses seed+1), same zone plans,
    same key stream (one shared derivation helper), same updates."""
    data, model = fed
    hp = RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5)
    single = RWSADMMTrainer(model, data, hp, zone_size=4, batch_size=20,
                            regen_every=10, solver="closed_form", seed=0)
    fleet = make_fleet(fed, n_walkers=1, sync_every=10**9)
    rng_s, rng_f = np.random.default_rng(0), np.random.default_rng(0)
    st_s = single.init_state(jax.random.PRNGKey(0))
    st_f = fleet.init_state(jax.random.PRNGKey(0))
    for r in range(15):
        st_s, m_s = single.round(st_s, r, rng_s)
        st_f, m_f = fleet.round(st_f, r, rng_f)
        assert m_s["client"] == m_f["client"]
        assert m_s["train_loss"] == m_f["train_loss"]
    assert_trees_equal(st_s.clients, st_f.base.clients)
    assert_trees_equal(st_s.server.y,
                       jax.tree_util.tree_map(lambda t: t[0], st_f.tokens))
    np.testing.assert_array_equal(np.asarray(st_s.visited),
                                  np.asarray(st_f.base.visited))


def test_sync_every_inf_gives_independent_tokens(fed):
    """sync_every → ∞: no rendezvous ever fires, so any two no-sync
    horizons agree (the trajectory is sync-free) while a syncing fleet
    diverges from it; the walkers' tokens stay distinct streams."""
    st_a, _ = run_eager(make_fleet(fed, 3, sync_every=10**9))
    st_b, _ = run_eager(make_fleet(fed, 3, sync_every=ROUNDS + 5))
    st_c, _ = run_eager(make_fleet(fed, 3, sync_every=5))
    assert_trees_equal(st_a.tokens, st_b.tokens)
    leaves_a = np.concatenate([np.asarray(l).reshape(3, -1)
                               for l in jax.tree_util.tree_leaves(
                                   st_a.tokens)], axis=1)
    leaves_c = np.concatenate([np.asarray(l).reshape(3, -1)
                               for l in jax.tree_util.tree_leaves(
                                   st_c.tokens)], axis=1)
    # without sync the K token streams are genuinely distinct...
    assert not np.allclose(leaves_a[0], leaves_a[1])
    # ...and differ from the rendezvousing fleet's (which just averaged
    # at round 10, so its walkers still agree more than the free-running
    # fleet's do).
    assert not np.allclose(leaves_a, leaves_c)


def test_rendezvous_mean_is_walker_permutation_invariant(fed):
    """The rendezvous operator (jnp.mean over the stacked walker axis)
    must not depend on walker order."""
    from repro.fl.fleet_trainer import _rendezvous

    st, _ = run_eager(make_fleet(fed, 3, sync_every=10**9), rounds=9)
    sync = jnp.asarray(1.0)
    for perm in ([1, 2, 0], [2, 0, 1], [2, 1, 0]):
        permuted = jax.tree_util.tree_map(
            lambda t: t[jnp.asarray(perm)], st.tokens)
        a = _rendezvous(st.tokens, sync)
        b = _rendezvous(permuted, sync)
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       atol=1e-6)


def test_fleet_hitting_time_covers_faster(fed):
    """The union-coverage wall clock drops with K, and the scan schedule
    (which batch-steps the walkers) reports the same hitting time as the
    eager driver (identical per-walker streams)."""
    def coverage(n_walkers, driver):
        tr = make_fleet(fed, n_walkers, "simultaneous")
        rng = np.random.default_rng(0)
        if driver == "scan":
            tr.schedule(200, rng, start_round=0)
        else:
            state = tr.init_state(jax.random.PRNGKey(0))
            for r in range(200):
                state, _ = tr.round(state, r, rng)
        return tr.fleet_hitting_time()

    t1 = coverage(1, "scan")
    t3 = coverage(3, "scan")
    assert t1 is not None and t3 is not None and t3 < t1
    assert coverage(3, "eager") == t3


# --------------------------------------------- multi-zone kernel/oracle --
def test_multizone_kernel_matches_oracle():
    from repro.kernels.rwsadmm_update.ops import (
        rwsadmm_multizone_fused_update,
    )

    hp = RWSADMMHparams(beta=4.0, kappa=0.02, epsilon=1e-4)
    K, Z = 3, 5
    template = {"w": jnp.zeros((K, Z, 37, 5)), "b": jnp.zeros((K, Z, 11))}
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    mk = lambda k: jax.tree_util.tree_map(
        lambda l: jax.random.normal(jax.random.fold_in(k, l.ndim),
                                    l.shape), template)
    x, z, g = mk(ks[0]), mk(ks[1]), mk(ks[2])
    y = jax.tree_util.tree_map(lambda l: l[:, 0] * 0.5, mk(ks[3]))
    mask = jnp.asarray(
        np.random.default_rng(0).integers(0, 2, (K, Z)).astype(np.float32))

    ref_c, ref_y = rwsadmm.multizone_round_masked(
        ClientState(x=x, z=z), y, g, mask, hp, 0.02, n_total=9.0)
    xk, zk, yk = rwsadmm_multizone_fused_update(
        x, z, y, g, mask, 0.02, beta=hp.beta, eps_half=hp.eps_half,
        n_total=9.0)
    assert_trees_equal(ref_c.x, xk, atol=1e-6)
    assert_trees_equal(ref_c.z, zk, atol=1e-6)
    assert_trees_equal(ref_y, yk, atol=1e-6)
    # masked-out slots pass x/z through untouched
    keep = np.asarray(mask) == 0.0
    np.testing.assert_array_equal(np.asarray(xk["b"])[keep],
                                  np.asarray(x["b"])[keep])


@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_fleet_fast_path_bit_identical_to_loop(backend):
    """The vectorized no-conflict fast path must reproduce the
    sequential conflict-resolving loop exactly on both graph backends —
    across a window that exercises BOTH regimes (a small crowded graph
    forces overlaps/fallbacks; later rounds plan disjoint zones), with
    churn masks composing and the shared rng replaying draw-for-draw."""
    import dataclasses

    from repro.scenarios import Scenario, get_scenario_config

    cfg = dataclasses.replace(get_scenario_config("duty_cycle"),
                              graph_backend=backend, neighbor_k_max=28)

    def build(fast_path):
        sc = Scenario(28, cfg, seed=2)
        walkers = [RandomWalkServer(seed=60 + 10 * k) for k in range(3)]
        for w in walkers:
            w.reset(sc.current())
        rng = np.random.default_rng(1)
        return markov.fleet_zone_schedule(
            sc, walkers, 50, 4, rng, mode="simultaneous", sync_every=9,
            fast_path=fast_path)

    fast, loop = build(True), build(False)
    np.testing.assert_array_equal(fast.idx, loop.idx)
    np.testing.assert_array_equal(fast.mask, loop.mask)
    np.testing.assert_array_equal(fast.n_i, loop.n_i)
    np.testing.assert_array_equal(fast.clients, loop.clients)
    np.testing.assert_array_equal(fast.keys, loop.keys)


def test_fleet_fast_path_covers_both_regimes():
    """Directly exercise the fast path's two outcomes: overlapping
    walkers → None (caller falls back to the conflict loop); disjoint
    walkers → exactly the loop's plan with identical rng consumption,
    including an oversubscribed zone's subsample draw."""
    g = DynamicGraph(40, min_degree=8, seed=3).current()
    # two walkers on the same client: overlap by construction
    assert markov._plan_fleet_round_fast(
        g, np.asarray([4, 4, 20]), 4, np.random.default_rng(0)) is None
    # walkers with disjoint neighborhoods (found by scanning): fast plan
    # must equal the loop plan and leave the rng in the same state
    disjoint = None
    for a in range(40):
        for b in range(40):
            na = set(g.neighborhood(a))
            nb = set(g.neighborhood(b))
            if a != b and not (na & nb):
                disjoint = (a, b)
                break
        if disjoint:
            break
    assert disjoint is not None, "graph too dense for the test setup"
    positions = np.asarray(disjoint)
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    fast = markov._plan_fleet_round_fast(g, positions, 4, r1)
    loop = markov.plan_fleet_zone_round(g, positions, 4, r2)
    assert fast is not None
    for a, b in zip(fast, loop):
        np.testing.assert_array_equal(a, b)
    assert r1.random() == r2.random()      # identical rng consumption


def test_plan_fleet_zone_round_disjoint_and_deterministic():
    """K zones are pairwise disjoint (lowest walker index wins conflicts)
    and the plan replays draw-for-draw from the same rng state."""
    g = DynamicGraph(20, min_degree=6, seed=3).current()
    positions = np.asarray([4, 4, 11])   # walkers 0 and 1 collide
    idx1, mask1, n1 = markov.plan_fleet_zone_round(
        g, positions, 4, np.random.default_rng(5))
    idx2, mask2, n2 = markov.plan_fleet_zone_round(
        g, positions, 4, np.random.default_rng(5))
    np.testing.assert_array_equal(idx1, idx2)
    np.testing.assert_array_equal(mask1, mask2)
    live = idx1[mask1 > 0]
    assert len(live) == len(set(live.tolist()))   # disjoint across zones
    # walker 0 owns the contested position; walker 1 does not serve it
    assert 4 in idx1[0][mask1[0] > 0]
    assert 4 not in idx1[1][mask1[1] > 0]


# --------------------------------------- batched walk sampling (opt-in) --
def test_batched_walk_seed_stability_pin():
    """The inverse-CDF sampler is an RNG-stream break from step();
    pin its stream for a fixed seed so it can never drift silently."""
    g = DynamicGraph(12, min_degree=4, seed=7)
    w = RandomWalkServer(seed=11)
    w.reset(g.current())
    graphs = g.schedule(10, include_current=True)
    batch = w.walk_schedule_batched(graphs, advance_first=False)
    assert batch[0] == w.history[0]
    np.testing.assert_array_equal(
        batch, np.asarray([1, 5, 7, 0, 2, 9, 0, 2, 9, 7]))


def test_batched_walk_chunks_compose():
    """random(a) then random(b) equals random(a+b) for PCG64: chunked
    batched-walk schedules replay one long schedule draw-for-draw."""
    def walk(chunks):
        g = DynamicGraph(15, min_degree=4, regen_every=5, seed=2)
        w = RandomWalkServer(seed=9)
        w.reset(g.current())
        out = []
        first = True
        for n in chunks:
            graphs = g.schedule(n, include_current=first)
            out.append(w.walk_schedule_batched(graphs,
                                               advance_first=not first))
            first = False
        return np.concatenate(out)

    np.testing.assert_array_equal(walk([12]), walk([5, 7]))


# ------------------------------------------- biased walk policies -------
@pytest.mark.parametrize("mode", ["roundrobin", "simultaneous"])
@pytest.mark.parametrize("policy", ["staleness", "label_skew"])
def test_fleet_scan_equals_eager_biased_policy(fed, policy, mode):
    """K=3 fleets with importance-biased walks: the scan engine replays
    the eager fleet bit-for-bit with the iw correction threaded through
    both modes ((R,) column in round-robin, (R, K) in simultaneous)."""
    kw = dict(walk_policy=policy, walk_bias=1.5)
    st_e, me = run_eager(make_fleet(fed, 3, mode, **kw))
    st_s, ms = run_scan(make_fleet(fed, 3, mode, **kw), "scan")
    assert_trees_equal(st_e.base.clients, st_s.base.clients)
    assert_trees_equal(st_e.tokens, st_s.tokens)
    np.testing.assert_array_equal(np.asarray(st_e.base.visited),
                                  np.asarray(st_s.base.visited))
    for a, b in zip(me, ms):
        assert set(a) == set(b), (sorted(a), sorted(b))
        for key in a:
            assert a[key] == b[key], (key, a[key], b[key])
    # the biased policy propagated to every fleet walker
    tr = make_fleet(fed, 3, mode, **kw)
    run_eager(tr, rounds=3)
    for w in tr.walkers:
        assert w.policy == policy and w.is_biased


def test_fleet_schedule_iw_shapes(fed):
    """The schedule the trainers consume carries the documented iw
    shapes: (R,) round-robin, (R, K) simultaneous, None when uniform."""
    rounds = 8
    for mode, shape in (("roundrobin", (rounds,)),
                        ("simultaneous", (rounds, 3))):
        tr = make_fleet(fed, 3, mode, walk_policy="staleness")
        sched = tr.schedule(rounds, np.random.default_rng(0))
        assert sched.iw is not None and sched.iw.shape == shape
        tr_u = make_fleet(fed, 3, mode)
        assert tr_u.schedule(rounds, np.random.default_rng(0)).iw is None


# ------------------------------------------- staleness round metrics ----
@pytest.mark.parametrize("mode", ["roundrobin", "simultaneous"])
def test_fleet_staleness_metrics_pinned(fed, mode):
    """K=3 fleet staleness metrics: eager == scan exactly, and both
    match an oracle replay of the served sets (the (K, Z) simultaneous
    zones flatten through the same mask > 0 indexing)."""
    rounds = 9
    st_e, me = run_eager(make_fleet(fed, 3, mode), rounds=rounds)

    tr = make_fleet(fed, 3, mode)
    rng = np.random.default_rng(0)
    state = tr.init_state(jax.random.PRNGKey(0))
    sched = tr.schedule(rounds, rng, start_round=0)
    state, stacked = tr.run_chunk(state, sched, engine="scan")
    ms = tr.chunk_round_metrics(sched, stacked, 0)

    last = np.full(tr.n_clients, -1, dtype=np.int64)
    for r, (a, b) in enumerate(zip(me, ms)):
        served = np.asarray(sched.idx[r])[np.asarray(sched.mask[r]) > 0]
        last[served] = r
        stale = r - last
        for m in (a, b):
            assert m["staleness_p50"] == float(np.median(stale))
            assert m["staleness_max"] == int(stale.max())
    # K zones serve more clients per wall step than one walker: by the
    # end of the window the fleet's staleness_max is no worse than the
    # single-walker trainer's at the same round (same seeds).
    from repro.fl.rwsadmm_trainer import RWSADMMTrainer
    data, model = fed
    single = RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=10.0, kappa=0.001, epsilon=1e-5),
        zone_size=4, batch_size=20, regen_every=10, solver="closed_form",
        seed=0)
    rng = np.random.default_rng(0)
    st = single.init_state(jax.random.PRNGKey(0))
    for r in range(rounds):
        st, m_single = single.round(st, r, rng)
    if mode == "simultaneous":
        assert ms[-1]["staleness_max"] <= m_single["staleness_max"]


def test_batched_walk_trainer_flag_round_trips(fed):
    """batched_walk=True flows trainer → schedule → walker; scan chunks
    still compose with themselves (self-consistent stream)."""
    def run(chunks):
        tr = make_fleet(fed, 3, batched_walk=True)
        rng = np.random.default_rng(0)
        state = tr.init_state(jax.random.PRNGKey(0))
        losses = []
        r = 0
        for n in chunks:
            sched = tr.schedule(n, rng, start_round=r)
            state, stacked = tr.run_chunk(state, sched, engine="scan")
            losses.extend(np.asarray(stacked["train_loss"]).tolist())
            r += n
        return np.asarray(losses)

    np.testing.assert_array_equal(run([12]), run([5, 7]))
