"""Coverage: optimizer substrate + the paper's CNN model in the FL loop
(CIFAR-shaped data)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam, constant, cosine, sgd, step_decay


def _rosenbrock_ish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1.0 - x) ** 2) + 5.0 * jnp.sum((y - x**2) ** 2)


@pytest.mark.parametrize("opt_name,steps,tol", [
    ("sgd", 1500, 0.3),           # plain SGD is slow on the curved valley
    ("sgd_momentum", 500, 0.05),
    ("adam", 400, 0.05),
])
def test_optimizers_converge_on_quadratic(opt_name, steps, tol):
    opt = {
        "sgd": sgd(0.02),
        "sgd_momentum": sgd(0.02, momentum=0.9),
        "adam": adam(0.05),
    }[opt_name]
    params = {"x": jnp.zeros((3,)), "y": jnp.zeros((3,))}
    state = opt.init(params)
    grad_fn = jax.grad(_rosenbrock_ish)

    @jax.jit
    def step(params, state):
        g = grad_fn(params)
        return opt.update(g, state, params)

    for _ in range(steps):
        params, state = step(params, state)
    assert float(_rosenbrock_ish(params)) < tol


def test_schedules():
    assert float(constant(0.1)(jnp.asarray(100))) == pytest.approx(0.1)
    sd = step_decay(1.0, decay=0.5, every=10)
    assert float(sd(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(sd(jnp.asarray(10))) == pytest.approx(0.5)
    cs = cosine(1.0, total_steps=100, final_frac=0.1)
    assert float(cs(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cs(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
    assert float(cs(jnp.asarray(50))) < 1.0


def test_weight_decay_shrinks_params():
    opt = sgd(0.1, weight_decay=0.1)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    g = {"w": jnp.zeros((4,))}
    params, state = opt.update(g, state, params)
    assert float(params["w"][0]) < 1.0


# ---------------------------------------------------------------- CNN -----
def test_cnn_rwsadmm_on_cifar_like():
    """The paper's third model (CNN) through the full RWSADMM loop on
    CIFAR-shaped synthetic data."""
    from repro.core.rwsadmm import RWSADMMHparams
    from repro.data import make_image_dataset, pathological_split
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data
    from repro.fl.rwsadmm_trainer import RWSADMMTrainer
    from repro.fl.simulation import run_simulation
    from repro.models.small import get_model

    imgs, labels = make_image_dataset(
        600, shape=(32, 32, 3), noise=0.6, seed=0)
    parts = pathological_split(labels, 6, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("cnn", (32, 32, 3))
    tr = RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5),
        zone_size=3, batch_size=16, inner_steps=5)
    res = run_simulation(tr, rounds=25, eval_every=25, seed=0)
    assert np.isfinite(res.final["loss_personalized"])
    assert res.final["acc_personalized"] > 0.25  # above 10% chance


def test_cnn_dropout_train_vs_eval():
    from repro.models.small import get_model

    model = get_model("cnn", (28, 28, 1))
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 28, 28, 1))
    e1 = model.apply(params, x, train=False)
    e2 = model.apply(params, x, train=False)
    np.testing.assert_allclose(e1, e2)  # eval is deterministic
    t1 = model.apply(params, x, train=True, rng=jax.random.PRNGKey(2))
    t2 = model.apply(params, x, train=True, rng=jax.random.PRNGKey(3))
    assert float(jnp.max(jnp.abs(t1 - t2))) > 0.0  # dropout active
