"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
vs the pure-jnp oracles + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st

from repro.core import tree as T
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwsadmm_update.ops import rwsadmm_fused_update
from repro.kernels.rwsadmm_update.ref import rwsadmm_fused_update_ref

HYP = dict(max_examples=15, deadline=None,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])


# ------------------------------------------------------- rwsadmm_update ---
@pytest.mark.parametrize("n", [128, 8192, 8192 + 17, 100_003])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwsadmm_update_shapes_dtypes(n, dtype):
    key = jax.random.PRNGKey(n)
    ks = jax.random.split(key, 4)
    mk = lambda k: jax.random.normal(k, (n,), jnp.float32).astype(dtype)
    x, z, y, g = (mk(k) for k in ks)
    xt = {"w": x}
    xk, zk, yk = rwsadmm_fused_update(
        xt, {"w": z}, {"w": y}, {"w": g}, 0.01,
        beta=2.0, eps_half=5e-4, n_total=8.0)
    xr, zr, yr = rwsadmmref(x, z, y, g, dtype)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(xk["w"], np.float32), xr,
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(zk["w"], np.float32), zr,
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(yk["w"], np.float32), yr,
                               atol=tol, rtol=tol)


def rwsadmmref(x, z, y, g, dtype):
    xr, zr, yr = rwsadmm_fused_update_ref(
        x, z, y, g, jnp.asarray(0.01, dtype),
        beta=2.0, eps_half=5e-4, n_total=8.0)
    return (np.asarray(xr, np.float32), np.asarray(zr, np.float32),
            np.asarray(yr, np.float32))


def test_rwsadmm_update_multi_leaf_pytree():
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (33, 7)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                         (5, 4, 3))}}
    z = T.scale(tree, 0.1)
    y = T.add_scaled(tree, tree, 0.05)
    g = T.scale(tree, 0.3)
    xk, zk, yk = rwsadmm_fused_update(tree, z, y, g, 0.02,
                                      beta=4.0, eps_half=1e-5, n_total=20.0)
    xr, zr, yr = rwsadmm_fused_update_ref(
        T.flatten(tree), T.flatten(z), T.flatten(y), T.flatten(g), 0.02,
        beta=4.0, eps_half=1e-5, n_total=20.0)
    np.testing.assert_allclose(T.flatten(xk), xr, atol=1e-6)
    np.testing.assert_allclose(T.flatten(yk), yr, atol=1e-6)
    assert jax.tree_util.tree_structure(xk) \
        == jax.tree_util.tree_structure(tree)


@hypothesis.settings(**HYP)
@hypothesis.given(
    n=st.integers(min_value=1, max_value=5000),
    beta=st.floats(min_value=0.5, max_value=100.0),
    kappa=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rwsadmm_update_property(n, beta, kappa, seed):
    """Property: kernel == oracle for arbitrary sizes/hparams, and with
    g=0, z=0, ε=0 the update is a fixed point (x=y stays)."""
    key = jax.random.PRNGKey(seed)
    y = jax.random.normal(key, (n,))
    x, z, g = y, jnp.zeros((n,)), jnp.zeros((n,))
    xk, zk, yk = rwsadmm_fused_update(
        {"w": x}, {"w": z}, {"w": y}, {"w": g}, kappa,
        beta=beta, eps_half=0.0, n_total=5.0)
    np.testing.assert_allclose(xk["w"], y, atol=1e-6)
    np.testing.assert_allclose(yk["w"], y, atol=1e-6)


# --------------------------------------------------------- flash_decode ---
@pytest.mark.parametrize("s", [256, 1024, 1000])
@pytest.mark.parametrize("h,kv,hd", [(8, 2, 64), (4, 4, 128), (7, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(s, h, kv, hd, dtype):
    key = jax.random.PRNGKey(s + h)
    b = 2
    q = jax.random.normal(key, (b, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd),
                          jnp.float32).astype(dtype)
    length = jnp.asarray([s, max(1, s // 3)], jnp.int32)
    out = flash_decode(q, k, v, length)
    ref = flash_decode_ref(q, k, v, length)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decode_sliding_window():
    key = jax.random.PRNGKey(7)
    b, h, kv, hd, s = 2, 4, 2, 64, 2048
    q = jax.random.normal(key, (b, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    length = jnp.asarray([2048, 1500], jnp.int32)
    for w in (128, 512, 4096):
        out = flash_decode(q, k, v, length, window=w)
        ref = flash_decode_ref(q, k, v, length, window=w)
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-4)


@hypothesis.settings(**HYP)
@hypothesis.given(
    s=st.integers(min_value=8, max_value=2048),
    length_frac=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_flash_decode_property(s, length_frac, seed):
    """Property: softmax weights sum to 1 ⇒ output is inside the convex
    hull of V rows (per channel min/max bound), and kernel == oracle."""
    key = jax.random.PRNGKey(seed)
    b, h, kv, hd = 1, 2, 1, 32
    q = jax.random.normal(key, (b, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    length = jnp.asarray([max(1, int(s * length_frac))], jnp.int32)
    out = flash_decode(q, k, v, length)
    ref = flash_decode_ref(q, k, v, length)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-3)
    vv = np.asarray(v[0, : int(length[0]), 0])
    assert (np.asarray(out[0, 0]) <= vv.max(0) + 1e-4).all()
    assert (np.asarray(out[0, 0]) >= vv.min(0) - 1e-4).all()


# ----------------------------------------------------------- rglru_scan ---
@pytest.mark.parametrize("s,d", [(64, 128), (300, 130), (1024, 256),
                                 (513, 64)])
def test_rglru_scan_sweep(s, d):
    key = jax.random.PRNGKey(s * d)
    a = jax.nn.sigmoid(jax.random.normal(key, (2, s, d)))
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, s, d))
    out = rglru_scan(a, b)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-4)


@hypothesis.settings(**HYP)
@hypothesis.given(
    s=st.integers(min_value=1, max_value=700),
    d=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rglru_scan_property(s, d, seed):
    """Properties: a=0 ⇒ h=b; a=1,b=0 ⇒ h=0; kernel == oracle."""
    key = jax.random.PRNGKey(seed)
    b_arr = jax.random.normal(key, (1, s, d))
    np.testing.assert_allclose(
        rglru_scan(jnp.zeros((1, s, d)), b_arr), b_arr, atol=1e-6)
    np.testing.assert_allclose(
        rglru_scan(jnp.ones((1, s, d)), jnp.zeros((1, s, d))),
        jnp.zeros((1, s, d)), atol=1e-6)
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 1),
                                         (1, s, d)))
    np.testing.assert_allclose(rglru_scan(a, b_arr),
                               rglru_scan_ref(a, b_arr),
                               atol=1e-5, rtol=1e-4)


def test_rglru_block_uses_kernel_path():
    """models.recurrent.rglru_block(use_pallas=True) must match the jnp
    path (kernel integration)."""
    from repro.configs import get_config
    from repro.models import recurrent as R

    cfg = get_config("recurrentgemma-9b").reduced()
    params = R.rglru_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model),
                          jnp.float32)
    out_ref = R.rglru_block(params, x, use_pallas=False)
    out_ker = R.rglru_block(params, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out_ker, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=2e-3, rtol=1e-2)
