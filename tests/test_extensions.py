"""Beyond-paper extensions: DP-RWSADMM, kernel-integrated decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import privacy, tree
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model


# ------------------------------------------------------------- privacy ----
def test_clip_tree_bounds_norm():
    t = {"a": jnp.full((10,), 3.0), "b": jnp.full((4,), -2.0)}
    clipped = privacy.clip_tree(t, 1.0)
    assert float(tree.norm(clipped)) <= 1.0 + 1e-5
    small = {"a": jnp.full((10,), 0.01)}
    np.testing.assert_allclose(privacy.clip_tree(small, 1.0)["a"],
                               small["a"])  # inside ball: untouched


def test_privatize_delta_noise_scale():
    key = jax.random.PRNGKey(0)
    zero = {"w": jnp.zeros((20_000,))}
    d = privacy.privatize_delta(key, zero, zero, clip=1.0,
                                noise_multiplier=0.5)
    # Δc = 0 ⇒ output is pure N(0, 0.5²) noise
    assert abs(float(jnp.std(d["w"])) - 0.5) < 0.02


def test_epsilon_monotone():
    e1 = privacy.epsilon_advanced_composition(1.0, 10)
    e2 = privacy.epsilon_advanced_composition(1.0, 100)
    e3 = privacy.epsilon_advanced_composition(2.0, 100)
    assert e1 < e2       # more visits ⇒ more privacy loss
    assert e3 < e2       # more noise ⇒ less privacy loss


def test_dp_rwsadmm_learns_with_moderate_noise():
    imgs, labels = make_image_dataset(1200, seed=0)
    parts = pathological_split(labels, 10, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))
    tr = RWSADMMTrainer(
        model, data, RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5),
        zone_size=6, batch_size=32, dp_clip=5.0, dp_noise=0.002)
    res = run_simulation(tr, rounds=80, eval_every=80, seed=0)
    # DP costs accuracy (non-private run reaches ~1.0 here) but the
    # mechanism must still learn well above the 10% chance level.
    assert res.final["acc_personalized"] > 0.6
    # σ=0.002 is utility-oriented; a meaningful ε needs σ ≳ 0.5
    assert privacy.epsilon_advanced_composition(0.002, 48) == float("inf")
    assert np.isfinite(privacy.epsilon_advanced_composition(1.0, 48))


# ----------------------------------------------------------- fleet --------
def test_fleet_rwsadmm_covers_faster_and_learns():
    """Beyond-paper: K mobile servers. The fleet covers all clients in
    ~K× fewer wall-clock steps and still learns (tokens re-sync on
    rendezvous)."""
    from repro.fl.fleet_trainer import FleetRWSADMMTrainer

    imgs, labels = make_image_dataset(1500, seed=0)
    parts = pathological_split(labels, 20, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))
    hp = RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5)
    single = RWSADMMTrainer(model, data, hp, zone_size=4, batch_size=32)
    fleet = FleetRWSADMMTrainer(model, data, hp, n_walkers=3,
                                sync_every=15, zone_size=4, batch_size=32)
    r1 = run_simulation(single, rounds=120, eval_every=120, seed=0)
    r2 = run_simulation(fleet, rounds=120, eval_every=120, seed=0)
    assert r2.final["acc_personalized"] > 0.6
    assert r1.final["acc_personalized"] > 0.6
    t_single = single.walker.hitting_time()
    t_fleet = fleet.fleet_hitting_time()
    assert t_fleet is not None and t_single is not None
    assert t_fleet < t_single  # wall-clock coverage advantage


# --------------------------------------------------- kernel integration ---
def test_decode_attention_pallas_path_matches_jnp():
    from repro.configs import get_config
    from repro.models import attention as A

    cfg = get_config("tinyllama-1.1b").reduced()
    params = A.attn_init(jax.random.PRNGKey(0), cfg)
    cache_j = A.init_kv_cache(cfg, 2, 32, "attn")
    cache_p = A.init_kv_cache(cfg, 2, 32, "attn")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model),
                          jnp.float32)
    for _ in range(5):
        out_j, cache_j = A.decode_attention(params, x, cache_j, cfg)
        out_p, cache_p = A.decode_attention(params, x, cache_p, cfg,
                                            use_pallas=True)
        np.testing.assert_allclose(np.asarray(out_j, np.float32),
                                   np.asarray(out_p, np.float32),
                                   atol=3e-3, rtol=1e-2)


def test_decode_attention_pallas_local_ring():
    import dataclasses

    from repro.configs import get_config
    from repro.models import attention as A

    cfg = dataclasses.replace(get_config("gemma3-12b").reduced(), window=8)
    params = A.attn_init(jax.random.PRNGKey(0), cfg)
    cache_j = A.init_kv_cache(cfg, 1, 8, "local")
    cache_p = A.init_kv_cache(cfg, 1, 8, "local")
    for t in range(12):  # goes past the window
        x = jax.random.normal(jax.random.PRNGKey(t), (1, 1, cfg.d_model))
        out_j, cache_j = A.decode_attention(params, x, cache_j, cfg,
                                            kind="local")
        out_p, cache_p = A.decode_attention(params, x, cache_p, cfg,
                                            kind="local", use_pallas=True)
        np.testing.assert_allclose(np.asarray(out_j, np.float32),
                                   np.asarray(out_p, np.float32),
                                   atol=3e-3, rtol=1e-2)
