"""Personalization shoot-out (paper Table 1 / Fig. 2, condensed):
RWSADMM vs Per-FedAvg, pFedMe, Ditto, APFL, FedAvg on pathological
non-IID data, for the strongly convex MLR model.

Run:  PYTHONPATH=src python examples/personalization_comparison.py
"""
import sys

sys.path.insert(0, "src")

from repro.baselines import (
    APFLTrainer,
    DittoTrainer,
    FedAvgTrainer,
    PerFedAvgTrainer,
    PFedMeTrainer,
)
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model


def main():
    imgs, labels = make_image_dataset(2500, seed=0)
    parts = pathological_split(labels, 20, seed=0)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))

    trainers = {
        "FedAvg": FedAvgTrainer(model, data, clients_per_round=10),
        "Per-FedAvg": PerFedAvgTrainer(model, data, clients_per_round=10),
        "pFedMe": PFedMeTrainer(model, data, clients_per_round=10),
        "Ditto": DittoTrainer(model, data, clients_per_round=10),
        "APFL": APFLTrainer(model, data, clients_per_round=10),
        "RWSADMM": RWSADMMTrainer(
            model, data, RWSADMMHparams(beta=1.0, kappa=0.001,
                                        epsilon=1e-5),
            zone_size=8, batch_size=32),
    }
    rows = []
    for name, tr in trainers.items():
        res = run_simulation(tr, rounds=200, eval_every=200, seed=0)
        rows.append((name, res.final["acc"],
                     res.final.get("acc_global", float("nan")),
                     res.wall_time_s, res.total_comm_bytes / 1e6))
    print(f"\n{'algorithm':12s} {'acc':>8s} {'acc_glob':>9s} "
          f"{'time_s':>7s} {'comm_MB':>8s}")
    for name, acc, accg, t, mb in sorted(rows, key=lambda r: -r[1]):
        print(f"{name:12s} {acc:8.4f} {accg:9.4f} {t:7.1f} {mb:8.1f}")


if __name__ == "__main__":
    main()
