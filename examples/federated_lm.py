"""End-to-end driver: RWSADMM federated training of a language model.

Uses a mid-size reduced TinyLlama variant (~35M params — CPU-tractable)
with per-client heterogeneous token streams; the mobile server walks the
client graph, each visit runs one compiled RWSADMM zone step (the same
step the 512-chip dry-run lowers for the full configs).

Run:  PYTHONPATH=src python examples/federated_lm.py [--rounds 200]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.graph import DynamicGraph
from repro.core.markov import RandomWalkServer
from repro.core.rwsadmm import RWSADMMHparams
from repro.launch.steps import TrainState, init_train_state, make_train_step
from repro.models.registry import build_model


def heterogeneous_stream(vocab: int, client: int, batch: int, seq: int,
                         rng: np.random.Generator):
    """Markovian token stream with per-client transition bias — the LM
    analogue of the paper's label-skew heterogeneity."""
    base = rng.integers(0, vocab, size=(batch, seq))
    # each client prefers a contiguous vocab slice
    lo = (client * vocab // 8) % vocab
    mask = rng.random((batch, seq)) < 0.7
    pref = lo + rng.integers(0, max(2, vocab // 8), size=(batch, seq))
    return jnp.asarray(np.where(mask, pref % vocab, base), jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b").reduced(),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=2, head_dim=64,
        d_ff=1024, vocab=2048, dtype="float32",
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.arch_id} ~{n_params / 1e6:.1f}M params")

    hp = RWSADMMHparams(beta=2.0, kappa=0.001, epsilon=1e-5)
    step = jax.jit(make_train_step(model, hp, n_total=args.clients))

    rng = np.random.default_rng(0)
    batches = [heterogeneous_stream(cfg.vocab, c, 4, 128, rng)
               for c in range(args.clients)]
    states = [init_train_state(params, hp) for _ in range(args.clients)]
    dyn = DynamicGraph(args.clients, min_degree=3, regen_every=10, seed=0)
    walker = RandomWalkServer(seed=1)
    walker.reset(dyn.current())

    y, kappa = states[0].y, jnp.asarray(hp.kappa)
    losses = {}
    for r in range(args.rounds):
        g = dyn.step() if r else dyn.current()
        i_k = walker.step(g) if r else walker.position
        st = TrainState(x=states[i_k].x, z=states[i_k].z, y=y, kappa=kappa)
        st, loss = step(st, {"tokens": batches[i_k]})
        states[i_k], y, kappa = st, st.y, st.kappa
        losses.setdefault(i_k, []).append(float(loss))
        if r % 10 == 0:
            print(f"round {r:4d} client {i_k} loss {float(loss):.4f}")
    print("\nper-client loss improvement (first visit → last):")
    for c in sorted(losses):
        l = losses[c]
        print(f"  client {c}: {l[0]:.3f} → {l[-1]:.3f} ({len(l)} visits)")


if __name__ == "__main__":
    main()
