"""Serving example: batched prefill + decode of a personalized model.

The mobile server's y token IS the deployable artifact; this example
serves it with the production serving path (prefill fills the KV/recurrent
caches; decode is the same serve_step the decode_32k/long_500k dry-runs
lower, with sliding-window ring buffers for local-attention archs).

Run:  PYTHONPATH=src python examples/serve_personalized.py \
          [--arch gemma3-12b] [--batch 4]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models.registry import build_model, random_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen + (
        cfg.n_patches if cfg.frontend == "vision_stub" else 0)

    # Batched requests: each row is one request's prompt.
    batch = random_batch(cfg, args.batch, args.prompt_len, seed=7)
    prefill = jax.jit(make_prefill_step(model, max_len))
    serve = jax.jit(make_serve_step(model))

    t0 = time.perf_counter()
    tok, cache = prefill(params, batch)
    print(f"prefill {args.batch}×{args.prompt_len}: "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        tok, cache = serve(params, cache, tok)
        out.append(tok)
    dt = time.perf_counter() - t0
    print(f"decode {args.gen - 1} steps: {dt * 1e3:.0f} ms "
          f"({args.batch * (args.gen - 1) / dt:.1f} tok/s)")
    gen = jax.numpy.concatenate(out, axis=1)
    for i in range(args.batch):
        print(f"request {i}: {gen[i].tolist()}")


if __name__ == "__main__":
    main()
