"""Mobile-server simulation: the control plane of RWSADMM in isolation.

Shows the dynamic reachability graph, the non-homogeneous Markov chain
(Eq. 2), empirical visit frequencies vs the stationary distribution,
mixing time τ(δ) (Eq. 6), and the O(1) communication ledger.

Run:  PYTHONPATH=src python examples/mobile_server_sim.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.graph import DynamicGraph
from repro.core.markov import (
    RandomWalkServer,
    degree_transition_matrix,
    mixing_time,
    p_max_envelope,
    stationary_distribution,
    verify_assumption_3_1,
)


def main():
    n = 20
    dyn = DynamicGraph(n, min_degree=5, regen_every=10, seed=0)
    walker = RandomWalkServer(transition="degree", seed=1)
    walker.reset(dyn.current())

    model_mb = 1.2  # MLP-sized token
    comm_mb = 0.0
    ps = []
    for k in range(500):
        graph = dyn.step() if k else dyn.current()
        p = degree_transition_matrix(graph)
        ps.append(p)
        i_k = walker.step(graph) if k else walker.position
        zone = graph.neighborhood(i_k)
        comm_mb += model_mb * (1 + len(zone))  # y broadcast + zone uploads
        if k in (0, 9, 10, 499):
            print(f"round {k:3d}: server @ client {i_k:2d}, "
                  f"zone={list(zone)}, edges={graph.n_edges}")

    print(f"\ndynamic graph regenerated {dyn.n_regens} times")
    print(f"hitting time T (all clients visited): {walker.hitting_time()}")
    freq = walker.visit_counts / walker.visit_counts.sum()
    pi = stationary_distribution(ps[-1])
    print(f"visit-frequency vs stationary π: "
          f"max dev {np.abs(freq - pi).max():.4f}")

    rep = verify_assumption_3_1(ps[-1], delta=0.5)
    print(f"Assumption 3.1: tau(0.5)={rep['tau']}, sigma={rep['sigma']:.3f},"
          f" holds={rep['holds']}")
    env = p_max_envelope(ps)
    print(f"P_max envelope (Eq. 5): tau bound via envelope = "
          f"{mixing_time(env / np.maximum(env.sum(1, keepdims=True), 1e-12))}")
    print(f"\ncomm total {comm_mb:.0f} MB over 500 rounds "
          f"({comm_mb / 500:.1f} MB/round — O(1) in n; "
          f"FedAvg with 10 clients/round would be "
          f"{2 * 10 * model_mb:.1f} MB/round)")


if __name__ == "__main__":
    main()
