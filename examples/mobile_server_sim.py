"""Mobile-server simulation: the control plane of RWSADMM in isolation,
now driven by the scenario subsystem (src/repro/scenarios/).

For each registered scenario this shows the mobility process (smooth
motion vs i.i.d. redraws), the wireless link layer (per-link success
probabilities, stochastic dropouts), client churn (duty-cycled
availability), the non-homogeneous Markov chain (Eq. 2) with its
mixing-time certificate (Eq. 6), and the wireless communication ledger
— bytes, latency, and energy per round instead of bytes alone.

Run:  PYTHONPATH=src python examples/mobile_server_sim.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.markov import (
    RandomWalkServer,
    degree_transition_matrix,
    mixing_time,
    p_max_envelope,
    stationary_distribution,
    verify_assumption_3_1,
)
from repro.scenarios import Scenario, available_scenarios

MODEL_BYTES = 1_200_000   # MLP-sized walking token
ROUNDS = 500


def simulate(name: str, n: int = 20) -> None:
    print(f"\n=== scenario: {name} ===")
    scn = Scenario(n, name, seed=0)
    walker = RandomWalkServer(transition="degree", seed=1)
    walker.reset(scn.current())

    total_lat = total_en = comm_mb = 0.0
    offline_rounds = 0
    ps = []
    for k in range(ROUNDS):
        graph = scn.step() if k else scn.current()
        ps.append(degree_transition_matrix(graph))
        i_k = walker.step(graph) if k else walker.position
        zone = graph.neighborhood(i_k)
        avail = scn.availability()
        if avail is not None:
            zone = zone[avail[zone] | (zone == i_k)]
            offline_rounds += int((~avail).sum() > 0)
        comm_mb += MODEL_BYTES * (1 + len(zone)) / 1e6
        lat, en = scn.price_round(
            graph, int(i_k), zone.astype(np.int32),
            np.ones(len(zone), np.float32), MODEL_BYTES)
        total_lat += lat
        total_en += en
        if k in (0, 9, 10, ROUNDS - 1):
            drop = ""
            if scn.link is not None:
                p = scn.link.link_matrix(graph)
                live = p[p > 0]
                drop = (f", mean link p={live.mean():.2f}"
                        if live.size else "")
            print(f"round {k:3d}: server @ client {i_k:2d}, "
                  f"|zone|={len(zone)}, edges={graph.n_edges}{drop}")

    print(f"hitting time T (all clients visited): {walker.hitting_time()}")
    freq = walker.visit_counts / walker.visit_counts.sum()
    pi = stationary_distribution(ps[-1])
    print(f"visit-frequency vs stationary π: "
          f"max dev {np.abs(freq - pi).max():.4f}")
    rep = verify_assumption_3_1(ps[-1], delta=0.5)
    print(f"Assumption 3.1: tau(0.5)={rep['tau']}, "
          f"sigma={rep['sigma']:.3f}, holds={rep['holds']}")
    env = p_max_envelope(ps)
    env = env / np.maximum(env.sum(1, keepdims=True), 1e-12)
    print(f"P_max envelope (Eq. 5): tau bound = {mixing_time(env)}")
    if offline_rounds:
        print(f"churn: clients were offline in {offline_rounds}/{ROUNDS} "
              f"rounds")
    print(f"comm ledger over {ROUNDS} rounds: {comm_mb:.0f} MB "
          f"({comm_mb / ROUNDS:.1f} MB/round — O(1) in n), "
          f"latency {total_lat:.1f} s, energy {total_en:.1f} J")


def main() -> None:
    names = sys.argv[1:] or available_scenarios()
    for name in names:
        simulate(name)
    print(f"\nFedAvg reference: 10 clients/round would move "
          f"{2 * 10 * MODEL_BYTES / 1e6:.1f} MB/round via the base "
          f"station, O(m) in cohort size.")


if __name__ == "__main__":
    main()
