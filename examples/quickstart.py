"""Quickstart: mobilized personalized FL with RWSADMM (paper Algorithm 1).

Trains the paper's MLP on an offline synthetic MNIST-shaped dataset with
a pathological non-IID split (2 labels per client), a dynamic client
graph, and a random-walking mobile server — then compares against FedAvg.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.baselines import FedAvgTrainer
from repro.core.rwsadmm import RWSADMMHparams
from repro.data import make_image_dataset, pathological_split
from repro.data.loader import build_federated
from repro.fl.base import to_device_data
from repro.fl.rwsadmm_trainer import RWSADMMTrainer
from repro.fl.simulation import run_simulation
from repro.models.small import get_model


def main():
    # 1. Offline dataset + the paper's non-IID partition (§5).
    imgs, labels = make_image_dataset(3000, seed=0)
    parts = pathological_split(labels, n_clients=20, labels_per_client=2,
                               seed=0)
    fed = build_federated(imgs, labels, parts)   # 75/25 local splits
    data = to_device_data(fed)
    model = get_model("mlp", (28, 28, 1))

    # 2. RWSADMM: mobile server + hard-constraint personalization.
    # engine="scan" compiles each eval window into ONE lax.scan
    # executable (~5x rounds/sec vs the per-round eager loop, identical
    # trajectory); use engine="eager" to step round-by-round.
    trainer = RWSADMMTrainer(
        model, data,
        RWSADMMHparams(beta=1.0, kappa=0.001, epsilon=1e-5),
        zone_size=8, batch_size=32, min_degree=5, regen_every=10,
    )
    print("== RWSADMM (mobile server, personalized) ==")
    res = run_simulation(trainer, rounds=300, eval_every=50, verbose=True,
                         engine="scan")

    # 3. FedAvg benchmark on the same data.
    print("== FedAvg (stationary server, consensus) ==")
    fed_res = run_simulation(
        FedAvgTrainer(model, data, clients_per_round=10),
        rounds=300, eval_every=100, verbose=True,
    )

    print("\nFinal personalized accuracy (RWSADMM): "
          f"{res.final['acc_personalized']:.4f} "
          f"± {res.final['acc_personalized_std']:.4f}")
    print(f"Final global accuracy (FedAvg):         "
          f"{fed_res.final['acc_global']:.4f}")
    print(f"RWSADMM comm/round: "
          f"{res.total_comm_bytes / 300 / 1e6:.2f} MB  |  FedAvg: "
          f"{fed_res.total_comm_bytes / 300 / 1e6:.2f} MB")
    server = trainer.walker
    print(f"server visits: min={server.visit_counts.min()} "
          f"max={server.visit_counts.max()} "
          f"hitting_time={server.hitting_time()}")


if __name__ == "__main__":
    main()
