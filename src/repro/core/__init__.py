"""Core: the paper's contribution — RWSADMM + random-walk machinery."""
from . import graph, markov, rwsadmm, tree, walkman  # noqa: F401
from .graph import ClientGraph, DynamicGraph, random_geometric_graph  # noqa: F401
from .markov import RandomWalkServer, mixing_time  # noqa: F401
from .rwsadmm import (  # noqa: F401
    ClientState,
    RWSADMMHparams,
    ServerState,
    client_round,
    init_states,
    init_states_warm,
    zone_round,
)
