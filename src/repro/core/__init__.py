"""Core: the paper's contribution — RWSADMM + random-walk machinery."""
from . import graph, markov, rwsadmm, tree, walkman  # noqa: F401
from .graph import ClientGraph, DynamicGraph, random_geometric_graph  # noqa: F401
from .markov import (  # noqa: F401
    RandomWalkServer,
    ZoneSchedule,
    mixing_time,
    zone_schedule,
)
from .rwsadmm import (  # noqa: F401
    ClientState,
    RWSADMMHparams,
    ServerState,
    client_round,
    init_states,
    init_states_warm,
    zone_round,
    zone_round_masked,
)
