"""Dynamic client connectivity graphs for the mobile-server random walk.

The paper (§5, App. D.2) uses "a moderately dynamic connected graph of
randomly placed nodes where each node has at least 5 neighboring nodes at
the k-th update", regenerated every ``regen_every`` rounds. Nodes are
clients; an edge (i, j) means client j is within the mobile server's
short-range communication zone when it visits client i.

This module is pure numpy/host-side: graph topology is control-plane state
(it decides *which* clients form the active zone), never traced into XLA.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientGraph:
    """Undirected connectivity graph over ``n`` clients.

    adjacency: boolean (n, n) matrix, symmetric, zero diagonal.
    positions: (n, 2) client coordinates (for geometric graphs / plotting).
    """

    adjacency: np.ndarray
    positions: np.ndarray

    @property
    def n(self) -> int:
        return int(self.adjacency.shape[0])

    def degree(self, i: int | None = None):
        deg = self.adjacency.sum(axis=1)
        return int(deg[i]) if i is not None else deg

    def neighborhood(self, i: int) -> np.ndarray:
        """N(i): client i plus its neighbors (paper's vertex set N(i))."""
        mask = self.adjacency[i].copy()
        mask[i] = True
        return np.flatnonzero(mask)

    def neighbors(self, i: int) -> np.ndarray:
        """N(i) \\ {i}."""
        return np.flatnonzero(self.adjacency[i])

    @property
    def n_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def is_connected(self) -> bool:
        return adjacency_connected(self.adjacency)


def adjacency_connected(adj: np.ndarray) -> bool:
    """Connectivity of a boolean adjacency matrix.

    Vectorized frontier expansion (runs at every regeneration epoch —
    and every round under link-dropout scenarios; a Python-loop BFS
    dominates schedule precomputation at n ≳ 500). The matvec avoids
    the row-gather copy a boolean index would make each iteration;
    accumulate in intp — a uint8 dot would wrap at 256 seen neighbors
    and misreport dense graphs.
    """
    a = adj.view(np.uint8)
    seen = np.zeros(adj.shape[0], dtype=bool)
    seen[0] = True
    while True:
        new = (a @ seen.astype(np.intp) > 0) & ~seen
        if not new.any():
            return bool(seen.all())
        seen |= new


# Distance-matrix cache: producers (range_graph, the mobility models,
# the batched rollout) seed the graph they return; consumers in the same
# round (link layer, comm pricing) hit it instead of recomputing the
# O(n²) matrix. The cache lives ON the graph object (set via
# object.__setattr__ to sidestep the frozen dataclass), so any number of
# live graphs — e.g. a whole rollout window — keep their matrices
# simultaneously, and a graph's cache dies with it.
def seed_sq_dist_cache(graph: "ClientGraph", d2: np.ndarray) -> None:
    object.__setattr__(graph, "_sq_dists", d2)


def detach_rollout_views(graph: "ClientGraph") -> None:
    """Copy-on-seed (memory): a graph assembled by the batched rollout
    (:func:`graphs_from_stack`) holds *views* into its window's
    (R, n, n) adjacency and distance stacks; a caller retaining one
    graph past the chunk window (the scenario keeps the window's last
    graph as its current state) would pin both whole stacks live.
    Copying the retained graph's slices costs O(n²) once and lets the
    O(R·n²) stacks be freed — values are unchanged, so everything
    downstream stays bit-identical (regression-pinned in
    ``tests/test_scenario_rollout.py``).
    """
    d2 = getattr(graph, "_sq_dists", None)
    if d2 is not None and d2.base is not None:
        object.__setattr__(graph, "_sq_dists", d2.copy())
    fields = (("nbrs", "nbr_mask", "nbr_d2", "positions")
              if not hasattr(graph, "adjacency")
              else ("adjacency", "positions"))
    for name in fields:
        arr = getattr(graph, name)
        if arr.base is not None:
            object.__setattr__(graph, name, arr.copy())


def graph_sq_dists(graph: "ClientGraph") -> np.ndarray:
    """Squared pairwise distances for a graph's positions (cached)."""
    d2 = getattr(graph, "_sq_dists", None)
    if d2 is None:
        d2 = pairwise_sq_dists(graph.positions)
        seed_sq_dist_cache(graph, d2)
    return d2


def _sum_sq_diffs(coord_pairs) -> np.ndarray:
    """THE distance kernel: Σ_c (a_c − b_c)², accumulated coordinate-
    by-coordinate with elementwise ops only, then clamped at 0.

    Every squared-distance producer in the repo — the dense (n, n)
    matrix, the (R, n, n) rollout batch, the sparse lane's gathered
    pairs, the cross-component patch — feeds its per-coordinate
    operand pairs through this one loop, so all of them share one
    float accumulation order *structurally*. Elementwise ops — unlike
    a BLAS matmul expansion, whose accumulation order is build-
    dependent — make the dense and sparse lanes bit-identical by
    construction (pinned in ``tests/test_sparse_backend.py``).
    """
    d2 = None
    for a, b in coord_pairs:
        dc = a - b
        dc *= dc
        d2 = dc if d2 is None else d2 + dc
    return np.maximum(d2, 0.0)


def pairwise_sq_dists(pos: np.ndarray) -> np.ndarray:
    """(n, n) squared distances with +inf diagonal."""
    d2 = _sum_sq_diffs((pos[:, c, None], pos[None, :, c])
                       for c in range(pos.shape[1]))
    np.fill_diagonal(d2, np.inf)
    return d2


def pairwise_sq_dists_batch(pos: np.ndarray) -> np.ndarray:
    """(R, n, n) squared distances with +inf diagonals for a stack of
    position frames (R, n, 2) — bit-identical to R per-frame
    :func:`pairwise_sq_dists` calls (pinned in the rollout tests)."""
    d2 = _sum_sq_diffs((pos[:, :, None, c], pos[:, None, :, c])
                       for c in range(pos.shape[2]))
    idx = np.arange(pos.shape[1])
    d2[:, idx, idx] = np.inf
    return d2


def pair_sq_dists(pos: np.ndarray, i: np.ndarray, j: np.ndarray
                  ) -> np.ndarray:
    """Squared distances for gathered index pairs (i, j) — the sparse
    lane's form of :func:`pairwise_sq_dists`."""
    return _sum_sq_diffs((pos[i, c], pos[j, c])
                         for c in range(pos.shape[1]))


def adjacency_connected_batch(adj: np.ndarray) -> np.ndarray:
    """(R,) connectivity flags for a stack of adjacency matrices (R, n, n).

    One frontier expansion for the whole batch: ~graph-diameter
    iterations of a single (R, n, n) @ (R, n, 1) matmul, instead of R
    independent BFS loops — this is the hot check of the batched
    link-dropout path, which re-validates every round's surviving graph.
    """
    a = adj.view(np.uint8)
    seen = np.zeros(adj.shape[:2], dtype=bool)
    seen[:, 0] = True
    while True:
        new = (np.matmul(a, seen[..., None].astype(np.intp))[..., 0] > 0) \
            & ~seen
        if not new.any():
            return seen.all(axis=1)
        seen |= new


def graphs_from_stack(adj: np.ndarray, d2s, positions) -> "list[ClientGraph]":
    """Assemble per-round ``ClientGraph``s from a batched adjacency
    stack: one batched connectivity check, a component re-patch only
    for the rounds that need it, and each graph seeded with its
    distance matrix. The shared tail of every batched-rollout lane
    (range/kNN mobility graphs, link-dropout survivors) — change the
    patch or cache protocol here and every lane follows.

    ``d2s`` and ``positions`` are per-round indexables (stacked arrays
    or lists); ``adj`` is (R, n, n) and is patched in place.
    """
    for r in np.flatnonzero(~adjacency_connected_batch(adj)):
        patch_connected(adj[r], d2s[r])
    out = []
    for r in range(adj.shape[0]):
        g = ClientGraph(adjacency=adj[r], positions=positions[r])
        seed_sq_dist_cache(g, d2s[r])
        out.append(g)
    return out


def knn_adjacency(d2: np.ndarray, k: int) -> np.ndarray:
    """Symmetrized k-nearest-neighbor adjacency from squared distances.

    argpartition is O(n²) vs argsort's O(n² log n) — this runs at every
    regeneration epoch.
    """
    n = d2.shape[0]
    k = min(k, n - 1)
    adj = np.zeros((n, n), dtype=bool)
    if k > 0:
        nearest = np.argpartition(d2, k - 1, axis=1)[:, :k]
        np.put_along_axis(adj, nearest, True, axis=1)
    return adj | adj.T


def patch_connected(adj: np.ndarray, d2: np.ndarray) -> np.ndarray:
    """Deterministically link nearest nodes across components until the
    graph is connected (Assumption 3.1 requires an irreducible chain).
    Mutates and returns ``adj``.
    """
    while not adjacency_connected(adj):
        comp = _component_labels(adj)
        a = np.flatnonzero(comp == comp[0])
        b = np.flatnonzero(comp != comp[0])
        sub = d2[np.ix_(a, b)]
        ia, ib = np.unravel_index(np.argmin(sub), sub.shape)
        adj[a[ia], b[ib]] = adj[b[ib], a[ia]] = True
    return adj


def random_geometric_graph(
    n: int,
    min_degree: int = 5,
    rng: np.random.Generator | None = None,
) -> ClientGraph:
    """Randomly placed clients; each connected to at least ``min_degree``
    nearest neighbors (paper App. D.2), then symmetrized and patched to be
    connected (Assumption 3.1 requires an irreducible chain)."""
    rng = rng or np.random.default_rng(0)
    pos = rng.uniform(0.0, 1.0, size=(n, 2))
    d2 = pairwise_sq_dists(pos)
    adj = knn_adjacency(d2, min_degree)
    adj = patch_connected(adj, d2)
    return ClientGraph(adjacency=adj, positions=pos)


def _component_labels(adj: np.ndarray) -> np.ndarray:
    n = adj.shape[0]
    labels = -np.ones(n, dtype=int)
    cur = 0
    for s in range(n):
        if labels[s] >= 0:
            continue
        stack = [s]
        labels[s] = cur
        while stack:
            u = stack.pop()
            for v in np.flatnonzero(adj[u]):
                if labels[v] < 0:
                    labels[v] = cur
                    stack.append(int(v))
        cur += 1
    return labels


class DynamicGraph:
    """Moderately dynamic graph: regenerated every ``regen_every`` rounds
    (paper uses 10). Node count and min-degree are preserved; positions are
    re-drawn, modelling client mobility between server visits."""

    def __init__(
        self,
        n: int,
        min_degree: int = 5,
        regen_every: int = 10,
        seed: int = 0,
    ):
        self.n = n
        self.min_degree = min_degree
        self.regen_every = max(1, regen_every)
        self._rng = np.random.default_rng(seed)
        self._round = 0
        self.graph = random_geometric_graph(n, min_degree, self._rng)
        self.n_regens = 0

    def current(self) -> ClientGraph:
        return self.graph

    def step(self) -> ClientGraph:
        """Advance one round; regenerate topology on schedule."""
        self._round += 1
        if self._round % self.regen_every == 0:
            self.graph = random_geometric_graph(
                self.n, self.min_degree, self._rng
            )
            self.n_regens += 1
        return self.graph

    def schedule(self, rounds: int,
                 *, include_current: bool = False) -> list[ClientGraph]:
        """Batch variant of :meth:`step`: the next ``rounds`` graphs.

        Consumes the generator state exactly as ``rounds`` successive
        ``step()`` calls would, so an eager per-round driver and a
        precomputed-schedule driver see identical topologies (including
        regeneration epochs). ``include_current=True`` makes the first
        entry the *current* graph without advancing — the round-0
        convention of the trainers, which use ``current()`` before the
        first ``step()``.
        """
        graphs: list[ClientGraph] = []
        if include_current:
            graphs.append(self.current())
        while len(graphs) < rounds:
            graphs.append(self.step())
        return graphs


# ---------------------------------------------------------------------------
# Sparse neighbor-list backend (large n).
#
# The dense lane above materializes O(n²) adjacency/distance matrices —
# fine to a few hundred clients, memory-blocked long before the paper's
# "n mobile devices" scaling story gets interesting. The sparse lane
# stores the same graph as capped-degree neighbor lists: (n, k_cap)
# int32 ids + validity mask + aligned squared distances, O(n·k) in both
# memory and per-round control-plane work. Producers live in
# ``scenarios.mobility`` (grid-bucket neighbor search); every consumer
# (walk stepping, zone planning, link dropouts, pricing) reads lists
# through this class. Where the dense lane is RNG-free the two lanes
# are pinned bit-identical (``tests/test_sparse_backend.py``).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NeighborGraph:
    """Undirected graph over ``n`` clients as packed neighbor lists.

    nbrs:     (n, k_cap) int32 — row i's neighbors in slots
              ``[:deg(i)]``, sorted ascending; padding slots hold 0.
    nbr_mask: (n, k_cap) bool — validity per slot (packed left).
    positions:(n, 2) client coordinates.
    nbr_d2:   (n, k_cap) float64 — squared distance to each neighbor,
              aligned with ``nbrs`` (padding slots hold 0).

    Symmetric by construction: j ∈ nbrs[i] ⇔ i ∈ nbrs[j].
    """

    nbrs: np.ndarray
    nbr_mask: np.ndarray
    positions: np.ndarray
    nbr_d2: np.ndarray

    @property
    def n(self) -> int:
        return int(self.nbrs.shape[0])

    @property
    def k_cap(self) -> int:
        return int(self.nbrs.shape[1])

    def degree(self, i: int | None = None):
        deg = self.nbr_mask.sum(axis=1)
        return int(deg[i]) if i is not None else deg

    def neighbors(self, i: int) -> np.ndarray:
        """N(i) \\ {i}, sorted ascending (packed-left invariant)."""
        return self.nbrs[i, : int(self.nbr_mask[i].sum())]

    def neighborhood(self, i: int) -> np.ndarray:
        """N(i): client i plus its neighbors, sorted ascending — the
        same ordering the dense ``ClientGraph.neighborhood`` produces,
        so zone plans (and their subsample draws) agree bit-for-bit."""
        nb = self.neighbors(i)
        return np.insert(nb, np.searchsorted(nb, i), i)

    @property
    def n_edges(self) -> int:
        return int(self.nbr_mask.sum()) // 2

    def is_connected(self) -> bool:
        return neighbor_lists_connected(self.nbrs, self.nbr_mask)

    def undirected_edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Canonical (i < j) edge arrays (ei, ej, d2), sorted by (i, j)
        — the link layer's per-edge sampling order."""
        deg = self.nbr_mask.sum(axis=1)
        ei = np.repeat(np.arange(self.n), deg)
        flat = self.nbr_mask.reshape(-1)
        ej = self.nbrs.reshape(-1)[flat]
        d2 = self.nbr_d2.reshape(-1)[flat]
        keep = ei < ej
        return ei[keep], ej[keep], d2[keep]

    def to_dense(self) -> ClientGraph:
        """Densify (small-n interop / diagnostics / equivalence tests)."""
        adj = np.zeros((self.n, self.n), dtype=bool)
        deg = self.nbr_mask.sum(axis=1)
        rows = np.repeat(np.arange(self.n), deg)
        cols = self.nbrs.reshape(-1)[self.nbr_mask.reshape(-1)]
        adj[rows, cols] = True
        return ClientGraph(adjacency=adj, positions=self.positions)


def neighbor_graph_from_dense(graph: ClientGraph) -> NeighborGraph:
    """Neighbor-list view of a dense graph (tests / migration)."""
    adj = graph.adjacency
    rows, cols = np.nonzero(adj)
    d2 = pair_sq_dists(graph.positions, rows, cols)
    return neighbor_graph_from_pairs(graph.n, rows, cols, d2,
                                     graph.positions)


def neighbor_graph_from_pairs(n: int, pi: np.ndarray, pj: np.ndarray,
                              d2: np.ndarray, positions: np.ndarray,
                              *, assume_sorted: bool = False,
                              ) -> NeighborGraph:
    """Pack directed pairs (both orientations present) into a
    :class:`NeighborGraph`. ``assume_sorted=True`` skips the lexsort
    when the pairs already arrive sorted by (i, j)."""
    pi = np.asarray(pi, dtype=np.int64)
    pj = np.asarray(pj, dtype=np.int64)
    if not assume_sorted:
        order = np.lexsort((pj, pi))
        pi, pj, d2 = pi[order], pj[order], d2[order]
    nbrs, mask, nd2 = _lists_from_sorted_pairs(n, pi, pj, d2)
    return NeighborGraph(nbrs=nbrs, nbr_mask=mask, positions=positions,
                         nbr_d2=nd2)


def segmented_arange(counts: np.ndarray) -> np.ndarray:
    """0..cᵢ−1 for each segment of a counts vector, concatenated —
    the within-group offset of every element of a group-sorted flat
    array (Σcounts entries). The shared building block of the packed
    neighbor-list constructors, the cell-list candidate generator, the
    degree-cap ranking, and the fleet fast-path planner."""
    counts = np.asarray(counts)
    total = int(counts.sum())
    return np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                        counts)


def _lists_from_sorted_pairs(n, pi, pj, d2):
    """(n, k_cap) packed arrays from (i, j)-sorted directed pairs."""
    deg = np.bincount(pi, minlength=n)
    k_cap = max(1, int(deg.max()) if len(deg) else 1)
    col = segmented_arange(deg)
    nbrs = np.zeros((n, k_cap), dtype=np.int32)
    mask = np.zeros((n, k_cap), dtype=bool)
    nd2 = np.zeros((n, k_cap), dtype=np.float64)
    nbrs[pi, col] = pj
    mask[pi, col] = True
    nd2[pi, col] = d2
    return nbrs, mask, nd2


def neighbor_lists_connected(nbrs: np.ndarray, mask: np.ndarray) -> bool:
    """Connectivity by frontier expansion over packed neighbor lists —
    O(E) per sweep instead of the dense lane's O(n²) matvec."""
    n = nbrs.shape[0]
    seen = np.zeros(n, dtype=bool)
    seen[0] = True
    frontier = np.array([0], dtype=np.int64)
    while frontier.size:
        cand = nbrs[frontier][mask[frontier]]
        new = np.unique(cand)
        new = new[~seen[new]]
        seen[new] = True
        frontier = new
    return bool(seen.all())


def _component_labels_lists(nbrs: np.ndarray, mask: np.ndarray
                            ) -> np.ndarray:
    n = nbrs.shape[0]
    labels = -np.ones(n, dtype=np.int64)
    cur = 0
    for s in range(n):
        if labels[s] >= 0:
            continue
        labels[s] = cur
        frontier = np.array([s], dtype=np.int64)
        while frontier.size:
            cand = nbrs[frontier][mask[frontier]]
            new = np.unique(cand)
            new = new[labels[new] < 0]
            labels[new] = cur
            frontier = new
        cur += 1
    return labels


def _nearest_cross_pair(pos: np.ndarray, a: np.ndarray, b: np.ndarray,
                        chunk: int = 1024) -> tuple[int, int, float]:
    """argmin over d2[a × b] without materializing the block: row-chunked
    scan with a strictly-less running best, preserving the dense lane's
    row-major first-occurrence tie-breaking (the shared
    :func:`_sum_sq_diffs` distance kernel)."""
    best = (np.inf, -1, -1)
    for s in range(0, len(a), chunk):
        rows = a[s:s + chunk]
        d2 = _sum_sq_diffs((pos[rows, c, None], pos[None, b, c])
                           for c in range(pos.shape[1]))
        flat = int(np.argmin(d2))
        ia, ib = divmod(flat, len(b))
        val = float(d2[ia, ib])
        if val < best[0]:
            best = (val, int(rows[ia]), int(b[ib]))
    return best[1], best[2], best[0]


def _insert_edge_lists(nbrs, mask, nd2, i: int, j: int, d2: float):
    """Insert undirected edge (i, j) keeping rows packed + sorted;
    grows k_cap when a row is full. Returns the (possibly re-allocated)
    arrays — callers must rebind."""
    for u, v in ((i, j), (j, i)):
        deg = int(mask[u].sum())
        if deg == nbrs.shape[1]:
            grow = max(4, nbrs.shape[1] // 2)
            nbrs = np.pad(nbrs, ((0, 0), (0, grow)))
            mask = np.pad(mask, ((0, 0), (0, grow)))
            nd2 = np.pad(nd2, ((0, 0), (0, grow)))
        pos_u = int(np.searchsorted(nbrs[u, :deg], v))
        if pos_u < deg and nbrs[u, pos_u] == v:
            continue                     # already present
        nbrs[u, pos_u + 1: deg + 1] = nbrs[u, pos_u: deg]
        nd2[u, pos_u + 1: deg + 1] = nd2[u, pos_u: deg]
        nbrs[u, pos_u] = v
        nd2[u, pos_u] = d2
        mask[u, deg] = True
    return nbrs, mask, nd2


def patch_connected_lists(nbrs, mask, nd2, positions):
    """Neighbor-list twin of :func:`patch_connected`: deterministically
    link the nearest node pair across components until connected — the
    same pair sequence the dense patch picks (component of node 0 vs the
    rest, global distance argmin), so patched sparse graphs match their
    dense oracles edge-for-edge. Returns (nbrs, mask, nd2)."""
    while not neighbor_lists_connected(nbrs, mask):
        comp = _component_labels_lists(nbrs, mask)
        a = np.flatnonzero(comp == comp[0])
        b = np.flatnonzero(comp != comp[0])
        ia, ib, d2 = _nearest_cross_pair(positions, a, b)
        nbrs, mask, nd2 = _insert_edge_lists(nbrs, mask, nd2, ia, ib, d2)
    return nbrs, mask, nd2


def line_graph(n: int) -> ClientGraph:
    """Worst-case mixing topology (used in tests/benchmarks)."""
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    pos = np.stack([np.linspace(0, 1, n), np.zeros(n)], axis=1)
    return ClientGraph(adjacency=adj, positions=pos)


def complete_graph(n: int) -> ClientGraph:
    adj = ~np.eye(n, dtype=bool)
    pos = np.stack(
        [np.cos(np.linspace(0, 2 * np.pi, n, endpoint=False)),
         np.sin(np.linspace(0, 2 * np.pi, n, endpoint=False))],
        axis=1,
    )
    return ClientGraph(adjacency=adj, positions=pos)
