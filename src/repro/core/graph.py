"""Dynamic client connectivity graphs for the mobile-server random walk.

The paper (§5, App. D.2) uses "a moderately dynamic connected graph of
randomly placed nodes where each node has at least 5 neighboring nodes at
the k-th update", regenerated every ``regen_every`` rounds. Nodes are
clients; an edge (i, j) means client j is within the mobile server's
short-range communication zone when it visits client i.

This module is pure numpy/host-side: graph topology is control-plane state
(it decides *which* clients form the active zone), never traced into XLA.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientGraph:
    """Undirected connectivity graph over ``n`` clients.

    adjacency: boolean (n, n) matrix, symmetric, zero diagonal.
    positions: (n, 2) client coordinates (for geometric graphs / plotting).
    """

    adjacency: np.ndarray
    positions: np.ndarray

    @property
    def n(self) -> int:
        return int(self.adjacency.shape[0])

    def degree(self, i: int | None = None):
        deg = self.adjacency.sum(axis=1)
        return int(deg[i]) if i is not None else deg

    def neighborhood(self, i: int) -> np.ndarray:
        """N(i): client i plus its neighbors (paper's vertex set N(i))."""
        mask = self.adjacency[i].copy()
        mask[i] = True
        return np.flatnonzero(mask)

    def neighbors(self, i: int) -> np.ndarray:
        """N(i) \\ {i}."""
        return np.flatnonzero(self.adjacency[i])

    @property
    def n_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def is_connected(self) -> bool:
        return adjacency_connected(self.adjacency)


def adjacency_connected(adj: np.ndarray) -> bool:
    """Connectivity of a boolean adjacency matrix.

    Vectorized frontier expansion (runs at every regeneration epoch —
    and every round under link-dropout scenarios; a Python-loop BFS
    dominates schedule precomputation at n ≳ 500). The matvec avoids
    the row-gather copy a boolean index would make each iteration;
    accumulate in intp — a uint8 dot would wrap at 256 seen neighbors
    and misreport dense graphs.
    """
    a = adj.view(np.uint8)
    seen = np.zeros(adj.shape[0], dtype=bool)
    seen[0] = True
    while True:
        new = (a @ seen.astype(np.intp) > 0) & ~seen
        if not new.any():
            return bool(seen.all())
        seen |= new


# Distance-matrix cache: producers (range_graph, the mobility models,
# the batched rollout) seed the graph they return; consumers in the same
# round (link layer, comm pricing) hit it instead of recomputing the
# O(n²) matrix. The cache lives ON the graph object (set via
# object.__setattr__ to sidestep the frozen dataclass), so any number of
# live graphs — e.g. a whole rollout window — keep their matrices
# simultaneously, and a graph's cache dies with it.
def seed_sq_dist_cache(graph: "ClientGraph", d2: np.ndarray) -> None:
    object.__setattr__(graph, "_sq_dists", d2)


def detach_rollout_views(graph: "ClientGraph") -> None:
    """Copy-on-seed (memory): a graph assembled by the batched rollout
    (:func:`graphs_from_stack`) holds *views* into its window's
    (R, n, n) adjacency and distance stacks; a caller retaining one
    graph past the chunk window (the scenario keeps the window's last
    graph as its current state) would pin both whole stacks live.
    Copying the retained graph's slices costs O(n²) once and lets the
    O(R·n²) stacks be freed — values are unchanged, so everything
    downstream stays bit-identical (regression-pinned in
    ``tests/test_scenario_rollout.py``).
    """
    d2 = getattr(graph, "_sq_dists", None)
    if d2 is not None and d2.base is not None:
        object.__setattr__(graph, "_sq_dists", d2.copy())
    if graph.adjacency.base is not None:
        object.__setattr__(graph, "adjacency", graph.adjacency.copy())
    if graph.positions.base is not None:
        object.__setattr__(graph, "positions", graph.positions.copy())


def graph_sq_dists(graph: "ClientGraph") -> np.ndarray:
    """Squared pairwise distances for a graph's positions (cached)."""
    d2 = getattr(graph, "_sq_dists", None)
    if d2 is None:
        d2 = pairwise_sq_dists(graph.positions)
        seed_sq_dist_cache(graph, d2)
    return d2


def pairwise_sq_dists(pos: np.ndarray) -> np.ndarray:
    """(n, n) squared distances with +inf diagonal.

    ‖a−b‖² = ‖a‖² + ‖b‖² − 2a·b: one (n,2)@(2,n) matmul instead of an
    (n,n,2) broadcast — this runs at every regeneration/mobility epoch.
    """
    sq = (pos * pos).sum(axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (pos @ pos.T)
    np.fill_diagonal(d2, np.inf)
    return np.maximum(d2, 0.0)


def pairwise_sq_dists_batch(pos: np.ndarray) -> np.ndarray:
    """(R, n, n) squared distances with +inf diagonals for a stack of
    position frames (R, n, 2).

    Same expansion as :func:`pairwise_sq_dists` — the inner dimension is
    2, so the per-frame matmul and the batched matmul reduce in the same
    order and the result is bit-identical to R per-frame calls (pinned
    in the rollout equivalence tests).
    """
    sq = np.einsum("rij,rij->ri", pos, pos)
    d2 = sq[:, :, None] + sq[:, None, :] - 2.0 * (pos @ pos.transpose(0, 2, 1))
    idx = np.arange(pos.shape[1])
    d2 = np.maximum(d2, 0.0)
    d2[:, idx, idx] = np.inf
    return d2


def adjacency_connected_batch(adj: np.ndarray) -> np.ndarray:
    """(R,) connectivity flags for a stack of adjacency matrices (R, n, n).

    One frontier expansion for the whole batch: ~graph-diameter
    iterations of a single (R, n, n) @ (R, n, 1) matmul, instead of R
    independent BFS loops — this is the hot check of the batched
    link-dropout path, which re-validates every round's surviving graph.
    """
    a = adj.view(np.uint8)
    seen = np.zeros(adj.shape[:2], dtype=bool)
    seen[:, 0] = True
    while True:
        new = (np.matmul(a, seen[..., None].astype(np.intp))[..., 0] > 0) \
            & ~seen
        if not new.any():
            return seen.all(axis=1)
        seen |= new


def graphs_from_stack(adj: np.ndarray, d2s, positions) -> "list[ClientGraph]":
    """Assemble per-round ``ClientGraph``s from a batched adjacency
    stack: one batched connectivity check, a component re-patch only
    for the rounds that need it, and each graph seeded with its
    distance matrix. The shared tail of every batched-rollout lane
    (range/kNN mobility graphs, link-dropout survivors) — change the
    patch or cache protocol here and every lane follows.

    ``d2s`` and ``positions`` are per-round indexables (stacked arrays
    or lists); ``adj`` is (R, n, n) and is patched in place.
    """
    for r in np.flatnonzero(~adjacency_connected_batch(adj)):
        patch_connected(adj[r], d2s[r])
    out = []
    for r in range(adj.shape[0]):
        g = ClientGraph(adjacency=adj[r], positions=positions[r])
        seed_sq_dist_cache(g, d2s[r])
        out.append(g)
    return out


def knn_adjacency(d2: np.ndarray, k: int) -> np.ndarray:
    """Symmetrized k-nearest-neighbor adjacency from squared distances.

    argpartition is O(n²) vs argsort's O(n² log n) — this runs at every
    regeneration epoch.
    """
    n = d2.shape[0]
    k = min(k, n - 1)
    adj = np.zeros((n, n), dtype=bool)
    if k > 0:
        nearest = np.argpartition(d2, k - 1, axis=1)[:, :k]
        np.put_along_axis(adj, nearest, True, axis=1)
    return adj | adj.T


def patch_connected(adj: np.ndarray, d2: np.ndarray) -> np.ndarray:
    """Deterministically link nearest nodes across components until the
    graph is connected (Assumption 3.1 requires an irreducible chain).
    Mutates and returns ``adj``.
    """
    while not adjacency_connected(adj):
        comp = _component_labels(adj)
        a = np.flatnonzero(comp == comp[0])
        b = np.flatnonzero(comp != comp[0])
        sub = d2[np.ix_(a, b)]
        ia, ib = np.unravel_index(np.argmin(sub), sub.shape)
        adj[a[ia], b[ib]] = adj[b[ib], a[ia]] = True
    return adj


def random_geometric_graph(
    n: int,
    min_degree: int = 5,
    rng: np.random.Generator | None = None,
) -> ClientGraph:
    """Randomly placed clients; each connected to at least ``min_degree``
    nearest neighbors (paper App. D.2), then symmetrized and patched to be
    connected (Assumption 3.1 requires an irreducible chain)."""
    rng = rng or np.random.default_rng(0)
    pos = rng.uniform(0.0, 1.0, size=(n, 2))
    d2 = pairwise_sq_dists(pos)
    adj = knn_adjacency(d2, min_degree)
    adj = patch_connected(adj, d2)
    return ClientGraph(adjacency=adj, positions=pos)


def _component_labels(adj: np.ndarray) -> np.ndarray:
    n = adj.shape[0]
    labels = -np.ones(n, dtype=int)
    cur = 0
    for s in range(n):
        if labels[s] >= 0:
            continue
        stack = [s]
        labels[s] = cur
        while stack:
            u = stack.pop()
            for v in np.flatnonzero(adj[u]):
                if labels[v] < 0:
                    labels[v] = cur
                    stack.append(int(v))
        cur += 1
    return labels


class DynamicGraph:
    """Moderately dynamic graph: regenerated every ``regen_every`` rounds
    (paper uses 10). Node count and min-degree are preserved; positions are
    re-drawn, modelling client mobility between server visits."""

    def __init__(
        self,
        n: int,
        min_degree: int = 5,
        regen_every: int = 10,
        seed: int = 0,
    ):
        self.n = n
        self.min_degree = min_degree
        self.regen_every = max(1, regen_every)
        self._rng = np.random.default_rng(seed)
        self._round = 0
        self.graph = random_geometric_graph(n, min_degree, self._rng)
        self.n_regens = 0

    def current(self) -> ClientGraph:
        return self.graph

    def step(self) -> ClientGraph:
        """Advance one round; regenerate topology on schedule."""
        self._round += 1
        if self._round % self.regen_every == 0:
            self.graph = random_geometric_graph(
                self.n, self.min_degree, self._rng
            )
            self.n_regens += 1
        return self.graph

    def schedule(self, rounds: int,
                 *, include_current: bool = False) -> list[ClientGraph]:
        """Batch variant of :meth:`step`: the next ``rounds`` graphs.

        Consumes the generator state exactly as ``rounds`` successive
        ``step()`` calls would, so an eager per-round driver and a
        precomputed-schedule driver see identical topologies (including
        regeneration epochs). ``include_current=True`` makes the first
        entry the *current* graph without advancing — the round-0
        convention of the trainers, which use ``current()`` before the
        first ``step()``.
        """
        graphs: list[ClientGraph] = []
        if include_current:
            graphs.append(self.current())
        while len(graphs) < rounds:
            graphs.append(self.step())
        return graphs


def line_graph(n: int) -> ClientGraph:
    """Worst-case mixing topology (used in tests/benchmarks)."""
    adj = np.zeros((n, n), dtype=bool)
    for i in range(n - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    pos = np.stack([np.linspace(0, 1, n), np.zeros(n)], axis=1)
    return ClientGraph(adjacency=adj, positions=pos)


def complete_graph(n: int) -> ClientGraph:
    adj = ~np.eye(n, dtype=bool)
    pos = np.stack(
        [np.cos(np.linspace(0, 2 * np.pi, n, endpoint=False)),
         np.sin(np.linspace(0, 2 * np.pi, n, endpoint=False))],
        axis=1,
    )
    return ClientGraph(adjacency=adj, positions=pos)
