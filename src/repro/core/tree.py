"""Pytree arithmetic used throughout the RWSADMM core.

All RWSADMM state variables (client x_i, dual z_i, server y) are parameter
pytrees of the underlying model; the closed-form updates (paper Eq. 11, 14,
15) are purely elementwise, so every helper here maps leaf-wise.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def tree_map(fn: Callable, *trees: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, *trees)


def zeros_like(t: PyTree) -> PyTree:
    return tree_map(jnp.zeros_like, t)


def ones_like(t: PyTree) -> PyTree:
    return tree_map(jnp.ones_like, t)


def add(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(jnp.add, a, b)


def sub(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(jnp.subtract, a, b)


def mul(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(jnp.multiply, a, b)


def scale(a: PyTree, s) -> PyTree:
    return tree_map(lambda x: x * s, a)


def add_scaled(a: PyTree, b: PyTree, s) -> PyTree:
    """a + s * b, leafwise."""
    return tree_map(lambda x, y: x + s * y, a, b)


def sign(a: PyTree) -> PyTree:
    return tree_map(jnp.sign, a)


def sub_scalar(a: PyTree, s) -> PyTree:
    return tree_map(lambda x: x - s, a)


def dot(a: PyTree, b: PyTree):
    """Global inner product <a, b> across all leaves."""
    leaves = tree_map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves)


def sq_norm(a: PyTree):
    """Global squared l2 norm across all leaves."""
    leaves = tree_map(lambda x: jnp.sum(jnp.square(x)), a)
    return jax.tree_util.tree_reduce(jnp.add, leaves)


def norm(a: PyTree):
    return jnp.sqrt(sq_norm(a))


def linf(a: PyTree):
    leaves = tree_map(lambda x: jnp.max(jnp.abs(x)), a)
    return jax.tree_util.tree_reduce(jnp.maximum, leaves)


def mean(trees: list[PyTree]) -> PyTree:
    """Elementwise mean of a list of pytrees."""
    n = len(trees)
    acc = trees[0]
    for t in trees[1:]:
        acc = add(acc, t)
    return scale(acc, 1.0 / n)


def weighted_mean(trees: list[PyTree], weights) -> PyTree:
    total = float(sum(weights))
    acc = scale(trees[0], weights[0] / total)
    for t, w in zip(trees[1:], weights[1:]):
        acc = add_scaled(acc, t, w / total)
    return acc


def n_params(t: PyTree) -> int:
    return sum(int(math.prod(l.shape)) for l in jax.tree_util.tree_leaves(t))


def n_bytes(t: PyTree) -> int:
    return sum(
        int(math.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(t)
    )


def flatten(t: PyTree) -> jnp.ndarray:
    """Concatenate all leaves into one flat vector (used by fused kernels)."""
    leaves = jax.tree_util.tree_leaves(t)
    return jnp.concatenate([jnp.ravel(l) for l in leaves])


def unflatten(template: PyTree, flat: jnp.ndarray) -> PyTree:
    """Inverse of :func:`flatten`, using ``template`` for shapes/treedef."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out, off = [], 0
    for l in leaves:
        size = int(math.prod(l.shape))
        out.append(jnp.reshape(flat[off : off + size], l.shape).astype(l.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def any_nan(t: PyTree):
    leaves = tree_map(lambda x: jnp.any(jnp.isnan(x)), t)
    return jax.tree_util.tree_reduce(jnp.logical_or, leaves)


def cast(t: PyTree, dtype) -> PyTree:
    return tree_map(lambda x: x.astype(dtype), t)
