"""Differentially private RWSADMM (the paper's §6 future-work item).

Mechanism: the only thing a client transmits is its contribution delta
Δc = c_new − c_old (Eq. 14's upload). We clip Δc to an l2 ball of radius
``clip`` and add Gaussian noise σ·clip — the standard Gaussian mechanism,
giving (ε, δ)-DP per round w.r.t. the client's local dataset; composition
over T visits follows the usual moments accountant bound (reported here
with the simple advanced-composition formula).

This is exactly where DP belongs in RWSADMM: x_i and z_i never leave the
client; the walking token y only ever sees clipped+noised deltas.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from . import tree

PyTree = Any


def clip_tree(t: PyTree, clip: float) -> PyTree:
    """Project onto the l2 ball of radius ``clip`` (global norm)."""
    nrm = tree.norm(t)
    scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
    return tree.scale(t, scale)


def gaussian_noise_like(key, t: PyTree, sigma: float) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(t)
    keys = jax.random.split(key, len(leaves))
    noised = [jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
              * sigma for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, noised)


def privatize_delta(key, c_new: PyTree, c_old: PyTree, *, clip: float,
                    noise_multiplier: float) -> PyTree:
    """DP upload: clip(Δc) + N(0, (σ·clip)²). Returns the private Δc."""
    delta = tree.sub(c_new, c_old)
    delta = clip_tree(delta, clip)
    noise = gaussian_noise_like(key, delta, noise_multiplier * clip)
    return tree.add(delta, noise)


def epsilon_advanced_composition(noise_multiplier: float, visits: int,
                                 delta: float = 1e-5) -> float:
    """(ε, δ) after ``visits`` Gaussian-mechanism releases (advanced
    composition; loose vs RDP but dependency-free)."""
    if noise_multiplier <= 0:
        return float("inf")
    eps_step = math.sqrt(2.0 * math.log(1.25 / delta)) / noise_multiplier
    if eps_step > 50.0:  # exp() would overflow; privacy is vacuous anyway
        return float("inf")
    return (math.sqrt(2.0 * visits * math.log(1.0 / delta)) * eps_step
            + visits * eps_step * (math.exp(eps_step) - 1.0))
