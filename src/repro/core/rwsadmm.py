"""RWSADMM: Random Walk Stochastic ADMM (paper §3.1, Algorithm 1).

The optimization problem (paper Eq. 1/7):

    min_{x_1..n}  (1/n) Σ_i f_i(x_i)
    s.t.          |x_i − x_j| ≤ ε_i   ∀ j ∈ N(i)          (hard inequality)

reformulated with a server variable y ("local proximity" token carried by
the mobile server) and solved by stochastic ADMM with closed-form updates:

    x ← y' + (1/β)·sgn(t') ⊙ (z' − ε − g)            (Eq. 11, t' = y' − x')
    z ← z' + κβ·(x − y' − ε)                         (Eq. 15, κ decayed)
    y ← y' + (1/n_i)·[ c(x, z) − c(x', z') ]          (Eq. 14, incremental)
        with contribution  c(x, z) = x − (z/β + ε) ⊙ sgn(y' − x)

All three updates are **elementwise** over the parameter pytree — this is
what makes the per-round cost O(p) compute and O(1) communication (the
y token is the only thing that moves with the server).

Everything here is functional JAX (jit/vmap-safe). Host-side orchestration
(random walk, graph regeneration, κ decay bookkeeping) lives in
``repro.fl.simulation``; the mesh-parallel zone step lives in
``repro.launch``.

Implementation notes vs the paper:
  * Eq. (14)'s typography is ambiguous about whether 1/n_i scales both
    bracket terms; deriving the incremental form from the closed-form
    solution Eq. (13) (y = (1/n_i) Σ_j c_j) requires it to scale the
    *difference*, which is what we implement:
        y ← y' + (1/n_i)(c_new − c_old).
    The multi-client generalization (Eq. 31) follows the same derivation:
        y ← y' + (1/n_i) Σ_{j∈S} (c_new_j − c_old_j).
  * ε is a scalar broadcast over parameters (paper's experiments use
    ε = 1e-5 for every client); vector ε_i per client is supported by
    passing an array.
  * sgn is jnp.sign (sgn(0) = 0); the paper leaves sgn(0) unspecified.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import tree

PyTree = Any


@dataclasses.dataclass(frozen=True)
class RWSADMMHparams:
    """Hyperparameters (paper App. D.3).

    beta: ADMM barrier parameter β. Theory needs β > 2L² + L + 2
        (Lemma 4.7); the experiments use 10/100 depending on dataset.
    kappa: initial dual step κ (Eq. 15); decayed ×``kappa_decay`` per round
        (Algorithm 1 line: κ = 0.99 κ).
    epsilon: hard-constraint relaxation ε (paper uses 1e-5). The split
        ε_half = ε/2 enters the reformulated constraint (Eq. 7).
    """

    beta: float = 10.0
    kappa: float = 0.001
    kappa_decay: float = 0.99
    epsilon: float = 1e-5

    @property
    def eps_half(self) -> float:
        return self.epsilon / 2.0


class ClientState(NamedTuple):
    """Per-client ADMM variables (kept on the client between visits)."""

    x: PyTree  # personalized model parameters
    z: PyTree  # dual variable


class ServerState(NamedTuple):
    """The token the mobile server carries."""

    y: PyTree       # local-proximity variable (Eq. 7)
    kappa: jnp.ndarray  # current dual step size (decayed per round)
    round: jnp.ndarray  # iteration counter k


def init_states(params_template: PyTree, hp: RWSADMMHparams,
                n_clients: int | None = None):
    """Paper Eq. (32): x⁰ = z⁰ = 0, y¹ = (1/n) Σ (x⁰ − z⁰/β) = 0.

    When ``n_clients`` is given, client states are stacked on a leading
    axis (the layout used by the vmapped simulation runner).
    """
    zeros = tree.zeros_like(params_template)
    if n_clients is None:
        client = ClientState(x=zeros, z=zeros)
    else:
        stacked = jax.tree_util.tree_map(
            lambda l: jnp.zeros((n_clients,) + l.shape, l.dtype), params_template
        )
        client = ClientState(x=stacked, z=stacked)
    server = ServerState(
        y=zeros,
        kappa=jnp.asarray(hp.kappa, jnp.float32),
        round=jnp.asarray(0, jnp.int32),
    )
    return client, server


def init_states_warm(params: PyTree, hp: RWSADMMHparams,
                     n_clients: int) -> tuple[ClientState, ServerState]:
    """Warm initialization from a model init (all clients share it).

    The paper's theory initializes at 0 (Eq. 32), which is fine for MLR but
    wasteful for deep nets; starting every x_i = y = params, z = 0 keeps
    Eq. (32)'s invariant y = (1/n)Σ(x_i − z_i/β)."""
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (n_clients,) + l.shape), params
    )
    client = ClientState(x=stacked, z=tree.zeros_like(stacked))
    server = ServerState(
        y=params,
        kappa=jnp.asarray(hp.kappa, jnp.float32),
        round=jnp.asarray(0, jnp.int32),
    )
    return client, server


# ---------------------------------------------------------------------------
# Closed-form updates (Eq. 11 / 15 / 14) — leafwise over pytrees.
# ---------------------------------------------------------------------------

def x_update(y_prev: PyTree, x_prev: PyTree, z_prev: PyTree, grad: PyTree,
             hp: RWSADMMHparams, *, literal_eq11: bool = False) -> PyTree:
    """Solver of the linearized x-subproblem (Eq. 10).

    Setting the subgradient of Eq. (10) to zero gives, elementwise
    (u = y' − x, s = sgn(u) approximated by sgn(t') at the previous
    iterate):

        0 = g − s·(z' + β(|u| − ε))   ⇒   x = y' − g/β + s ⊙ (z' − βε)/β

    The paper's printed Eq. (11) folds g *inside* the sign product
    (x = y' + sgn(t')⊙(z' − ε − g)/β). That form is degenerate under the
    paper's own initialization (Eq. 32 gives t' = 0 ⇒ sgn = 0 ⇒ x never
    moves) and scrambles gradient signs; we treat it as a typo for the
    derivation above — note the derived form reduces to a stochastic
    proximal-gradient step x = y' − g/β on first visit, consistent with
    the paper's tuned β=10 behaving like lr=0.1. Set ``literal_eq11=True``
    to reproduce the printed formula (used in an ablation benchmark).
    """
    beta, eps = hp.beta, hp.eps_half

    if literal_eq11:
        def leaf(y, x, z, g):
            s = jnp.sign(y - x)
            return y + (s * (z - eps - g)) / beta
    else:
        def leaf(y, x, z, g):
            s = jnp.sign(y - x)
            return y - g / beta + s * (z - beta * eps) / beta

    return tree.tree_map(leaf, y_prev, x_prev, z_prev, grad)


def z_update(x_new: PyTree, y_prev: PyTree, z_prev: PyTree,
             hp: RWSADMMHparams, kappa) -> PyTree:
    """Eq. (15): z = z' + κβ·(x − y' − ε), κ decayed per round."""
    beta, eps = hp.beta, hp.eps_half

    def leaf(x, y, z):
        return z + kappa * beta * (x - y - eps)

    return tree.tree_map(leaf, x_new, y_prev, z_prev)


def contribution(x: PyTree, z: PyTree, y_ref: PyTree,
                 hp: RWSADMMHparams) -> PyTree:
    """c(x, z) = x − (z/β + ε) ⊙ sgn(y' − x)   (the bracket of Eq. 13/14)."""
    beta, eps = hp.beta, hp.eps_half

    def leaf(x_, z_, y_):
        return x_ - (z_ / beta + eps) * jnp.sign(y_ - x_)

    return tree.tree_map(leaf, x, z, y_ref)


def y_update(y_prev: PyTree, c_new: PyTree, c_old: PyTree,
             n_total) -> PyTree:
    """Eq. (14) incremental y-update: y = y' + (1/n)(c_new − c_old).

    The printed Eq. (14) divides by n_{i_k} = |N(i_k)| (zone size), but the
    incremental form only maintains the running-average invariant that the
    paper's own initialization establishes (Eq. 32: y = (1/n)Σ_i(x_i −
    z_i/β) over ALL n clients) when the replacement is scaled by 1/n.
    Scaling by 1/n_i over-applies each replacement by n/n_i — empirically a
    geometric divergence (~×1.3/round at n=20, n_i≈6). Walkman's analogous
    token update [35] also uses 1/n. We treat Eq. (14)'s n_{i_k} as a typo
    for n; the ``benchmarks/ablations`` suite includes the literal variant
    for comparison.
    """

    def leaf(y, cn, co):
        return y + (cn - co) / n_total

    return tree.tree_map(leaf, y_prev, c_new, c_old)


def subproblem_grad(x: PyTree, y_prev: PyTree, z: PyTree, grad_f: PyTree,
                    hp: RWSADMMHparams) -> PyTree:
    """(Sub)gradient of the x-subproblem objective (Eq. 9):

        F(x) = f(x) + ⟨z, |y'−x| − ε⟩ + (β/2)‖|y'−x| − ε‖²
        ∇F   = ∇f(x) + sgn(x−y')⊙(z − βε) + β(x − y')

    Used by the iterative (prox-SGD) solver of Eq. (9) — the paper's
    original subproblem before the one-step stochastic linearization of
    Eq. (10). Multiple stochastic steps on this objective match the
    paper's reported per-iteration wall-clock (≈seconds, vs ms for one
    minibatch gradient) and give the dual/constraint structure teeth.
    """
    beta, eps = hp.beta, hp.eps_half

    def leaf(x_, y_, z_, g_):
        t = x_ - y_
        return g_ + jnp.sign(t) * (z_ - beta * eps) + beta * t

    return tree.tree_map(leaf, x, y_prev, z, grad_f)


def client_round(client: ClientState, y_prev: PyTree, grad: PyTree,
                 hp: RWSADMMHparams, kappa, *, literal_eq11: bool = False):
    """One client's full local update when the server is in range.

    Returns the new client state plus the (c_new, c_old) contribution pair
    the server needs for its incremental y-update. This is everything that
    crosses the wireless link — O(1) tensors, independent of n.
    """
    c_old = contribution(client.x, client.z, y_prev, hp)
    x_new = x_update(y_prev, client.x, client.z, grad, hp,
                     literal_eq11=literal_eq11)
    z_new = z_update(x_new, y_prev, client.z, hp, kappa)
    c_new = contribution(x_new, z_new, y_prev, hp)
    return ClientState(x=x_new, z=z_new), c_new, c_old


def zone_round(clients: ClientState, y_prev: PyTree, grads: PyTree,
               hp: RWSADMMHparams, kappa, n_total):
    """Multi-client zone update (paper Eq. 31): all active clients in
    S(i_k) update in parallel (stacked leading axis), then the server folds
    the summed contribution deltas into y.

    clients / grads: pytrees with a leading ``S`` axis (active clients).
    n_total: total client count n (see :func:`y_update` for why the fold
    uses 1/n rather than the printed 1/n_i).
    """
    upd = jax.vmap(
        lambda c, g: client_round(c, y_prev, g, hp, kappa),
        in_axes=(0, 0),
    )
    new_clients, c_new, c_old = upd(clients, grads)
    delta = tree.tree_map(
        lambda cn, co: jnp.sum(cn - co, axis=0), c_new, c_old
    )
    y_new = tree.tree_map(lambda y, d: y + d / n_total, y_prev, delta)
    return new_clients, y_new


def zone_round_masked(clients: ClientState, y_prev: PyTree, grads: PyTree,
                      mask: jnp.ndarray, hp: RWSADMMHparams, kappa, n_total):
    """Masked fixed-shape variant of :func:`zone_round` (paper Eq. 31).

    clients / grads carry a padded leading ``Z`` axis; ``mask`` (Z,) marks
    live slots. Padded slots pass their x/z through unchanged and
    contribute zero to the y fold, so a whole run reuses one executable
    regardless of the realized zone size. This is the pure-jnp oracle for
    the fused Pallas kernel (``kernels.rwsadmm_update.ops.
    rwsadmm_zone_fused_update``), which computes the same math in a
    single HBM pass.
    """
    upd = jax.vmap(
        lambda c, g: client_round(c, y_prev, g, hp, kappa),
        in_axes=(0, 0),
    )
    new_act, c_new, c_old = upd(clients, grads)

    def mexpand(leaf):
        return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))

    keep = tree.tree_map(
        lambda new, old: mexpand(new) * new + (1.0 - mexpand(new)) * old,
        new_act.x, clients.x,
    )
    keep_z = tree.tree_map(
        lambda new, old: mexpand(new) * new + (1.0 - mexpand(new)) * old,
        new_act.z, clients.z,
    )
    y_new = tree.tree_map(
        lambda y, cn, co: y + jnp.sum(
            mexpand(cn) * (cn - co), axis=0) / n_total,
        y_prev, c_new, c_old,
    )
    return ClientState(x=keep, z=keep_z), y_new


def multizone_round_masked(clients: ClientState, ys: PyTree, grads: PyTree,
                           mask: jnp.ndarray, hp: RWSADMMHparams, kappa,
                           n_total):
    """K simultaneous zone rounds (fleet mode): :func:`zone_round_masked`
    vmapped over a leading walker axis.

    clients / grads carry (K, Z, ...) leading axes (K walkers × padded
    zone), ``ys`` a (K, ...) stacked token pytree, ``mask`` (K, Z). Each
    walker folds only its own zone's contribution deltas into its own
    token; the caller guarantees the K zones are disjoint
    (``markov.plan_fleet_zone_round``), so scattering the per-zone
    client updates back is conflict-free. This is the pure-jnp oracle
    for the batched multi-zone Pallas kernel
    (``kernels.rwsadmm_update.ops.rwsadmm_multizone_fused_update``).
    """
    return jax.vmap(
        lambda c, y, g, m: zone_round_masked(c, y, g, m, hp, kappa, n_total)
    )(clients, ys, grads, mask)


def server_round_done(server: ServerState, y_new: PyTree,
                      hp: RWSADMMHparams) -> ServerState:
    """Advance the server token: store y, decay κ (Algorithm 1)."""
    return ServerState(
        y=y_new,
        kappa=server.kappa * hp.kappa_decay,
        round=server.round + 1,
    )


# ---------------------------------------------------------------------------
# Lyapunov monitors (Eq. 8 / 25) — used by tests & convergence diagnostics.
# ---------------------------------------------------------------------------

def augmented_lagrangian(y: PyTree, xs: ClientState, losses: jnp.ndarray,
                         hp: RWSADMMHparams) -> jnp.ndarray:
    """L_β(y, X; Z) of Eq. (8) with the single global token y.

    xs: stacked client states (leading axis n). losses: per-client f_i(x_i).
    """
    beta, eps = hp.beta, hp.eps_half

    def per_leaf(x, z, y_):
        r = jnp.abs(y_[None] - x) - eps          # |y − x_i| − ε, per client
        inner = jnp.sum(z * r, axis=tuple(range(1, r.ndim)))
        quad = jnp.sum(r * r, axis=tuple(range(1, r.ndim)))
        return inner + (beta / 2.0) * quad

    leaves = jax.tree_util.tree_map(per_leaf, xs.x, xs.z, y)
    per_client = jax.tree_util.tree_reduce(jnp.add, leaves)  # (n,)
    n = losses.shape[0]
    return (jnp.sum(losses) + jnp.sum(per_client)) / n


def lyapunov_m(l_beta: jnp.ndarray, last_x_delta_sq: jnp.ndarray,
               lipschitz: float, n: int) -> jnp.ndarray:
    """M_β^k = L_β^k + (L²/n) Σ_i ||x_i^{τ(k,i)+1} − x_i^{τ(k,i)}||²
    (Eq. 25 as used in Lemma B.4). ``last_x_delta_sq``: per-client squared
    norm of the most recent x update (0 until first visit)."""
    return l_beta + (lipschitz**2 / n) * jnp.sum(last_x_delta_sq)


def constraint_violation(y: PyTree, xs_stacked: PyTree,
                         hp: RWSADMMHparams) -> jnp.ndarray:
    """max_i || max(|y − x_i| − ε/2, 0) ||_∞ — hard-constraint residual of
    the reformulated problem (Eq. 7). → 0 at feasibility."""
    eps = hp.eps_half

    def leaf(x, y_):
        v = jnp.maximum(jnp.abs(y_[None] - x) - eps, 0.0)
        return jnp.max(v)

    leaves = jax.tree_util.tree_map(leaf, xs_stacked, y)
    return jax.tree_util.tree_reduce(jnp.maximum, leaves)


def pairwise_violation(xs_stacked: PyTree, adjacency: jnp.ndarray,
                       hp: RWSADMMHparams) -> jnp.ndarray:
    """max over edges (i,j) of || max(|x_i − x_j| − ε, 0) ||_∞ — the
    ORIGINAL constraint of Eq. (1), implied by Eq. (7) via triangle
    inequality."""
    eps = hp.epsilon

    def leaf(x):
        diff = jnp.abs(x[:, None] - x[None])  # (n, n, ...)
        viol = jnp.maximum(diff - eps, 0.0)
        axes = tuple(range(2, viol.ndim))
        v = jnp.max(viol, axis=axes) if axes else viol
        return jnp.max(jnp.where(adjacency, v, 0.0))

    leaves = jax.tree_util.tree_map(leaf, xs_stacked)
    return jax.tree_util.tree_reduce(jnp.maximum, leaves)


def beta_lower_bound(lipschitz: float) -> float:
    """Theory threshold β > 2L² + L + 2 (Lemma 4.7 / Theorem 4.8)."""
    return 2.0 * lipschitz**2 + lipschitz + 2.0
