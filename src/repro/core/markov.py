"""Markov-chain machinery for the mobile server's random walk.

Implements the paper's §3:
  * transition matrix  [P(k)]_{ij} = 1/deg(i) for j ~ i  (experiments §5),
  * Metropolis-Hastings variant (uniform stationary distribution π = 1/n,
    which makes Assumption 3.1's π_* as large as possible — used when a
    uniform client-visit frequency is desired),
  * stationary distribution π, spectral quantities σ(P), λ₂(P),
  * mixing time τ(δ) from Eq. (6),
  * P_max elementwise envelope (Eq. (5)) for the dynamic chain,
  * random-walk sampling of the visited-client sequence (i_k),
  * importance-biased walk policies (staleness / label-skew targets with
    the Walk-for-Learning importance-weight correction — see
    ``docs/walks.md``).
"""
from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Any, Sequence

import numpy as np

from . import graph as graph_mod
from .graph import ClientGraph, NeighborGraph


def degree_transition_matrix(graph: ClientGraph) -> np.ndarray:
    """[P]_{ij} = 1/deg(i) for j in N(i)\\{i}; the paper's experimental
    choice. Stationary distribution is π_i ∝ deg(i)."""
    adj = graph.adjacency.astype(np.float64)
    deg = adj.sum(axis=1, keepdims=True)
    return adj / np.maximum(deg, 1.0)


def metropolis_transition_matrix(graph: ClientGraph) -> np.ndarray:
    """Metropolis-Hastings weights: uniform stationary distribution.

    P_ij = min(1/deg(i), 1/deg(j)) for j~i; self-loop absorbs the rest.

    Vectorized: one (n, n) elementwise min instead of a Python double
    loop (this runs at every regeneration epoch, and every round under
    link-dropout scenarios). Pinned against the loop form in
    ``tests/test_graph_markov.py``.
    """
    adj = graph.adjacency.astype(np.float64)
    deg = adj.sum(axis=1)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    p = adj * np.minimum(inv[:, None], inv[None, :])
    # The rounded min(1/deg_i, 1/deg_j) terms can sum a hair above 1
    # even though the exact sum never does; a −2⁻⁵² self-loop would
    # poison rng.choice mid-walk, so clamp (mirrored in _sparse_row
    # and the biased builders so all row constructions stay
    # bit-identical).
    np.fill_diagonal(p, np.maximum(1.0 - p.sum(axis=1), 0.0))
    return p


def biased_transition_matrix(graph: ClientGraph,
                             weights: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings chain targeting π ∝ ``weights``.

    P_ij = min(1/deg(i), w_j / (w_i · deg(j))) for j ~ i; the self-loop
    absorbs the rest. With w ≡ 1 this is *float-identical* to
    :func:`metropolis_transition_matrix` (min(1/deg_i, 1/deg_j)).
    Detailed balance: w_i·P_ij = min(w_i/deg_i, w_j/deg_j) = w_j·P_ji,
    so the stationary distribution is exactly w/Σw on any connected
    graph — the lever the biased walk policies (staleness, label-skew)
    pull to steer visit frequencies, with the induced sampling bias
    undone by the 1/(n·π_i) importance weights (``docs/walks.md``).
    """
    adj = graph.adjacency.astype(np.float64)
    deg = adj.sum(axis=1)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    w = np.asarray(weights, np.float64)
    p = adj * np.minimum(inv[:, None], (w[None, :] * inv[None, :])
                         / w[:, None])
    # The rounded w_j/(w_i·deg_j) terms can sum a hair above 1 even
    # though the exact sum never does; a −2⁻⁵² self-loop would poison
    # rng.choice, so clamp (mirrored bit-for-bit in _biased_row).
    np.fill_diagonal(p, np.maximum(1.0 - p.sum(axis=1), 0.0))
    return p


def stationary_distribution(p: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """π with πᵀP = πᵀ, via power iteration on Pᵀ."""
    n = p.shape[0]
    pi = np.full(n, 1.0 / n)
    for _ in range(100_000):
        nxt = pi @ p
        if np.abs(nxt - pi).max() < tol:
            pi = nxt
            break
        pi = nxt
    return pi / pi.sum()


def sigma(p: np.ndarray) -> float:
    """σ(P) := sup { ||fᵀP|| / ||f|| : fᵀ1 = 0 }  (paper Eq. 6).

    Equals the largest singular value of Pᵀ restricted to 1⊥.
    """
    n = p.shape[0]
    # Orthonormal basis of 1-perp via QR of [1 | I].
    q, _ = np.linalg.qr(np.concatenate([np.ones((n, 1)) / math.sqrt(n),
                                        np.eye(n)[:, : n - 1]], axis=1))
    basis = q[:, 1:]  # (n, n-1), orthonormal, ⊥ 1
    m = basis.T @ p @ p.T @ basis
    ev = np.linalg.eigvalsh(m)
    return float(np.sqrt(max(ev.max(), 0.0)))


def lambda2(p: np.ndarray) -> float:
    """Second-largest eigenvalue modulus (reversible-chain rate, Eq. 30)."""
    ev = np.linalg.eigvals(p)
    ev = np.sort(np.abs(ev))[::-1]
    return float(ev[1]) if len(ev) > 1 else 0.0


def mixing_time(p: np.ndarray, delta: float = 0.5,
                pi: np.ndarray | None = None) -> int:
    """τ(δ) = ceil( ln(√2/(δ π_*)) / (1 − σ(P)) )   (paper Eq. 6)."""
    if pi is None:
        pi = stationary_distribution(p)
    pi_star = float(pi.min())
    s = sigma(p)
    if s >= 1.0 - 1e-12:
        return 2**31 - 1  # non-ergodic chain: infinite mixing time
    return int(math.ceil(math.log(math.sqrt(2.0) / (delta * pi_star))
                         / (1.0 - s)))


def p_max_envelope(ps: list[np.ndarray]) -> np.ndarray:
    """Eq. (5): elementwise max over the dynamic chain's matrices P(k)."""
    env = ps[0].copy()
    for p in ps[1:]:
        np.maximum(env, p, out=env)
    return env


def verify_assumption_3_1(p: np.ndarray, delta: float = 0.5) -> dict:
    """Empirically verify the mixing inequality Eq. (3)/(4) for τ(δ)."""
    pi = stationary_distribution(p)
    tau = mixing_time(p, delta, pi)
    if tau >= 2**30:  # non-ergodic (e.g. periodic bipartite chain)
        return {"tau": tau, "holds": False, "max_dev": float("inf"),
                "pi_star": float(pi.min()), "sigma": sigma(p),
                "lambda2": lambda2(p)}
    pt = np.linalg.matrix_power(p, tau)
    dev = np.abs(pt - pi[None, :]).max()
    return {
        "tau": tau,
        "pi_star": float(pi.min()),
        "sigma": sigma(p),
        "lambda2": lambda2(p),
        "max_dev": float(dev),
        "holds": bool(dev <= delta * pi.min() + 1e-9),
    }


# Walk-policy axis: which stationary distribution the walk targets.
# "degree"/"metropolis" are the uniform (unbiased) chains the paper uses;
# "staleness"/"label_skew" are importance-biased MH chains (π ∝ w) whose
# sampling bias the per-visit importance weights undo (docs/walks.md).
WALK_POLICIES = ("degree", "metropolis", "staleness", "label_skew")
BIASED_POLICIES = frozenset({"staleness", "label_skew"})


@dataclasses.dataclass
class RandomWalkServer:
    """The mobile server: walks the client graph per the Markov chain.

    Host-side control plane; the visited sequence (i_k) drives which zone
    the compiled SPMD round operates on.

    ``policy`` picks the chain the walk runs (defaults to ``transition``):

    * ``"degree"`` / ``"metropolis"`` — the unbiased chains (π ∝ deg,
      π uniform); importance weights are identically 1.0.
    * ``"staleness"`` — MH chain targeting π ∝ (1 + steps-since-visit)^γ
      (γ = ``bias_gamma``): under-visited clients attract the walk.
    * ``"label_skew"`` — MH chain targeting the fixed per-client data
      utilities installed via :meth:`set_label_weights` (from
      ``data.partition.label_skew_weights``): clients holding rare
      labels attract the walk.

    Every visit records an importance weight ``(Σw)/(n·w_i)`` (≡ 1/(n·π_i)
    normalized so uniform policies give 1.0) in ``weight_history``,
    aligned 1:1 with ``history`` — the Walk-for-Learning correction the
    trainers fold into the Eq. 31 y-update to keep the stochastic
    estimator unbiased under a biased visit distribution.
    """

    transition: str = "degree"  # "degree" (paper) | "metropolis"
    seed: int = 0
    policy: str | None = None   # defaults to ``transition``
    bias_gamma: float = 1.0     # staleness exponent γ

    def __post_init__(self):
        if self.policy is None:
            self.policy = self.transition
        elif self.policy in ("degree", "metropolis"):
            # A uniform policy IS a transition kind; keep them in sync so
            # matrix()/transition_row() dispatch stays single-sourced.
            self.transition = self.policy
        if self.policy not in WALK_POLICIES:
            raise ValueError(f"unknown walk policy {self.policy!r}; "
                             f"pick one of {WALK_POLICIES}")
        self._rng = np.random.default_rng(self.seed)
        self.position: int | None = None
        self.visit_counts: np.ndarray | None = None
        self.history: list[int] = []
        self.weight_history: list[float] = []
        self.label_weights: np.ndarray | None = None
        self._last_visit: np.ndarray | None = None
        self._n_seen = 0
        self._cover_step: int | None = None
        self._matrix_cache: tuple[Any, np.ndarray] | None = None

    # -- policy weights ---------------------------------------------------
    @property
    def is_biased(self) -> bool:
        return self.policy in BIASED_POLICIES

    def set_label_weights(self, weights: np.ndarray | None) -> None:
        """Install per-client utilities for the ``label_skew`` policy
        (normalized to mean 1 — importance weights are scale-invariant,
        this just keeps the floats well-conditioned)."""
        if weights is None:
            self.label_weights = None
            return
        w = np.asarray(weights, np.float64)
        if (w <= 0).any():
            raise ValueError("label weights must be strictly positive")
        self.label_weights = w / w.mean()

    def policy_weights(self, n: int) -> np.ndarray:
        """(n,) current target weights w (π ∝ w). Uniform policies → 1s.
        Deterministic in walker state, so row construction and the
        importance-weight record read identical floats."""
        if self.policy == "staleness":
            assert self._last_visit is not None, "call reset() first"
            k = len(self.history) - 1
            s = (k - self._last_visit).astype(np.float64)  # never seen → k+1
            return (1.0 + s) ** self.bias_gamma
        if self.policy == "label_skew" and self.label_weights is not None:
            if len(self.label_weights) != n:
                raise ValueError(
                    f"label weights have length {len(self.label_weights)}, "
                    f"graph has {n} clients")
            return self.label_weights
        return np.ones(n)

    def stationary_target(self, n: int) -> np.ndarray:
        """The designed stationary distribution π = w/Σw at the current
        walker state (uniform policies: exactly 1/n; the degree chain's
        deg-proportional π comes from ``stationary_distribution`` of the
        matrix instead — its target is implicit in the graph)."""
        w = self.policy_weights(n)
        return w / w.sum()

    def matrix(self, graph: ClientGraph) -> np.ndarray:
        # The graph object only changes at regeneration epochs (every
        # ``regen_every`` rounds), but step() runs every round — cache
        # the O(n²) transition matrix per graph instance (weakref so a
        # recycled id can never alias a dead graph). Biased policies are
        # never cached: their weights move with walker state (staleness)
        # or with set_label_weights, so a cached P could silently stale.
        if self.is_biased:
            g = (graph.to_dense() if isinstance(graph, NeighborGraph)
                 else graph)
            return biased_transition_matrix(g, self.policy_weights(graph.n))
        if self._matrix_cache is not None \
                and self._matrix_cache[0]() is graph:
            return self._matrix_cache[1]
        # Diagnostics-only densification for sparse graphs: the walking
        # hot paths (step / walk_schedule*) never come through here for
        # a NeighborGraph — they sample O(deg) rows directly.
        g = graph.to_dense() if isinstance(graph, NeighborGraph) else graph
        if self.transition == "degree":
            p = degree_transition_matrix(g)
        elif self.transition == "metropolis":
            p = metropolis_transition_matrix(g)
        else:
            raise ValueError(f"unknown transition kind {self.transition!r}")
        self._matrix_cache = (weakref.ref(graph), p)
        return p

    def reset(self, graph: ClientGraph, start: int | None = None) -> int:
        self.visit_counts = np.zeros(graph.n, dtype=np.int64)
        self.history = []
        self.weight_history = []
        self._last_visit = np.full(graph.n, -1, dtype=np.int64)
        self._n_seen = 0
        self._cover_step = None
        self.position = (int(self._rng.integers(graph.n))
                         if start is None else int(start))
        self._record_visit(self.position, graph.n, initial=True)
        return self.position

    def _record_visit(self, i: int, n: int, *, initial: bool = False) -> None:
        """Shared visit bookkeeping for reset/step/batched-step: counts,
        history, the importance weight of THIS visit (from the weight
        vector the step was drawn under — before the visit mutates it),
        the staleness clock, and the incremental first-full-coverage
        step that makes :meth:`hitting_time` O(1)."""
        if initial or not self.is_biased:
            iw = 1.0   # start position / unbiased chain: no correction
        else:
            w = self.policy_weights(n)
            iw = float(w.sum() / (n * w[i]))
        if self.visit_counts[i] == 0:
            self._n_seen += 1
            if self._n_seen == n and self._cover_step is None:
                self._cover_step = len(self.history)
        self.visit_counts[i] += 1
        self.history.append(i)
        self.weight_history.append(iw)
        self._last_visit[i] = len(self.history) - 1

    def transition_row(self, graph: ClientGraph, i: int) -> np.ndarray:
        """Row i of P(k) — all one walk step needs. A cached full matrix
        is reused when present (static graphs between regens); otherwise
        the degree chain builds just the O(n) row, so link-dropout
        scenarios (a fresh surviving graph every round) skip the O(n²)
        full-matrix rebuild per round. The row values are bit-identical
        to the matrix row (0/1 sums are exact, one division either way).
        Metropolis rows need every node's degree, so that chain still
        goes through the cached matrix. Biased policies always build the
        row fresh (their weights move with walker state) through the
        backend-shared scatter in :meth:`_biased_row`, so dense and
        sparse backends read bit-identical rows."""
        if self.is_biased:
            _, row = self._biased_row(graph, i)
            return row
        if self._matrix_cache is not None \
                and self._matrix_cache[0]() is graph:
            return self._matrix_cache[1][i]
        if isinstance(graph, NeighborGraph):
            cands, probs = self._sparse_row(graph, i)
            row = np.zeros(graph.n)
            row[cands] = probs
            return row
        if self.transition == "degree":
            row = graph.adjacency[i].astype(np.float64)
            return row / max(row.sum(), 1.0)
        return self.matrix(graph)[i]

    def _biased_row(self, graph: ClientGraph, i: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        """(candidates, full row) of the biased MH chain at node i —
        ONE construction for both graph backends. Only the neighbor /
        degree gather differs per backend (identical integers either
        way); every float op afterwards is shared, so dense and sparse
        rows are bit-identical by construction, and both match the
        elementwise expression in :func:`biased_transition_matrix`
        (same multiply/divide order, same length-n pairwise sum for
        the self-loop mass)."""
        w = self.policy_weights(graph.n)
        if isinstance(graph, NeighborGraph):
            nbrs = graph.neighbors(i)
            deg_nb = graph.nbr_mask[nbrs].sum(axis=1).astype(np.float64)
        else:
            nbrs = np.flatnonzero(graph.adjacency[i])
            nbrs = nbrs[nbrs != i]
            deg_nb = graph.adjacency[nbrs].astype(np.float64).sum(axis=1)
        deg_i = np.float64(len(nbrs))
        inv_i = np.where(deg_i > 0, 1.0 / np.maximum(deg_i, 1.0), 0.0)
        inv_nb = np.where(deg_nb > 0, 1.0 / np.maximum(deg_nb, 1.0), 0.0)
        row = np.zeros(graph.n)
        row[nbrs] = np.minimum(inv_i, (w[nbrs] * inv_nb) / w[i])
        # Same float-error clamp as biased_transition_matrix: rounding
        # in the off-diagonal terms can push their sum past 1.
        row[i] = max(1.0 - row.sum(), 0.0)
        cands = np.insert(nbrs, np.searchsorted(nbrs, i), i)
        return cands, row

    def _sparse_row(self, graph: NeighborGraph, i: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        """(candidates, probs): the nonzero support of row i of P(k), in
        ascending client order, for a neighbor-list graph — O(deg) for
        the degree chain instead of the dense row's O(n).

        The floats match the dense row exactly: the degree chain divides
        by the same degree, and the Metropolis self-loop scatters the
        neighbor masses into a length-n row first so ``1 − row.sum()``
        reduces with the same pairwise summation the dense matrix row
        uses. Together with the choice emulation in :meth:`step` this
        makes sparse walks replay dense walks draw-for-draw (pinned in
        ``tests/test_sparse_backend.py``).
        """
        if self.is_biased:
            cands, row = self._biased_row(graph, i)
            return cands, row[cands]
        if self.transition == "degree":
            nbrs = graph.neighbors(i)
            return nbrs, np.full(len(nbrs), 1.0) / max(float(len(nbrs)),
                                                       1.0)
        if self.transition != "metropolis":
            raise ValueError(f"unknown transition kind {self.transition!r}")
        nbrs = graph.neighbors(i)
        # Only deg(i) and deg(j) for j ~ i are needed — O(deg²) worst
        # case, not the full (n, k_cap) mask reduction. The values are
        # integer-valued float64 divisions, so they equal the dense
        # matrix's elementwise 1/deg floats exactly.
        deg_i = np.float64(len(nbrs))
        deg_nb = graph.nbr_mask[nbrs].sum(axis=1).astype(np.float64)
        inv_i = np.where(deg_i > 0, 1.0 / np.maximum(deg_i, 1.0), 0.0)
        inv_nb = np.where(deg_nb > 0, 1.0 / np.maximum(deg_nb, 1.0), 0.0)
        # Scatter into a length-n row so the self-loop mass reduces
        # with the same pairwise summation the dense matrix row uses.
        row = np.zeros(graph.n)
        row[nbrs] = np.minimum(inv_i, inv_nb)
        # Same float-error clamp as metropolis_transition_matrix.
        row[i] = max(1.0 - row.sum(), 0.0)
        cands = np.insert(nbrs, np.searchsorted(nbrs, i), i)
        return cands, row[cands]

    def _sample_sparse(self, graph: NeighborGraph, u: float) -> int:
        """Map one uniform through row ``position``'s CDF exactly as
        ``Generator.choice(n, p=row)`` does on the dense row (cumsum,
        normalize, searchsorted-right): the zero-mass entries of the
        dense row never move the CDF's float levels, so the compressed
        search lands on the same client for the same uniform."""
        cands, probs = self._sparse_row(graph, self.position)
        cdf = probs.cumsum()
        cdf /= cdf[-1]
        j = int(np.searchsorted(cdf, u, side="right"))
        return int(cands[min(j, len(cands) - 1)])

    def step(self, graph: ClientGraph) -> int:
        """One random-walk move: i_{k+1} ~ [P(k)]_{i_k, ·} (Eq. 2)."""
        assert self.position is not None, "call reset() first"
        if isinstance(graph, NeighborGraph):
            self.position = self._sample_sparse(graph, self._rng.random())
        else:
            row = self.transition_row(graph, self.position)
            # The dynamic graph may have disconnected the current node
            # from its old neighbors; row always sums to 1 on the
            # *current* graph.
            self.position = int(self._rng.choice(graph.n, p=row))
        self._record_visit(self.position, graph.n)
        return self.position

    def hitting_time(self) -> int | None:
        """T = max_i T_i once every client has been visited (paper §4).
        O(1): the first-full-coverage step is tracked incrementally by
        ``_record_visit`` instead of rescanning the visit history on
        every call (regression-pinned against the oracle scan)."""
        if self.visit_counts is None:
            return None
        return self._cover_step

    def walk_schedule(self, graphs: Sequence[ClientGraph],
                      *, advance_first: bool = True) -> np.ndarray:
        """Batch variant of :meth:`step`: the visited sequence (i_k) over a
        precomputed graph schedule (one graph per round).

        Consumes the walk RNG exactly as per-round ``step()`` calls would,
        so eager and compiled-schedule drivers visit identical clients.
        ``advance_first=False`` keeps the first entry at the current
        position (the round-0 convention: the server starts *at* a client
        before its first move).
        """
        positions = np.empty(len(graphs), dtype=np.int64)
        for k, graph in enumerate(graphs):
            if k == 0 and not advance_first:
                assert self.position is not None, "call reset() first"
                positions[k] = self.position
            else:
                positions[k] = self.step(graph)
        return positions

    def walk_schedule_batched(self, graphs: Sequence[ClientGraph],
                              *, advance_first: bool = True) -> np.ndarray:
        """Inverse-CDF variant of :meth:`walk_schedule`: all step uniforms
        are pre-drawn in ONE ``rng.random`` call and each step maps its
        uniform through the transition row's CDF — O(1) RNG dispatches
        per window instead of one ``Generator.choice`` (which rebuilds a
        CDF and re-enters the generator) per round.

        RNG-STREAM BREAK: raw uniforms consume the walker's bit stream
        differently from ``choice``, so a run mixing this with eager
        ``step()`` calls diverges. It therefore ships opt-in (the
        trainers' ``batched_walk`` flag); the stream it does produce is
        deterministic, chunk-composable (``random(a)`` then ``random(b)``
        equals ``random(a+b)`` for PCG64), and pinned by a seed-stability
        test so it can never drift silently.
        """
        rounds = len(graphs)
        positions = np.empty(rounds, dtype=np.int64)
        start = 0
        if rounds and not advance_first:
            assert self.position is not None, "call reset() first"
            positions[0] = self.position
            start = 1
        u = self._rng.random(rounds - start)
        for k in range(start, rounds):
            assert self.position is not None, "call reset() first"
            if isinstance(graphs[k], NeighborGraph):
                cands, row = self._sparse_row(graphs[k], self.position)
            else:
                cands = None
                row = self.transition_row(graphs[k], self.position)
            cdf = np.cumsum(row)
            # Scale by the realized total (≈1.0) so fp undershoot in the
            # cumsum can never push the draw past the last bin.
            j = int(np.searchsorted(cdf, u[k - start] * cdf[-1],
                                    side="right"))
            # A uniform within 1 ulp of 1.0 can land past the last
            # positive-mass bin (trailing zero-probability states share
            # cdf[-1]); clamp to the first bin reaching the total — the
            # last state the row actually supports. The sparse lane's
            # compressed CDF shares the dense CDF's float levels, so
            # the clamp index maps to the same client.
            j = min(j, int(np.searchsorted(cdf, cdf[-1], side="left")))
            self.position = int(cands[j]) if cands is not None else j
            self._record_visit(self.position, graphs[k].n)
            positions[k] = self.position
        return positions

    def walk_weights(self, rounds: int) -> np.ndarray | None:
        """(R,) importance weights of the walker's last ``rounds``
        visits (the schedule column the trainers consume), or ``None``
        for unbiased policies — the engines then skip the correction
        entirely, keeping the uniform-policy computation graphs (and
        their bit-identical pins) untouched."""
        if not self.is_biased:
            return None
        if rounds == 0:
            return np.zeros(0, np.float64)
        assert rounds <= len(self.weight_history)
        return np.asarray(self.weight_history[-rounds:], np.float64)


# ---------------------------------------------------------------------------
# Precomputed zone schedules — the host-side half of the compiled
# multi-round (lax.scan) driver. Everything data-dependent that the random
# walk decides (which client, which zone members, which PRNG key) is
# resolved here into fixed-shape arrays; the device then runs R rounds as
# one XLA executable with no host round-trips.
# ---------------------------------------------------------------------------


def round_key_seed(rng: np.random.Generator) -> int:
    """Draw one round's PRNG-key seed from the shared simulation RNG.

    The single choke point for per-round key derivation: the eager
    drivers (single-walker, fleet) and the schedule precompute all draw
    through here, so their key streams are identical *by construction* —
    the eager/scan equivalence pins are structural, not incidental.
    """
    return int(rng.integers(2**31 - 1))


def round_key(rng: np.random.Generator):
    """Eager-driver form: materialize the round's key on device."""
    import jax

    return jax.random.PRNGKey(round_key_seed(rng))


def round_keys(seeds: np.ndarray) -> np.ndarray:
    """Schedule form: one batched dispatch for a whole window's key block
    (threefry init is jit-traced, so vmap over seeds matches per-seed
    ``PRNGKey`` bit-for-bit)."""
    import jax

    return np.asarray(jax.vmap(jax.random.PRNGKey)(np.asarray(seeds)))


@dataclasses.dataclass(frozen=True)
class ZoneSchedule:
    """R precomputed zone rounds as fixed-shape host arrays.

    idx:     (R, Z) int32 — active-client ids, padded with 0.
    mask:    (R, Z) float32 — 1 for live slots, 0 for padding.
    n_i:     (R,) float32 — |N(i_k)| zone sizes (pre-subsampling).
    keys:    (R, 2) uint32 — per-round PRNG keys (minibatch sampling).
    clients: (R,) int32 — the visited client i_k per round.
    active:  (R,) int32 — number of live slots per round (≤ Z).

    When the schedule is built from a scenario with a wireless comm
    model (``scenarios/``), two extra host-side columns price each
    round; they never enter the compiled scan (control-plane only):

    latency_s: (R,) float64 — expected round latency, or None.
    energy_j:  (R,) float64 — expected round radio energy, or None.

    Under a biased walk policy (``RandomWalkServer.policy`` in
    ``BIASED_POLICIES``) one more per-round column rides along, consumed
    by BOTH engines' Eq. 31 y-update (the Walk-for-Learning correction):

    iw: (R,) float64 — importance weight 1/(n·π_{i_k}) of the visited
        client, or None for unbiased policies (engines skip the
        correction entirely — the uniform computation graph, and its
        bit-identical eager ≡ scan pins, stay untouched).
    """

    idx: np.ndarray
    mask: np.ndarray
    n_i: np.ndarray
    keys: np.ndarray
    clients: np.ndarray
    active: np.ndarray
    latency_s: np.ndarray | None = None
    energy_j: np.ndarray | None = None
    iw: np.ndarray | None = None

    @property
    def rounds(self) -> int:
        return int(self.idx.shape[0])

    @property
    def zone_size(self) -> int:
        return int(self.idx.shape[1])


def plan_zone_round(
    graph: ClientGraph,
    i_k: int,
    zone_size: int,
    rng: np.random.Generator,
    avail: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Form the active zone S(i_k) ⊆ N(i_k) for one round (Eq. 31 subset).

    Returns (idx (Z,), mask (Z,), n_i). Zones larger than ``zone_size``
    are subsampled: i_k plus random neighbors, drawn from ``rng`` — the
    single host RNG shared with per-round key generation, so schedule
    precomputation replays the eager driver's draw sequence exactly.

    ``avail`` is an optional (n,) bool client-availability mask (churn /
    duty-cycling, ``scenarios/``): offline neighbors are dropped from the
    zone before subsampling. The visited client i_k always participates —
    the server is physically at its location. ``avail=None`` (the default)
    consumes ``rng`` identically to the pre-scenario code path.
    """
    zone = graph.neighborhood(i_k)
    if avail is not None:
        zone = zone[avail[zone] | (zone == i_k)]
    n_i = len(zone)
    if n_i > zone_size:
        others = zone[zone != i_k]
        pick = rng.choice(others, size=zone_size - 1, replace=False)
        active = np.concatenate([[i_k], pick])
    else:
        active = zone
    mask = np.zeros(zone_size, np.float32)
    mask[: len(active)] = 1.0
    idx = np.zeros(zone_size, np.int32)
    idx[: len(active)] = active
    return idx, mask, n_i


def _plan_rounds(graphs, positions, zone_size, rng, avails):
    """The shared per-round planning loop: zone membership + key seeds.

    Inherently sequential in ``rng`` (subsample draws and key seeds
    interleave in round order, replaying the eager drivers exactly), so
    it stays a host loop; everything around it — walk stepping, key
    materialization, pricing — is batched by the callers.
    """
    rounds = len(graphs)
    z = zone_size
    idx = np.zeros((rounds, z), np.int32)
    mask = np.zeros((rounds, z), np.float32)
    n_i = np.zeros((rounds,), np.float32)
    seeds = np.zeros((rounds,), np.int64)
    active = np.zeros((rounds,), np.int32)
    for k in range(rounds):
        idx[k], mask[k], n_i[k] = plan_zone_round(
            graphs[k], int(positions[k]), z, rng,
            avail=None if avails is None else avails[k],
        )
        active[k] = int(mask[k].sum())
        seeds[k] = round_key_seed(rng)
    return idx, mask, n_i, seeds, active


def zone_schedule(
    dyn_graph,
    walker: RandomWalkServer,
    rounds: int,
    zone_size: int,
    rng: np.random.Generator,
    *,
    start_round: int = 0,
    price=None,
    batched_walk: bool = False,
) -> ZoneSchedule:
    """Precompute ``rounds`` zone rounds: graphs (covering regeneration
    epochs), random-walk positions, padded zone membership, and PRNG keys.

    Advances ``dyn_graph``, ``walker``, and ``rng`` exactly as the same
    number of eager per-round calls would, so chunked schedules compose:
    ``zone_schedule(..., R1) + zone_schedule(..., R2, start_round=R1)``
    reproduces one eager run of R1+R2 rounds draw-for-draw.

    ``dyn_graph`` is either a plain ``graph.DynamicGraph`` or a
    ``scenarios.Scenario``. A scenario additionally yields per-round
    client-availability masks (churn) via ``pop_avail_trace()``, which
    feed zone planning, and — when ``price`` is given — per-round
    latency/energy columns. ``price(graphs, clients, idx, mask) ->
    ((R,), (R,))`` prices the whole window in one vectorized call and
    must be deterministic (no RNG) so eager and scan engines price
    identically.

    ``batched_walk=True`` swaps the per-round ``rng.choice`` walk step
    for the pre-drawn-uniform inverse-CDF sampler
    (:meth:`RandomWalkServer.walk_schedule_batched`) — an RNG-stream
    break from the eager driver, hence opt-in.
    """
    first = start_round == 0
    graphs = dyn_graph.schedule(rounds, include_current=first)
    pop_trace = getattr(dyn_graph, "pop_avail_trace", None)
    avails = pop_trace() if pop_trace is not None else None
    step = (walker.walk_schedule_batched if batched_walk
            else walker.walk_schedule)
    positions = step(graphs, advance_first=not first)

    # The last `rounds` recorded weights align with `positions` in both
    # advance_first regimes: with the round-0 convention the window's
    # first entry is the walker's current position, whose weight was
    # recorded when it was visited (1.0 at reset).
    iw = walker.walk_weights(rounds)

    idx, mask, n_i, seeds, active = _plan_rounds(
        graphs, positions, zone_size, rng, avails)
    latency = energy = None
    if price is not None:
        latency, energy = price(graphs, positions, idx, mask)
    return ZoneSchedule(
        idx=idx, mask=mask, n_i=n_i, keys=round_keys(seeds),
        clients=positions.astype(np.int32), active=active,
        latency_s=latency, energy_j=energy, iw=iw,
    )


# ---------------------------------------------------------------------------
# Fleet schedules — K mobile servers compiled into one scan window.
# Round-robin mode serves one walker's zone per round (the walkers take
# turns; one wall step moves every walker once per K rounds); simultaneous
# mode moves ALL K walkers every wall step and serves K zones at once.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetZoneSchedule(ZoneSchedule):
    """R precomputed fleet rounds (see :class:`ZoneSchedule`).

    Round-robin mode keeps the base-class shapes and adds:

    walker: (R,) int32 — the active walker per round.
    sync:   (R,) float32 — 1.0 where a rendezvous (token averaging)
            follows the round, 0.0 otherwise.

    Under biased walk policies the base class's ``iw`` column is (R,)
    in round-robin mode (the active walker's importance weight) and
    (R, K) in simultaneous mode (one weight per walker's zone).

    Simultaneous mode gains a walker axis: idx/mask are (R, K, Z),
    clients/n_i/active are (R, K), and the latency/energy columns keep
    their (R,) wall-clock aggregates (parallel service: latency is the
    max over walkers, energy the sum) with the per-walker (R, K) columns
    preserved in ``latency_s_walkers``/``energy_j_walkers``.
    """

    walker: np.ndarray | None = None
    sync: np.ndarray | None = None
    latency_s_walkers: np.ndarray | None = None
    energy_j_walkers: np.ndarray | None = None
    mode: str = "roundrobin"
    n_walkers: int = 1

    @property
    def zone_size(self) -> int:
        return int(self.idx.shape[-1])


def plan_fleet_zone_round(
    graph: ClientGraph,
    positions: np.ndarray,
    zone_size: int,
    rng: np.random.Generator,
    avail: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """K zone plans for one simultaneous wall step.

    Returns (idx (K, Z), mask (K, Z), n_i (K,)). Walkers plan in index
    order and a client claimed by an earlier walker is excluded from
    later walkers' zones — deterministic conflict resolution (lowest
    walker index wins), so the K zones are pairwise disjoint and the
    multi-zone round's scatter-add is duplicate-free. A walker whose own
    position was already claimed serves whatever unclaimed neighbors
    remain (possibly none: an all-padding row — the walker idles).
    ``avail`` composes exactly as in :func:`plan_zone_round`: offline
    neighbors drop out, but a walker's own position always participates
    (unless claimed — the server at that client is the earlier walker).
    """
    k_walkers = len(positions)
    idx = np.zeros((k_walkers, zone_size), np.int32)
    mask = np.zeros((k_walkers, zone_size), np.float32)
    n_i = np.zeros((k_walkers,), np.float32)
    taken = np.zeros(graph.n, dtype=bool)
    for k, i_k in enumerate(positions):
        i_k = int(i_k)
        zone = graph.neighborhood(i_k)
        if avail is not None:
            zone = zone[avail[zone] | (zone == i_k)]
        zone = zone[~taken[zone]]
        n_i[k] = len(zone)
        if len(zone) > zone_size:
            if taken[i_k]:
                active = rng.choice(zone, size=zone_size, replace=False)
            else:
                others = zone[zone != i_k]
                pick = rng.choice(others, size=zone_size - 1, replace=False)
                active = np.concatenate([[i_k], pick])
        else:
            active = zone
        mask[k, : len(active)] = 1.0
        idx[k, : len(active)] = active
        taken[active] = True
    return idx, mask, n_i


def _plan_fleet_round_fast(
    graph,
    positions: np.ndarray,
    zone_size: int,
    rng: np.random.Generator,
    avail: np.ndarray | None = None,
):
    """No-conflict fast path of :func:`plan_fleet_zone_round`.

    When the K walkers' candidate neighborhoods are pairwise disjoint
    (the common case once n ≫ K·deg), the sequential loop's ``taken``
    bookkeeping is a no-op, so the K zone plans can be formed from one
    vectorized neighborhood gather — only walkers whose zone
    oversubscribes still draw from ``rng``, in walker order, exactly as
    the loop would. Returns ``None`` whenever any client is reachable by
    two walkers (including a walker standing on another's candidate or
    duplicate walker positions): the caller falls back to the loop for
    that round. Bit-identical to the loop when it applies (pinned in
    ``tests/test_fleet_scan.py``).
    """
    k_walkers = len(positions)
    pos_arr = np.asarray(positions, dtype=np.int64)
    n = graph.n
    if isinstance(graph, NeighborGraph):
        cand = np.concatenate([graph.nbrs[pos_arr].astype(np.int64),
                               pos_arr[:, None]], axis=1)
        cmask = np.concatenate(
            [graph.nbr_mask[pos_arr],
             np.ones((k_walkers, 1), dtype=bool)], axis=1)
        if avail is not None:
            cmask &= avail[cand] | (cand == pos_arr[:, None])
        live = cand[cmask]
        if len(np.unique(live)) != len(live):
            return None
        # Row-sort with an n sentinel on dead slots → each walker's
        # zone in ascending client order (the loop's ordering).
        sortable = np.where(cmask, cand, n)
        zones = np.sort(sortable, axis=1)
        counts = cmask.sum(axis=1)
    else:
        cand = graph.adjacency[pos_arr].copy()        # (K, n)
        if avail is not None:
            cand &= avail[None, :]
        cand[np.arange(k_walkers), pos_arr] = True
        if (cand.sum(axis=0) > 1).any():
            return None
        counts = cand.sum(axis=1)
        width = int(counts.max()) if k_walkers else 0
        zones = np.full((k_walkers, max(width, 1)), n, dtype=np.int64)
        rr, cc = np.nonzero(cand)                     # row-major → sorted
        zones[rr, graph_mod.segmented_arange(counts)] = cc
    z = zone_size
    idx = np.zeros((k_walkers, z), np.int32)
    mask = np.zeros((k_walkers, z), np.float32)
    n_i = counts.astype(np.float32)
    w = min(zones.shape[1], z)
    small = counts <= z
    fits = zones[:, :w]
    live_cols = fits < n
    idx[:, :w][small] = np.where(live_cols, fits, 0)[small]
    mask[:, :w][small] = live_cols[small].astype(np.float32)
    for k in np.flatnonzero(~small):                  # walker order
        zone = zones[k, : int(counts[k])]
        others = zone[zone != pos_arr[k]]
        pick = rng.choice(others, size=z - 1, replace=False)
        active = np.concatenate([[pos_arr[k]], pick])
        idx[k, : len(active)] = active
        mask[k, : len(active)] = 1.0
    return idx, mask, n_i


def fleet_zone_schedule(
    dyn_graph,
    walkers: Sequence[RandomWalkServer],
    rounds: int,
    zone_size: int,
    rng: np.random.Generator,
    *,
    start_round: int = 0,
    sync_every: int = 20,
    mode: str = "roundrobin",
    price=None,
    price_fleet=None,
    batched_walk: bool = False,
    fast_path: bool = True,
) -> FleetZoneSchedule:
    """Precompute ``rounds`` fleet rounds in one batched pass: the
    active-walker index, per-walker random-walk positions, the zone
    plan(s), rendezvous (sync) mask, PRNG keys, and wireless pricing.

    Consumes ``dyn_graph``, each walker's RNG, and the shared simulation
    ``rng`` exactly as the eager fleet driver would, so chunked fleet
    schedules compose and eager/scan trajectories pin bit-for-bit.

    Round-robin: walker ``(start_round + r) % K`` serves round r; the
    graph holds still (and nobody moves) for the first K rounds — every
    vehicle starts parked at a client — then advances per round with the
    active walker taking its step. Walk stepping is batched per walker
    (each walker's RNG stream is independent, so regrouping the rounds
    by walker replays the per-round order exactly).

    Simultaneous: every walker moves every wall step and
    :func:`plan_fleet_zone_round` forms K disjoint zones per round —
    through the vectorized no-conflict fast path
    (:func:`_plan_fleet_round_fast`) when the walkers' neighborhoods are
    disjoint, falling back to the sequential loop for rounds where they
    overlap (``fast_path=False`` forces the loop everywhere; both paths
    are bit-identical where the fast path applies);
    ``price_fleet(graphs, clients (R, K), idx, mask) -> ((R, K), (R, K))``
    prices each walker's zone, aggregated to wall-clock (R,) columns
    (max latency — the zones are served in parallel — and summed energy).
    """
    k_walkers = len(walkers)
    first = start_round == 0
    pop_trace = getattr(dyn_graph, "pop_avail_trace", None)
    avail_fn = getattr(dyn_graph, "availability", None)

    if mode == "roundrobin":
        lead = min(max(k_walkers - start_round, 0), rounds)
    elif mode == "simultaneous":
        lead = 1 if first else 0
    else:
        raise ValueError(
            f"mode must be roundrobin|simultaneous, got {mode!r}")

    graphs = [dyn_graph.current()] * lead
    cur_avail = avail_fn() if avail_fn is not None else None
    avails_lead = [cur_avail] * lead
    stepped: list = []
    trace = None
    if rounds > lead:
        stepped = dyn_graph.schedule(rounds - lead, include_current=False)
        trace = pop_trace() if pop_trace is not None else None
    graphs = graphs + stepped
    if cur_avail is None and trace is None:
        avails = None
    else:
        avails = avails_lead + (list(trace) if trace is not None
                                else [None] * len(stepped))

    step_name = "walk_schedule_batched" if batched_walk else "walk_schedule"
    biased = any(w.is_biased for w in walkers)
    rs = np.arange(rounds)
    if mode == "roundrobin":
        active_walker = ((start_round + rs) % k_walkers).astype(np.int32)
        positions = np.empty((rounds,), np.int64)
        iw = np.ones((rounds,), np.float64) if biased else None
        for k, w in enumerate(walkers):
            mine = np.flatnonzero(active_walker == k)
            parked = mine[mine < lead]
            if len(parked):
                assert w.position is not None, "call reset() first"
                positions[parked] = w.position
                if iw is not None:
                    # Parked rounds serve the walker's current position;
                    # its weight was recorded at the visit that put it
                    # there (1.0 for the reset visit) — same float the
                    # eager fleet round reads.
                    iw[parked] = w.weight_history[-1]
            moving = mine[mine >= lead]
            if len(moving):
                positions[moving] = getattr(w, step_name)(
                    [graphs[r] for r in moving], advance_first=True)
                if iw is not None and w.is_biased:
                    iw[moving] = w.walk_weights(len(moving))
        idx, mask, n_i, seeds, active = _plan_rounds(
            graphs, positions, zone_size, rng, avails)
        latency = energy = None
        if price is not None:
            latency, energy = price(graphs, positions, idx, mask)
        return FleetZoneSchedule(
            idx=idx, mask=mask, n_i=n_i, keys=round_keys(seeds),
            clients=positions.astype(np.int32), active=active,
            latency_s=latency, energy_j=energy, iw=iw,
            walker=active_walker,
            sync=_sync_mask(start_round, rounds, sync_every),
            mode=mode, n_walkers=k_walkers,
        )

    # -- simultaneous -----------------------------------------------------
    positions = np.empty((rounds, k_walkers), np.int64)
    iw = np.ones((rounds, k_walkers), np.float64) if biased else None
    for k, w in enumerate(walkers):
        if lead:
            assert w.position is not None, "call reset() first"
            positions[0, k] = w.position
            if iw is not None:
                iw[0, k] = w.weight_history[-1]
        if rounds > lead:
            positions[lead:, k] = getattr(w, step_name)(
                stepped, advance_first=True)
            if iw is not None and w.is_biased:
                iw[lead:, k] = w.walk_weights(rounds - lead)
    z = zone_size
    idx = np.zeros((rounds, k_walkers, z), np.int32)
    mask = np.zeros((rounds, k_walkers, z), np.float32)
    n_i = np.zeros((rounds, k_walkers), np.float32)
    seeds = np.zeros((rounds,), np.int64)
    for r in range(rounds):
        av = None if avails is None else avails[r]
        plan = (_plan_fleet_round_fast(graphs[r], positions[r], z, rng,
                                       avail=av)
                if fast_path else None)
        if plan is None:        # overlapping neighborhoods this round
            plan = plan_fleet_zone_round(graphs[r], positions[r], z,
                                         rng, avail=av)
        idx[r], mask[r], n_i[r] = plan
        seeds[r] = round_key_seed(rng)
    active = mask.sum(axis=2).astype(np.int32)          # (R, K)
    latency = energy = lat_kw = en_kw = None
    if price_fleet is not None:
        lat_kw, en_kw = price_fleet(graphs, positions, idx, mask)
        latency, energy = lat_kw.max(axis=1), en_kw.sum(axis=1)
    return FleetZoneSchedule(
        idx=idx, mask=mask, n_i=n_i, keys=round_keys(seeds),
        clients=positions.astype(np.int32), active=active,
        latency_s=latency, energy_j=energy, iw=iw,
        sync=_sync_mask(start_round, rounds, sync_every),
        latency_s_walkers=lat_kw, energy_j_walkers=en_kw,
        mode=mode, n_walkers=k_walkers,
    )


def _sync_mask(start_round: int, rounds: int, sync_every: int) -> np.ndarray:
    """(R,) float32 rendezvous mask: 1.0 after rounds where
    ``(rnd + 1) % sync_every == 0`` — the eager fleet's trigger."""
    rs = start_round + np.arange(rounds)
    return ((rs + 1) % max(int(sync_every), 1) == 0).astype(np.float32)
