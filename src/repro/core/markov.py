"""Markov-chain machinery for the mobile server's random walk.

Implements the paper's §3:
  * transition matrix  [P(k)]_{ij} = 1/deg(i) for j ~ i  (experiments §5),
  * Metropolis-Hastings variant (uniform stationary distribution π = 1/n,
    which makes Assumption 3.1's π_* as large as possible — used when a
    uniform client-visit frequency is desired),
  * stationary distribution π, spectral quantities σ(P), λ₂(P),
  * mixing time τ(δ) from Eq. (6),
  * P_max elementwise envelope (Eq. (5)) for the dynamic chain,
  * random-walk sampling of the visited-client sequence (i_k).
"""
from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Any, Sequence

import numpy as np

from . import graph as graph_mod
from .graph import ClientGraph, NeighborGraph


def degree_transition_matrix(graph: ClientGraph) -> np.ndarray:
    """[P]_{ij} = 1/deg(i) for j in N(i)\\{i}; the paper's experimental
    choice. Stationary distribution is π_i ∝ deg(i)."""
    adj = graph.adjacency.astype(np.float64)
    deg = adj.sum(axis=1, keepdims=True)
    return adj / np.maximum(deg, 1.0)


def metropolis_transition_matrix(graph: ClientGraph) -> np.ndarray:
    """Metropolis-Hastings weights: uniform stationary distribution.

    P_ij = min(1/deg(i), 1/deg(j)) for j~i; self-loop absorbs the rest.

    Vectorized: one (n, n) elementwise min instead of a Python double
    loop (this runs at every regeneration epoch, and every round under
    link-dropout scenarios). Pinned against the loop form in
    ``tests/test_graph_markov.py``.
    """
    adj = graph.adjacency.astype(np.float64)
    deg = adj.sum(axis=1)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    p = adj * np.minimum(inv[:, None], inv[None, :])
    np.fill_diagonal(p, 1.0 - p.sum(axis=1))
    return p


def stationary_distribution(p: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """π with πᵀP = πᵀ, via power iteration on Pᵀ."""
    n = p.shape[0]
    pi = np.full(n, 1.0 / n)
    for _ in range(100_000):
        nxt = pi @ p
        if np.abs(nxt - pi).max() < tol:
            pi = nxt
            break
        pi = nxt
    return pi / pi.sum()


def sigma(p: np.ndarray) -> float:
    """σ(P) := sup { ||fᵀP|| / ||f|| : fᵀ1 = 0 }  (paper Eq. 6).

    Equals the largest singular value of Pᵀ restricted to 1⊥.
    """
    n = p.shape[0]
    # Orthonormal basis of 1-perp via QR of [1 | I].
    q, _ = np.linalg.qr(np.concatenate([np.ones((n, 1)) / math.sqrt(n),
                                        np.eye(n)[:, : n - 1]], axis=1))
    basis = q[:, 1:]  # (n, n-1), orthonormal, ⊥ 1
    m = basis.T @ p @ p.T @ basis
    ev = np.linalg.eigvalsh(m)
    return float(np.sqrt(max(ev.max(), 0.0)))


def lambda2(p: np.ndarray) -> float:
    """Second-largest eigenvalue modulus (reversible-chain rate, Eq. 30)."""
    ev = np.linalg.eigvals(p)
    ev = np.sort(np.abs(ev))[::-1]
    return float(ev[1]) if len(ev) > 1 else 0.0


def mixing_time(p: np.ndarray, delta: float = 0.5,
                pi: np.ndarray | None = None) -> int:
    """τ(δ) = ceil( ln(√2/(δ π_*)) / (1 − σ(P)) )   (paper Eq. 6)."""
    if pi is None:
        pi = stationary_distribution(p)
    pi_star = float(pi.min())
    s = sigma(p)
    if s >= 1.0 - 1e-12:
        return 2**31 - 1  # non-ergodic chain: infinite mixing time
    return int(math.ceil(math.log(math.sqrt(2.0) / (delta * pi_star))
                         / (1.0 - s)))


def p_max_envelope(ps: list[np.ndarray]) -> np.ndarray:
    """Eq. (5): elementwise max over the dynamic chain's matrices P(k)."""
    env = ps[0].copy()
    for p in ps[1:]:
        np.maximum(env, p, out=env)
    return env


def verify_assumption_3_1(p: np.ndarray, delta: float = 0.5) -> dict:
    """Empirically verify the mixing inequality Eq. (3)/(4) for τ(δ)."""
    pi = stationary_distribution(p)
    tau = mixing_time(p, delta, pi)
    if tau >= 2**30:  # non-ergodic (e.g. periodic bipartite chain)
        return {"tau": tau, "holds": False, "max_dev": float("inf"),
                "pi_star": float(pi.min()), "sigma": sigma(p),
                "lambda2": lambda2(p)}
    pt = np.linalg.matrix_power(p, tau)
    dev = np.abs(pt - pi[None, :]).max()
    return {
        "tau": tau,
        "pi_star": float(pi.min()),
        "sigma": sigma(p),
        "lambda2": lambda2(p),
        "max_dev": float(dev),
        "holds": bool(dev <= delta * pi.min() + 1e-9),
    }


@dataclasses.dataclass
class RandomWalkServer:
    """The mobile server: walks the client graph per the Markov chain.

    Host-side control plane; the visited sequence (i_k) drives which zone
    the compiled SPMD round operates on.
    """

    transition: str = "degree"  # "degree" (paper) | "metropolis"
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.position: int | None = None
        self.visit_counts: np.ndarray | None = None
        self.history: list[int] = []
        self._matrix_cache: tuple[Any, np.ndarray] | None = None

    def matrix(self, graph: ClientGraph) -> np.ndarray:
        # The graph object only changes at regeneration epochs (every
        # ``regen_every`` rounds), but step() runs every round — cache
        # the O(n²) transition matrix per graph instance (weakref so a
        # recycled id can never alias a dead graph).
        if self._matrix_cache is not None \
                and self._matrix_cache[0]() is graph:
            return self._matrix_cache[1]
        # Diagnostics-only densification for sparse graphs: the walking
        # hot paths (step / walk_schedule*) never come through here for
        # a NeighborGraph — they sample O(deg) rows directly.
        g = graph.to_dense() if isinstance(graph, NeighborGraph) else graph
        if self.transition == "degree":
            p = degree_transition_matrix(g)
        elif self.transition == "metropolis":
            p = metropolis_transition_matrix(g)
        else:
            raise ValueError(f"unknown transition kind {self.transition!r}")
        self._matrix_cache = (weakref.ref(graph), p)
        return p

    def reset(self, graph: ClientGraph, start: int | None = None) -> int:
        self.visit_counts = np.zeros(graph.n, dtype=np.int64)
        self.position = (int(self._rng.integers(graph.n))
                         if start is None else int(start))
        self.visit_counts[self.position] += 1
        self.history = [self.position]
        return self.position

    def transition_row(self, graph: ClientGraph, i: int) -> np.ndarray:
        """Row i of P(k) — all one walk step needs. A cached full matrix
        is reused when present (static graphs between regens); otherwise
        the degree chain builds just the O(n) row, so link-dropout
        scenarios (a fresh surviving graph every round) skip the O(n²)
        full-matrix rebuild per round. The row values are bit-identical
        to the matrix row (0/1 sums are exact, one division either way).
        Metropolis rows need every node's degree, so that chain still
        goes through the cached matrix."""
        if self._matrix_cache is not None \
                and self._matrix_cache[0]() is graph:
            return self._matrix_cache[1][i]
        if isinstance(graph, NeighborGraph):
            cands, probs = self._sparse_row(graph, i)
            row = np.zeros(graph.n)
            row[cands] = probs
            return row
        if self.transition == "degree":
            row = graph.adjacency[i].astype(np.float64)
            return row / max(row.sum(), 1.0)
        return self.matrix(graph)[i]

    def _sparse_row(self, graph: NeighborGraph, i: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        """(candidates, probs): the nonzero support of row i of P(k), in
        ascending client order, for a neighbor-list graph — O(deg) for
        the degree chain instead of the dense row's O(n).

        The floats match the dense row exactly: the degree chain divides
        by the same degree, and the Metropolis self-loop scatters the
        neighbor masses into a length-n row first so ``1 − row.sum()``
        reduces with the same pairwise summation the dense matrix row
        uses. Together with the choice emulation in :meth:`step` this
        makes sparse walks replay dense walks draw-for-draw (pinned in
        ``tests/test_sparse_backend.py``).
        """
        if self.transition == "degree":
            nbrs = graph.neighbors(i)
            return nbrs, np.full(len(nbrs), 1.0) / max(float(len(nbrs)),
                                                       1.0)
        if self.transition != "metropolis":
            raise ValueError(f"unknown transition kind {self.transition!r}")
        nbrs = graph.neighbors(i)
        # Only deg(i) and deg(j) for j ~ i are needed — O(deg²) worst
        # case, not the full (n, k_cap) mask reduction. The values are
        # integer-valued float64 divisions, so they equal the dense
        # matrix's elementwise 1/deg floats exactly.
        deg_i = np.float64(len(nbrs))
        deg_nb = graph.nbr_mask[nbrs].sum(axis=1).astype(np.float64)
        inv_i = np.where(deg_i > 0, 1.0 / np.maximum(deg_i, 1.0), 0.0)
        inv_nb = np.where(deg_nb > 0, 1.0 / np.maximum(deg_nb, 1.0), 0.0)
        # Scatter into a length-n row so the self-loop mass reduces
        # with the same pairwise summation the dense matrix row uses.
        row = np.zeros(graph.n)
        row[nbrs] = np.minimum(inv_i, inv_nb)
        self_mass = 1.0 - row.sum()
        row[i] = self_mass
        cands = np.insert(nbrs, np.searchsorted(nbrs, i), i)
        return cands, row[cands]

    def _sample_sparse(self, graph: NeighborGraph, u: float) -> int:
        """Map one uniform through row ``position``'s CDF exactly as
        ``Generator.choice(n, p=row)`` does on the dense row (cumsum,
        normalize, searchsorted-right): the zero-mass entries of the
        dense row never move the CDF's float levels, so the compressed
        search lands on the same client for the same uniform."""
        cands, probs = self._sparse_row(graph, self.position)
        cdf = probs.cumsum()
        cdf /= cdf[-1]
        j = int(np.searchsorted(cdf, u, side="right"))
        return int(cands[min(j, len(cands) - 1)])

    def step(self, graph: ClientGraph) -> int:
        """One random-walk move: i_{k+1} ~ [P(k)]_{i_k, ·} (Eq. 2)."""
        assert self.position is not None, "call reset() first"
        if isinstance(graph, NeighborGraph):
            self.position = self._sample_sparse(graph, self._rng.random())
        else:
            row = self.transition_row(graph, self.position)
            # The dynamic graph may have disconnected the current node
            # from its old neighbors; row always sums to 1 on the
            # *current* graph.
            self.position = int(self._rng.choice(graph.n, p=row))
        self.visit_counts[self.position] += 1
        self.history.append(self.position)
        return self.position

    def hitting_time(self) -> int | None:
        """T = max_i T_i once every client has been visited (paper §4)."""
        if self.visit_counts is None or (self.visit_counts == 0).any():
            return None
        seen: set[int] = set()
        for k, i in enumerate(self.history):
            seen.add(i)
            if len(seen) == len(self.visit_counts):
                return k
        return None

    def walk_schedule(self, graphs: Sequence[ClientGraph],
                      *, advance_first: bool = True) -> np.ndarray:
        """Batch variant of :meth:`step`: the visited sequence (i_k) over a
        precomputed graph schedule (one graph per round).

        Consumes the walk RNG exactly as per-round ``step()`` calls would,
        so eager and compiled-schedule drivers visit identical clients.
        ``advance_first=False`` keeps the first entry at the current
        position (the round-0 convention: the server starts *at* a client
        before its first move).
        """
        positions = np.empty(len(graphs), dtype=np.int64)
        for k, graph in enumerate(graphs):
            if k == 0 and not advance_first:
                assert self.position is not None, "call reset() first"
                positions[k] = self.position
            else:
                positions[k] = self.step(graph)
        return positions

    def walk_schedule_batched(self, graphs: Sequence[ClientGraph],
                              *, advance_first: bool = True) -> np.ndarray:
        """Inverse-CDF variant of :meth:`walk_schedule`: all step uniforms
        are pre-drawn in ONE ``rng.random`` call and each step maps its
        uniform through the transition row's CDF — O(1) RNG dispatches
        per window instead of one ``Generator.choice`` (which rebuilds a
        CDF and re-enters the generator) per round.

        RNG-STREAM BREAK: raw uniforms consume the walker's bit stream
        differently from ``choice``, so a run mixing this with eager
        ``step()`` calls diverges. It therefore ships opt-in (the
        trainers' ``batched_walk`` flag); the stream it does produce is
        deterministic, chunk-composable (``random(a)`` then ``random(b)``
        equals ``random(a+b)`` for PCG64), and pinned by a seed-stability
        test so it can never drift silently.
        """
        rounds = len(graphs)
        positions = np.empty(rounds, dtype=np.int64)
        start = 0
        if rounds and not advance_first:
            assert self.position is not None, "call reset() first"
            positions[0] = self.position
            start = 1
        u = self._rng.random(rounds - start)
        for k in range(start, rounds):
            assert self.position is not None, "call reset() first"
            if isinstance(graphs[k], NeighborGraph):
                cands, row = self._sparse_row(graphs[k], self.position)
            else:
                cands = None
                row = self.transition_row(graphs[k], self.position)
            cdf = np.cumsum(row)
            # Scale by the realized total (≈1.0) so fp undershoot in the
            # cumsum can never push the draw past the last bin.
            j = int(np.searchsorted(cdf, u[k - start] * cdf[-1],
                                    side="right"))
            # A uniform within 1 ulp of 1.0 can land past the last
            # positive-mass bin (trailing zero-probability states share
            # cdf[-1]); clamp to the first bin reaching the total — the
            # last state the row actually supports. The sparse lane's
            # compressed CDF shares the dense CDF's float levels, so
            # the clamp index maps to the same client.
            j = min(j, int(np.searchsorted(cdf, cdf[-1], side="left")))
            self.position = int(cands[j]) if cands is not None else j
            self.visit_counts[self.position] += 1
            self.history.append(self.position)
            positions[k] = self.position
        return positions


# ---------------------------------------------------------------------------
# Precomputed zone schedules — the host-side half of the compiled
# multi-round (lax.scan) driver. Everything data-dependent that the random
# walk decides (which client, which zone members, which PRNG key) is
# resolved here into fixed-shape arrays; the device then runs R rounds as
# one XLA executable with no host round-trips.
# ---------------------------------------------------------------------------


def round_key_seed(rng: np.random.Generator) -> int:
    """Draw one round's PRNG-key seed from the shared simulation RNG.

    The single choke point for per-round key derivation: the eager
    drivers (single-walker, fleet) and the schedule precompute all draw
    through here, so their key streams are identical *by construction* —
    the eager/scan equivalence pins are structural, not incidental.
    """
    return int(rng.integers(2**31 - 1))


def round_key(rng: np.random.Generator):
    """Eager-driver form: materialize the round's key on device."""
    import jax

    return jax.random.PRNGKey(round_key_seed(rng))


def round_keys(seeds: np.ndarray) -> np.ndarray:
    """Schedule form: one batched dispatch for a whole window's key block
    (threefry init is jit-traced, so vmap over seeds matches per-seed
    ``PRNGKey`` bit-for-bit)."""
    import jax

    return np.asarray(jax.vmap(jax.random.PRNGKey)(np.asarray(seeds)))


@dataclasses.dataclass(frozen=True)
class ZoneSchedule:
    """R precomputed zone rounds as fixed-shape host arrays.

    idx:     (R, Z) int32 — active-client ids, padded with 0.
    mask:    (R, Z) float32 — 1 for live slots, 0 for padding.
    n_i:     (R,) float32 — |N(i_k)| zone sizes (pre-subsampling).
    keys:    (R, 2) uint32 — per-round PRNG keys (minibatch sampling).
    clients: (R,) int32 — the visited client i_k per round.
    active:  (R,) int32 — number of live slots per round (≤ Z).

    When the schedule is built from a scenario with a wireless comm
    model (``scenarios/``), two extra host-side columns price each
    round; they never enter the compiled scan (control-plane only):

    latency_s: (R,) float64 — expected round latency, or None.
    energy_j:  (R,) float64 — expected round radio energy, or None.
    """

    idx: np.ndarray
    mask: np.ndarray
    n_i: np.ndarray
    keys: np.ndarray
    clients: np.ndarray
    active: np.ndarray
    latency_s: np.ndarray | None = None
    energy_j: np.ndarray | None = None

    @property
    def rounds(self) -> int:
        return int(self.idx.shape[0])

    @property
    def zone_size(self) -> int:
        return int(self.idx.shape[1])


def plan_zone_round(
    graph: ClientGraph,
    i_k: int,
    zone_size: int,
    rng: np.random.Generator,
    avail: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Form the active zone S(i_k) ⊆ N(i_k) for one round (Eq. 31 subset).

    Returns (idx (Z,), mask (Z,), n_i). Zones larger than ``zone_size``
    are subsampled: i_k plus random neighbors, drawn from ``rng`` — the
    single host RNG shared with per-round key generation, so schedule
    precomputation replays the eager driver's draw sequence exactly.

    ``avail`` is an optional (n,) bool client-availability mask (churn /
    duty-cycling, ``scenarios/``): offline neighbors are dropped from the
    zone before subsampling. The visited client i_k always participates —
    the server is physically at its location. ``avail=None`` (the default)
    consumes ``rng`` identically to the pre-scenario code path.
    """
    zone = graph.neighborhood(i_k)
    if avail is not None:
        zone = zone[avail[zone] | (zone == i_k)]
    n_i = len(zone)
    if n_i > zone_size:
        others = zone[zone != i_k]
        pick = rng.choice(others, size=zone_size - 1, replace=False)
        active = np.concatenate([[i_k], pick])
    else:
        active = zone
    mask = np.zeros(zone_size, np.float32)
    mask[: len(active)] = 1.0
    idx = np.zeros(zone_size, np.int32)
    idx[: len(active)] = active
    return idx, mask, n_i


def _plan_rounds(graphs, positions, zone_size, rng, avails):
    """The shared per-round planning loop: zone membership + key seeds.

    Inherently sequential in ``rng`` (subsample draws and key seeds
    interleave in round order, replaying the eager drivers exactly), so
    it stays a host loop; everything around it — walk stepping, key
    materialization, pricing — is batched by the callers.
    """
    rounds = len(graphs)
    z = zone_size
    idx = np.zeros((rounds, z), np.int32)
    mask = np.zeros((rounds, z), np.float32)
    n_i = np.zeros((rounds,), np.float32)
    seeds = np.zeros((rounds,), np.int64)
    active = np.zeros((rounds,), np.int32)
    for k in range(rounds):
        idx[k], mask[k], n_i[k] = plan_zone_round(
            graphs[k], int(positions[k]), z, rng,
            avail=None if avails is None else avails[k],
        )
        active[k] = int(mask[k].sum())
        seeds[k] = round_key_seed(rng)
    return idx, mask, n_i, seeds, active


def zone_schedule(
    dyn_graph,
    walker: RandomWalkServer,
    rounds: int,
    zone_size: int,
    rng: np.random.Generator,
    *,
    start_round: int = 0,
    price=None,
    batched_walk: bool = False,
) -> ZoneSchedule:
    """Precompute ``rounds`` zone rounds: graphs (covering regeneration
    epochs), random-walk positions, padded zone membership, and PRNG keys.

    Advances ``dyn_graph``, ``walker``, and ``rng`` exactly as the same
    number of eager per-round calls would, so chunked schedules compose:
    ``zone_schedule(..., R1) + zone_schedule(..., R2, start_round=R1)``
    reproduces one eager run of R1+R2 rounds draw-for-draw.

    ``dyn_graph`` is either a plain ``graph.DynamicGraph`` or a
    ``scenarios.Scenario``. A scenario additionally yields per-round
    client-availability masks (churn) via ``pop_avail_trace()``, which
    feed zone planning, and — when ``price`` is given — per-round
    latency/energy columns. ``price(graphs, clients, idx, mask) ->
    ((R,), (R,))`` prices the whole window in one vectorized call and
    must be deterministic (no RNG) so eager and scan engines price
    identically.

    ``batched_walk=True`` swaps the per-round ``rng.choice`` walk step
    for the pre-drawn-uniform inverse-CDF sampler
    (:meth:`RandomWalkServer.walk_schedule_batched`) — an RNG-stream
    break from the eager driver, hence opt-in.
    """
    first = start_round == 0
    graphs = dyn_graph.schedule(rounds, include_current=first)
    pop_trace = getattr(dyn_graph, "pop_avail_trace", None)
    avails = pop_trace() if pop_trace is not None else None
    step = (walker.walk_schedule_batched if batched_walk
            else walker.walk_schedule)
    positions = step(graphs, advance_first=not first)

    idx, mask, n_i, seeds, active = _plan_rounds(
        graphs, positions, zone_size, rng, avails)
    latency = energy = None
    if price is not None:
        latency, energy = price(graphs, positions, idx, mask)
    return ZoneSchedule(
        idx=idx, mask=mask, n_i=n_i, keys=round_keys(seeds),
        clients=positions.astype(np.int32), active=active,
        latency_s=latency, energy_j=energy,
    )


# ---------------------------------------------------------------------------
# Fleet schedules — K mobile servers compiled into one scan window.
# Round-robin mode serves one walker's zone per round (the walkers take
# turns; one wall step moves every walker once per K rounds); simultaneous
# mode moves ALL K walkers every wall step and serves K zones at once.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetZoneSchedule(ZoneSchedule):
    """R precomputed fleet rounds (see :class:`ZoneSchedule`).

    Round-robin mode keeps the base-class shapes and adds:

    walker: (R,) int32 — the active walker per round.
    sync:   (R,) float32 — 1.0 where a rendezvous (token averaging)
            follows the round, 0.0 otherwise.

    Simultaneous mode gains a walker axis: idx/mask are (R, K, Z),
    clients/n_i/active are (R, K), and the latency/energy columns keep
    their (R,) wall-clock aggregates (parallel service: latency is the
    max over walkers, energy the sum) with the per-walker (R, K) columns
    preserved in ``latency_s_walkers``/``energy_j_walkers``.
    """

    walker: np.ndarray | None = None
    sync: np.ndarray | None = None
    latency_s_walkers: np.ndarray | None = None
    energy_j_walkers: np.ndarray | None = None
    mode: str = "roundrobin"
    n_walkers: int = 1

    @property
    def zone_size(self) -> int:
        return int(self.idx.shape[-1])


def plan_fleet_zone_round(
    graph: ClientGraph,
    positions: np.ndarray,
    zone_size: int,
    rng: np.random.Generator,
    avail: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """K zone plans for one simultaneous wall step.

    Returns (idx (K, Z), mask (K, Z), n_i (K,)). Walkers plan in index
    order and a client claimed by an earlier walker is excluded from
    later walkers' zones — deterministic conflict resolution (lowest
    walker index wins), so the K zones are pairwise disjoint and the
    multi-zone round's scatter-add is duplicate-free. A walker whose own
    position was already claimed serves whatever unclaimed neighbors
    remain (possibly none: an all-padding row — the walker idles).
    ``avail`` composes exactly as in :func:`plan_zone_round`: offline
    neighbors drop out, but a walker's own position always participates
    (unless claimed — the server at that client is the earlier walker).
    """
    k_walkers = len(positions)
    idx = np.zeros((k_walkers, zone_size), np.int32)
    mask = np.zeros((k_walkers, zone_size), np.float32)
    n_i = np.zeros((k_walkers,), np.float32)
    taken = np.zeros(graph.n, dtype=bool)
    for k, i_k in enumerate(positions):
        i_k = int(i_k)
        zone = graph.neighborhood(i_k)
        if avail is not None:
            zone = zone[avail[zone] | (zone == i_k)]
        zone = zone[~taken[zone]]
        n_i[k] = len(zone)
        if len(zone) > zone_size:
            if taken[i_k]:
                active = rng.choice(zone, size=zone_size, replace=False)
            else:
                others = zone[zone != i_k]
                pick = rng.choice(others, size=zone_size - 1, replace=False)
                active = np.concatenate([[i_k], pick])
        else:
            active = zone
        mask[k, : len(active)] = 1.0
        idx[k, : len(active)] = active
        taken[active] = True
    return idx, mask, n_i


def _plan_fleet_round_fast(
    graph,
    positions: np.ndarray,
    zone_size: int,
    rng: np.random.Generator,
    avail: np.ndarray | None = None,
):
    """No-conflict fast path of :func:`plan_fleet_zone_round`.

    When the K walkers' candidate neighborhoods are pairwise disjoint
    (the common case once n ≫ K·deg), the sequential loop's ``taken``
    bookkeeping is a no-op, so the K zone plans can be formed from one
    vectorized neighborhood gather — only walkers whose zone
    oversubscribes still draw from ``rng``, in walker order, exactly as
    the loop would. Returns ``None`` whenever any client is reachable by
    two walkers (including a walker standing on another's candidate or
    duplicate walker positions): the caller falls back to the loop for
    that round. Bit-identical to the loop when it applies (pinned in
    ``tests/test_fleet_scan.py``).
    """
    k_walkers = len(positions)
    pos_arr = np.asarray(positions, dtype=np.int64)
    n = graph.n
    if isinstance(graph, NeighborGraph):
        cand = np.concatenate([graph.nbrs[pos_arr].astype(np.int64),
                               pos_arr[:, None]], axis=1)
        cmask = np.concatenate(
            [graph.nbr_mask[pos_arr],
             np.ones((k_walkers, 1), dtype=bool)], axis=1)
        if avail is not None:
            cmask &= avail[cand] | (cand == pos_arr[:, None])
        live = cand[cmask]
        if len(np.unique(live)) != len(live):
            return None
        # Row-sort with an n sentinel on dead slots → each walker's
        # zone in ascending client order (the loop's ordering).
        sortable = np.where(cmask, cand, n)
        zones = np.sort(sortable, axis=1)
        counts = cmask.sum(axis=1)
    else:
        cand = graph.adjacency[pos_arr].copy()        # (K, n)
        if avail is not None:
            cand &= avail[None, :]
        cand[np.arange(k_walkers), pos_arr] = True
        if (cand.sum(axis=0) > 1).any():
            return None
        counts = cand.sum(axis=1)
        width = int(counts.max()) if k_walkers else 0
        zones = np.full((k_walkers, max(width, 1)), n, dtype=np.int64)
        rr, cc = np.nonzero(cand)                     # row-major → sorted
        zones[rr, graph_mod.segmented_arange(counts)] = cc
    z = zone_size
    idx = np.zeros((k_walkers, z), np.int32)
    mask = np.zeros((k_walkers, z), np.float32)
    n_i = counts.astype(np.float32)
    w = min(zones.shape[1], z)
    small = counts <= z
    fits = zones[:, :w]
    live_cols = fits < n
    idx[:, :w][small] = np.where(live_cols, fits, 0)[small]
    mask[:, :w][small] = live_cols[small].astype(np.float32)
    for k in np.flatnonzero(~small):                  # walker order
        zone = zones[k, : int(counts[k])]
        others = zone[zone != pos_arr[k]]
        pick = rng.choice(others, size=z - 1, replace=False)
        active = np.concatenate([[pos_arr[k]], pick])
        idx[k, : len(active)] = active
        mask[k, : len(active)] = 1.0
    return idx, mask, n_i


def fleet_zone_schedule(
    dyn_graph,
    walkers: Sequence[RandomWalkServer],
    rounds: int,
    zone_size: int,
    rng: np.random.Generator,
    *,
    start_round: int = 0,
    sync_every: int = 20,
    mode: str = "roundrobin",
    price=None,
    price_fleet=None,
    batched_walk: bool = False,
    fast_path: bool = True,
) -> FleetZoneSchedule:
    """Precompute ``rounds`` fleet rounds in one batched pass: the
    active-walker index, per-walker random-walk positions, the zone
    plan(s), rendezvous (sync) mask, PRNG keys, and wireless pricing.

    Consumes ``dyn_graph``, each walker's RNG, and the shared simulation
    ``rng`` exactly as the eager fleet driver would, so chunked fleet
    schedules compose and eager/scan trajectories pin bit-for-bit.

    Round-robin: walker ``(start_round + r) % K`` serves round r; the
    graph holds still (and nobody moves) for the first K rounds — every
    vehicle starts parked at a client — then advances per round with the
    active walker taking its step. Walk stepping is batched per walker
    (each walker's RNG stream is independent, so regrouping the rounds
    by walker replays the per-round order exactly).

    Simultaneous: every walker moves every wall step and
    :func:`plan_fleet_zone_round` forms K disjoint zones per round —
    through the vectorized no-conflict fast path
    (:func:`_plan_fleet_round_fast`) when the walkers' neighborhoods are
    disjoint, falling back to the sequential loop for rounds where they
    overlap (``fast_path=False`` forces the loop everywhere; both paths
    are bit-identical where the fast path applies);
    ``price_fleet(graphs, clients (R, K), idx, mask) -> ((R, K), (R, K))``
    prices each walker's zone, aggregated to wall-clock (R,) columns
    (max latency — the zones are served in parallel — and summed energy).
    """
    k_walkers = len(walkers)
    first = start_round == 0
    pop_trace = getattr(dyn_graph, "pop_avail_trace", None)
    avail_fn = getattr(dyn_graph, "availability", None)

    if mode == "roundrobin":
        lead = min(max(k_walkers - start_round, 0), rounds)
    elif mode == "simultaneous":
        lead = 1 if first else 0
    else:
        raise ValueError(
            f"mode must be roundrobin|simultaneous, got {mode!r}")

    graphs = [dyn_graph.current()] * lead
    cur_avail = avail_fn() if avail_fn is not None else None
    avails_lead = [cur_avail] * lead
    stepped: list = []
    trace = None
    if rounds > lead:
        stepped = dyn_graph.schedule(rounds - lead, include_current=False)
        trace = pop_trace() if pop_trace is not None else None
    graphs = graphs + stepped
    if cur_avail is None and trace is None:
        avails = None
    else:
        avails = avails_lead + (list(trace) if trace is not None
                                else [None] * len(stepped))

    step_name = "walk_schedule_batched" if batched_walk else "walk_schedule"
    rs = np.arange(rounds)
    if mode == "roundrobin":
        active_walker = ((start_round + rs) % k_walkers).astype(np.int32)
        positions = np.empty((rounds,), np.int64)
        for k, w in enumerate(walkers):
            mine = np.flatnonzero(active_walker == k)
            parked = mine[mine < lead]
            if len(parked):
                assert w.position is not None, "call reset() first"
                positions[parked] = w.position
            moving = mine[mine >= lead]
            if len(moving):
                positions[moving] = getattr(w, step_name)(
                    [graphs[r] for r in moving], advance_first=True)
        idx, mask, n_i, seeds, active = _plan_rounds(
            graphs, positions, zone_size, rng, avails)
        latency = energy = None
        if price is not None:
            latency, energy = price(graphs, positions, idx, mask)
        return FleetZoneSchedule(
            idx=idx, mask=mask, n_i=n_i, keys=round_keys(seeds),
            clients=positions.astype(np.int32), active=active,
            latency_s=latency, energy_j=energy,
            walker=active_walker,
            sync=_sync_mask(start_round, rounds, sync_every),
            mode=mode, n_walkers=k_walkers,
        )

    # -- simultaneous -----------------------------------------------------
    positions = np.empty((rounds, k_walkers), np.int64)
    for k, w in enumerate(walkers):
        if lead:
            assert w.position is not None, "call reset() first"
            positions[0, k] = w.position
        if rounds > lead:
            positions[lead:, k] = getattr(w, step_name)(
                stepped, advance_first=True)
    z = zone_size
    idx = np.zeros((rounds, k_walkers, z), np.int32)
    mask = np.zeros((rounds, k_walkers, z), np.float32)
    n_i = np.zeros((rounds, k_walkers), np.float32)
    seeds = np.zeros((rounds,), np.int64)
    for r in range(rounds):
        av = None if avails is None else avails[r]
        plan = (_plan_fleet_round_fast(graphs[r], positions[r], z, rng,
                                       avail=av)
                if fast_path else None)
        if plan is None:        # overlapping neighborhoods this round
            plan = plan_fleet_zone_round(graphs[r], positions[r], z,
                                         rng, avail=av)
        idx[r], mask[r], n_i[r] = plan
        seeds[r] = round_key_seed(rng)
    active = mask.sum(axis=2).astype(np.int32)          # (R, K)
    latency = energy = lat_kw = en_kw = None
    if price_fleet is not None:
        lat_kw, en_kw = price_fleet(graphs, positions, idx, mask)
        latency, energy = lat_kw.max(axis=1), en_kw.sum(axis=1)
    return FleetZoneSchedule(
        idx=idx, mask=mask, n_i=n_i, keys=round_keys(seeds),
        clients=positions.astype(np.int32), active=active,
        latency_s=latency, energy_j=energy,
        sync=_sync_mask(start_round, rounds, sync_every),
        latency_s_walkers=lat_kw, energy_j_walkers=en_kw,
        mode=mode, n_walkers=k_walkers,
    )


def _sync_mask(start_round: int, rounds: int, sync_every: int) -> np.ndarray:
    """(R,) float32 rendezvous mask: 1.0 after rounds where
    ``(rnd + 1) % sync_every == 0`` — the eager fleet's trigger."""
    rs = start_round + np.arange(rounds)
    return ((rs + 1) % max(int(sync_every), 1) == 0).astype(np.float32)
