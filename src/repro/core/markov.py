"""Markov-chain machinery for the mobile server's random walk.

Implements the paper's §3:
  * transition matrix  [P(k)]_{ij} = 1/deg(i) for j ~ i  (experiments §5),
  * Metropolis-Hastings variant (uniform stationary distribution π = 1/n,
    which makes Assumption 3.1's π_* as large as possible — used when a
    uniform client-visit frequency is desired),
  * stationary distribution π, spectral quantities σ(P), λ₂(P),
  * mixing time τ(δ) from Eq. (6),
  * P_max elementwise envelope (Eq. (5)) for the dynamic chain,
  * random-walk sampling of the visited-client sequence (i_k).
"""
from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Any, Sequence

import numpy as np

from .graph import ClientGraph


def degree_transition_matrix(graph: ClientGraph) -> np.ndarray:
    """[P]_{ij} = 1/deg(i) for j in N(i)\\{i}; the paper's experimental
    choice. Stationary distribution is π_i ∝ deg(i)."""
    adj = graph.adjacency.astype(np.float64)
    deg = adj.sum(axis=1, keepdims=True)
    return adj / np.maximum(deg, 1.0)


def metropolis_transition_matrix(graph: ClientGraph) -> np.ndarray:
    """Metropolis-Hastings weights: uniform stationary distribution.

    P_ij = min(1/deg(i), 1/deg(j)) for j~i; self-loop absorbs the rest.

    Vectorized: one (n, n) elementwise min instead of a Python double
    loop (this runs at every regeneration epoch, and every round under
    link-dropout scenarios). Pinned against the loop form in
    ``tests/test_graph_markov.py``.
    """
    adj = graph.adjacency.astype(np.float64)
    deg = adj.sum(axis=1)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    p = adj * np.minimum(inv[:, None], inv[None, :])
    np.fill_diagonal(p, 1.0 - p.sum(axis=1))
    return p


def stationary_distribution(p: np.ndarray, tol: float = 1e-12) -> np.ndarray:
    """π with πᵀP = πᵀ, via power iteration on Pᵀ."""
    n = p.shape[0]
    pi = np.full(n, 1.0 / n)
    for _ in range(100_000):
        nxt = pi @ p
        if np.abs(nxt - pi).max() < tol:
            pi = nxt
            break
        pi = nxt
    return pi / pi.sum()


def sigma(p: np.ndarray) -> float:
    """σ(P) := sup { ||fᵀP|| / ||f|| : fᵀ1 = 0 }  (paper Eq. 6).

    Equals the largest singular value of Pᵀ restricted to 1⊥.
    """
    n = p.shape[0]
    # Orthonormal basis of 1-perp via QR of [1 | I].
    q, _ = np.linalg.qr(np.concatenate([np.ones((n, 1)) / math.sqrt(n),
                                        np.eye(n)[:, : n - 1]], axis=1))
    basis = q[:, 1:]  # (n, n-1), orthonormal, ⊥ 1
    m = basis.T @ p @ p.T @ basis
    ev = np.linalg.eigvalsh(m)
    return float(np.sqrt(max(ev.max(), 0.0)))


def lambda2(p: np.ndarray) -> float:
    """Second-largest eigenvalue modulus (reversible-chain rate, Eq. 30)."""
    ev = np.linalg.eigvals(p)
    ev = np.sort(np.abs(ev))[::-1]
    return float(ev[1]) if len(ev) > 1 else 0.0


def mixing_time(p: np.ndarray, delta: float = 0.5,
                pi: np.ndarray | None = None) -> int:
    """τ(δ) = ceil( ln(√2/(δ π_*)) / (1 − σ(P)) )   (paper Eq. 6)."""
    if pi is None:
        pi = stationary_distribution(p)
    pi_star = float(pi.min())
    s = sigma(p)
    if s >= 1.0 - 1e-12:
        return 2**31 - 1  # non-ergodic chain: infinite mixing time
    return int(math.ceil(math.log(math.sqrt(2.0) / (delta * pi_star))
                         / (1.0 - s)))


def p_max_envelope(ps: list[np.ndarray]) -> np.ndarray:
    """Eq. (5): elementwise max over the dynamic chain's matrices P(k)."""
    env = ps[0].copy()
    for p in ps[1:]:
        np.maximum(env, p, out=env)
    return env


def verify_assumption_3_1(p: np.ndarray, delta: float = 0.5) -> dict:
    """Empirically verify the mixing inequality Eq. (3)/(4) for τ(δ)."""
    pi = stationary_distribution(p)
    tau = mixing_time(p, delta, pi)
    if tau >= 2**30:  # non-ergodic (e.g. periodic bipartite chain)
        return {"tau": tau, "holds": False, "max_dev": float("inf"),
                "pi_star": float(pi.min()), "sigma": sigma(p),
                "lambda2": lambda2(p)}
    pt = np.linalg.matrix_power(p, tau)
    dev = np.abs(pt - pi[None, :]).max()
    return {
        "tau": tau,
        "pi_star": float(pi.min()),
        "sigma": sigma(p),
        "lambda2": lambda2(p),
        "max_dev": float(dev),
        "holds": bool(dev <= delta * pi.min() + 1e-9),
    }


@dataclasses.dataclass
class RandomWalkServer:
    """The mobile server: walks the client graph per the Markov chain.

    Host-side control plane; the visited sequence (i_k) drives which zone
    the compiled SPMD round operates on.
    """

    transition: str = "degree"  # "degree" (paper) | "metropolis"
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.position: int | None = None
        self.visit_counts: np.ndarray | None = None
        self.history: list[int] = []
        self._matrix_cache: tuple[Any, np.ndarray] | None = None

    def matrix(self, graph: ClientGraph) -> np.ndarray:
        # The graph object only changes at regeneration epochs (every
        # ``regen_every`` rounds), but step() runs every round — cache
        # the O(n²) transition matrix per graph instance (weakref so a
        # recycled id can never alias a dead graph).
        if self._matrix_cache is not None \
                and self._matrix_cache[0]() is graph:
            return self._matrix_cache[1]
        if self.transition == "degree":
            p = degree_transition_matrix(graph)
        elif self.transition == "metropolis":
            p = metropolis_transition_matrix(graph)
        else:
            raise ValueError(f"unknown transition kind {self.transition!r}")
        self._matrix_cache = (weakref.ref(graph), p)
        return p

    def reset(self, graph: ClientGraph, start: int | None = None) -> int:
        self.visit_counts = np.zeros(graph.n, dtype=np.int64)
        self.position = (int(self._rng.integers(graph.n))
                         if start is None else int(start))
        self.visit_counts[self.position] += 1
        self.history = [self.position]
        return self.position

    def transition_row(self, graph: ClientGraph, i: int) -> np.ndarray:
        """Row i of P(k) — all one walk step needs. A cached full matrix
        is reused when present (static graphs between regens); otherwise
        the degree chain builds just the O(n) row, so link-dropout
        scenarios (a fresh surviving graph every round) skip the O(n²)
        full-matrix rebuild per round. The row values are bit-identical
        to the matrix row (0/1 sums are exact, one division either way).
        Metropolis rows need every node's degree, so that chain still
        goes through the cached matrix."""
        if self._matrix_cache is not None \
                and self._matrix_cache[0]() is graph:
            return self._matrix_cache[1][i]
        if self.transition == "degree":
            row = graph.adjacency[i].astype(np.float64)
            return row / max(row.sum(), 1.0)
        return self.matrix(graph)[i]

    def step(self, graph: ClientGraph) -> int:
        """One random-walk move: i_{k+1} ~ [P(k)]_{i_k, ·} (Eq. 2)."""
        assert self.position is not None, "call reset() first"
        row = self.transition_row(graph, self.position)
        # The dynamic graph may have disconnected the current node from its
        # old neighbors; row always sums to 1 on the *current* graph.
        self.position = int(self._rng.choice(graph.n, p=row))
        self.visit_counts[self.position] += 1
        self.history.append(self.position)
        return self.position

    def hitting_time(self) -> int | None:
        """T = max_i T_i once every client has been visited (paper §4)."""
        if self.visit_counts is None or (self.visit_counts == 0).any():
            return None
        seen: set[int] = set()
        for k, i in enumerate(self.history):
            seen.add(i)
            if len(seen) == len(self.visit_counts):
                return k
        return None

    def walk_schedule(self, graphs: Sequence[ClientGraph],
                      *, advance_first: bool = True) -> np.ndarray:
        """Batch variant of :meth:`step`: the visited sequence (i_k) over a
        precomputed graph schedule (one graph per round).

        Consumes the walk RNG exactly as per-round ``step()`` calls would,
        so eager and compiled-schedule drivers visit identical clients.
        ``advance_first=False`` keeps the first entry at the current
        position (the round-0 convention: the server starts *at* a client
        before its first move).
        """
        positions = np.empty(len(graphs), dtype=np.int64)
        for k, graph in enumerate(graphs):
            if k == 0 and not advance_first:
                assert self.position is not None, "call reset() first"
                positions[k] = self.position
            else:
                positions[k] = self.step(graph)
        return positions


# ---------------------------------------------------------------------------
# Precomputed zone schedules — the host-side half of the compiled
# multi-round (lax.scan) driver. Everything data-dependent that the random
# walk decides (which client, which zone members, which PRNG key) is
# resolved here into fixed-shape arrays; the device then runs R rounds as
# one XLA executable with no host round-trips.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ZoneSchedule:
    """R precomputed zone rounds as fixed-shape host arrays.

    idx:     (R, Z) int32 — active-client ids, padded with 0.
    mask:    (R, Z) float32 — 1 for live slots, 0 for padding.
    n_i:     (R,) float32 — |N(i_k)| zone sizes (pre-subsampling).
    keys:    (R, 2) uint32 — per-round PRNG keys (minibatch sampling).
    clients: (R,) int32 — the visited client i_k per round.
    active:  (R,) int32 — number of live slots per round (≤ Z).

    When the schedule is built from a scenario with a wireless comm
    model (``scenarios/``), two extra host-side columns price each
    round; they never enter the compiled scan (control-plane only):

    latency_s: (R,) float64 — expected round latency, or None.
    energy_j:  (R,) float64 — expected round radio energy, or None.
    """

    idx: np.ndarray
    mask: np.ndarray
    n_i: np.ndarray
    keys: np.ndarray
    clients: np.ndarray
    active: np.ndarray
    latency_s: np.ndarray | None = None
    energy_j: np.ndarray | None = None

    @property
    def rounds(self) -> int:
        return int(self.idx.shape[0])

    @property
    def zone_size(self) -> int:
        return int(self.idx.shape[1])


def plan_zone_round(
    graph: ClientGraph,
    i_k: int,
    zone_size: int,
    rng: np.random.Generator,
    avail: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Form the active zone S(i_k) ⊆ N(i_k) for one round (Eq. 31 subset).

    Returns (idx (Z,), mask (Z,), n_i). Zones larger than ``zone_size``
    are subsampled: i_k plus random neighbors, drawn from ``rng`` — the
    single host RNG shared with per-round key generation, so schedule
    precomputation replays the eager driver's draw sequence exactly.

    ``avail`` is an optional (n,) bool client-availability mask (churn /
    duty-cycling, ``scenarios/``): offline neighbors are dropped from the
    zone before subsampling. The visited client i_k always participates —
    the server is physically at its location. ``avail=None`` (the default)
    consumes ``rng`` identically to the pre-scenario code path.
    """
    zone = graph.neighborhood(i_k)
    if avail is not None:
        zone = zone[avail[zone] | (zone == i_k)]
    n_i = len(zone)
    if n_i > zone_size:
        others = zone[zone != i_k]
        pick = rng.choice(others, size=zone_size - 1, replace=False)
        active = np.concatenate([[i_k], pick])
    else:
        active = zone
    mask = np.zeros(zone_size, np.float32)
    mask[: len(active)] = 1.0
    idx = np.zeros(zone_size, np.int32)
    idx[: len(active)] = active
    return idx, mask, n_i


def zone_schedule(
    dyn_graph,
    walker: RandomWalkServer,
    rounds: int,
    zone_size: int,
    rng: np.random.Generator,
    *,
    start_round: int = 0,
    price=None,
) -> ZoneSchedule:
    """Precompute ``rounds`` zone rounds: graphs (covering regeneration
    epochs), random-walk positions, padded zone membership, and PRNG keys.

    Advances ``dyn_graph``, ``walker``, and ``rng`` exactly as the same
    number of eager per-round calls would, so chunked schedules compose:
    ``zone_schedule(..., R1) + zone_schedule(..., R2, start_round=R1)``
    reproduces one eager run of R1+R2 rounds draw-for-draw.

    ``dyn_graph`` is either a plain ``graph.DynamicGraph`` or a
    ``scenarios.Scenario``. A scenario additionally yields per-round
    client-availability masks (churn) via ``pop_avail_trace()``, which
    feed zone planning, and — when ``price`` is given — per-round
    latency/energy columns. ``price(graphs, clients, idx, mask) ->
    ((R,), (R,))`` prices the whole window in one vectorized call and
    must be deterministic (no RNG) so eager and scan engines price
    identically.
    """
    first = start_round == 0
    graphs = dyn_graph.schedule(rounds, include_current=first)
    pop_trace = getattr(dyn_graph, "pop_avail_trace", None)
    avails = pop_trace() if pop_trace is not None else None
    positions = walker.walk_schedule(graphs, advance_first=not first)

    z = zone_size
    idx = np.zeros((rounds, z), np.int32)
    mask = np.zeros((rounds, z), np.float32)
    n_i = np.zeros((rounds,), np.float32)
    seeds = np.zeros((rounds,), np.int64)
    active = np.zeros((rounds,), np.int32)
    for k in range(rounds):
        idx[k], mask[k], n_i[k] = plan_zone_round(
            graphs[k], int(positions[k]), z, rng,
            avail=None if avails is None else avails[k],
        )
        active[k] = int(mask[k].sum())
        seeds[k] = rng.integers(2**31 - 1)
    latency = energy = None
    if price is not None:
        latency, energy = price(graphs, positions, idx, mask)

    # One batched dispatch for the key block (threefry init is jit-traced,
    # so vmap over seeds matches per-seed PRNGKey bit-for-bit).
    import jax

    keys = np.asarray(jax.vmap(jax.random.PRNGKey)(seeds))
    return ZoneSchedule(
        idx=idx, mask=mask, n_i=n_i, keys=keys,
        clients=positions.astype(np.int32), active=active,
        latency_s=latency, energy_j=energy,
    )
