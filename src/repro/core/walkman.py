"""Walkman-style random-walk consensus ADMM (Mao et al. 2020, paper [35]).

The closest prior algorithm to RWSADMM: a walker token y performs a random
walk over the agents; exactly one agent is activated per iteration; updates
enforce *consensus* (x_i = y for all i) instead of RWSADMM's hard inequality
proximity. Included as an ablation baseline — it isolates the value of the
paper's hard-constraint personalization (RWSADMM vs Walkman differ exactly
there, holding the random-walk/token structure fixed).

We implement the gradient-type variant (Walkman's inexact update, analogous
to the paper's stochastic linearization):

    x_i ← y' − (1/β)(g_i(x_i') + z_i')
    z_i ← z_i' + β (x_i − y')
    y  ← y' + (1/n)[(x_i + z_i/β) − (x_i' + z_i'/β)]
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import tree

PyTree = Any


class WalkmanClientState(NamedTuple):
    x: PyTree
    z: PyTree


class WalkmanServerState(NamedTuple):
    y: PyTree
    round: jnp.ndarray


def init_states(params_template: PyTree, n_clients: int):
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.zeros((n_clients,) + l.shape, l.dtype), params_template
    )
    return (
        WalkmanClientState(x=stacked, z=stacked),
        WalkmanServerState(
            y=tree.zeros_like(params_template),
            round=jnp.asarray(0, jnp.int32),
        ),
    )


def client_round(client: WalkmanClientState, y_prev: PyTree, grad: PyTree,
                 beta: float):
    def x_leaf(y, g, z):
        return y - (g + z) / beta

    x_new = tree.tree_map(x_leaf, y_prev, grad, client.z)
    z_new = tree.tree_map(
        lambda z, x, y: z + beta * (x - y), client.z, x_new, y_prev
    )
    c_new = tree.tree_map(lambda x, z: x + z / beta, x_new, z_new)
    c_old = tree.tree_map(lambda x, z: x + z / beta, client.x, client.z)
    return WalkmanClientState(x=x_new, z=z_new), c_new, c_old


def y_update(y_prev: PyTree, c_new: PyTree, c_old: PyTree, n: int) -> PyTree:
    return tree.tree_map(lambda y, cn, co: y + (cn - co) / n,
                         y_prev, c_new, c_old)
