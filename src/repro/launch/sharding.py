"""Sharding rules: parameter / batch / KV-cache PartitionSpecs.

Policy (MaxText-style 2D "fsdp + tensor" sharding):
  * activations: batch over the data axes (("pod","data") multi-pod).
  * weights: output-feature dim over "model" (tensor parallel), the other
    big dim over the data axes (ZeRO/FSDP storage — XLA all-gathers per
    layer inside the scan and reduce-scatters grads).
  * MoE experts: expert dim over "model" (expert parallel); optional
    ZeRO-3 of the expert hidden dim over "data" (needed for the 1T kimi
    config — see DESIGN.md).
  * KV caches: batch over data axes; cache sequence dim over "model"
    (decode TP); for long_500k (B=1) the sequence dim is sharded over
    BOTH ("data","model") — sequence-parallel decode.

Every rule is divisibility-checked against the mesh; a dim that does not
divide falls back to replication on that axis (e.g. whisper's 51866
vocab), keeping lowering robust across all 10 architectures.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(mesh, dim_size: int, axes) -> bool:
    return dim_size % _axis_size(mesh, axes) == 0


def _spec(mesh, shape, wanted: list) -> P:
    """Apply per-dim wanted axes with divisibility fallback."""
    out = []
    for size, axes in zip(shape, wanted):
        out.append(axes if axes and _fits(mesh, size, axes) else None)
    return P(*out)


def param_spec(path: str, leaf, cfg: ModelConfig, mesh,
               data_axes: tuple[str, ...] | None, *,
               zero3_moe: bool = False, embed_mode: str = "model",
               rglru_row_parallel: bool = False) -> P:
    """Sharding rule for one parameter leaf, by name + rank.

    data_axes=None disables FSDP storage (pure tensor parallel) — the
    §Perf decode variant (no per-token parameter all-gathers)."""
    fsdp = data_axes
    shape = leaf.shape
    name = path.split("/")[-1]
    stacked = ("layers/" in path or "enc_layers" in path
               or "dec_layers" in path)
    lead = [None] if stacked else []       # scan-stacked (R, ...) leading dim
    body = shape[1:] if stacked else shape

    def build(wanted):
        return _spec(mesh, shape, lead + wanted)

    # ---- MoE experts (E, d, h) / (E, h, d); router replicated ----------
    if "/ffn/" in path and cfg.moe is not None:
        if name == "router":
            return build([None, None])
        if name in ("w_in", "w_gate"):
            return build(["model", None, fsdp if zero3_moe else None])
        if name == "w_out":
            return build(["model", fsdp if zero3_moe else None, None])
        # shared expert: plain TP
        if name in ("w_in", "w_gate"):
            return build([fsdp, "model"])
    if "/shared/" in path:
        if name in ("w_in", "w_gate"):
            return build([fsdp, "model"])
        if name == "w_out":
            return build(["model", fsdp])

    # ---- embeddings / head / positional tables -------------------------
    if name == "embed":
        if embed_mode == "tp_d":
            # §Perf variant: vocab replicated, d over model — the token
            # lookup becomes collective-free (rows are local).
            return _spec(mesh, shape, [None, "model"])
        return _spec(mesh, shape, ["model", fsdp])
    if name == "head":
        return _spec(mesh, shape, [fsdp, "model"])
    if name in ("pos_embed", "dec_pos"):
        return _spec(mesh, shape, [None, fsdp])

    # ---- norms / small vectors ------------------------------------------
    if name in ("scale", "b_gates", "lam") or len(body) <= 1:
        return build([None] * len(body))

    # ---- attention projections ------------------------------------------
    if rglru_row_parallel and name in ("w_rg", "w_ig"):
        # §Perf: the gate matmuls consume the (model-sharded) recurrence
        # branch u — row-parallel keeps the chain contraction in place
        # (one psum) instead of an all-gather + column-parallel matmul.
        return build(["model", fsdp])
    if name in ("wq", "wk", "wv", "w_in", "w_gate", "w_up", "w_gate_up",
                "w_x", "w_g", "w_rg", "w_ig", "w_gates", "r_gates",
                "w_if", "projector"):
        return build([fsdp, "model"])
    if name in ("wo", "w_out", "w_down"):
        return build(["model", fsdp])
    if name == "conv_w":
        return build([None, "model"])

    # default: replicate
    return build([None] * len(body))


def params_shardings(params, cfg: ModelConfig, mesh,
                     data_axes: tuple[str, ...] | None, *,
                     zero3_moe: bool = False, embed_mode: str = "model",
                     rglru_row_parallel: bool = False):
    """NamedSharding tree matching the params pytree."""
    def one(kp, leaf):
        path = "/".join(_key_str(k) for k in kp)
        spec = param_spec(path, leaf, cfg, mesh, data_axes,
                          zero3_moe=zero3_moe, embed_mode=embed_mode,
                          rglru_row_parallel=rglru_row_parallel)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"layers/{k.idx}" if False else str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def batch_shardings(cfg: ModelConfig, mesh, data_axes: tuple[str, ...],
                    kind: str = "train", *, batch: int | None = None):
    """Input batch shardings (dict mirrors registry.batch_spec).
    Divisibility-checked: B=1 (long_500k) falls back to replication."""
    dp = data_axes if (batch is None or _fits(mesh, batch, data_axes)) \
        else None
    out = {"tokens": NamedSharding(mesh, P(dp, None))}
    if kind != "decode":
        if cfg.frontend == "vision_stub":
            out["patches"] = NamedSharding(mesh, P(dp, None, None))
        if cfg.frontend == "audio_stub":
            out["frames"] = NamedSharding(mesh, P(dp, None, None))
    return out


def cache_shardings(model, cfg: ModelConfig, mesh,
                    data_axes: tuple[str, ...], batch: int, max_len: int):
    """Sharding tree mirroring model.init_cache(batch, max_len).

    KV k/v leaves: (R, B, S, K, hd). B over data axes when divisible;
    cache seq dim S over "model" (+ data axes too when B == 1, i.e. the
    sequence-parallel long-context decode path).
    """
    cache_struct = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    dp = data_axes
    seq_axes = ("model",) if batch > 1 else tuple(dp) + ("model",)

    def one(leaf):
        shape = leaf.shape
        if len(shape) == 5:  # stacked KVCache k/v: (R, B, S, K, hd)
            return NamedSharding(mesh, _spec(
                mesh, shape, [None, dp, seq_axes, None, None]))
        if len(shape) == 4:  # mlstm C: (R, B, H, hd, hd) is 5D... (B,H,hd,hd) stacked→5
            return NamedSharding(mesh, _spec(
                mesh, shape, [None, dp, None, "model"]))
        if len(shape) == 3:  # recurrent (R, B, d) / conv (R, B, 3, d) is 4D
            return NamedSharding(mesh, _spec(
                mesh, shape, [None, dp, "model"]))
        if len(shape) == 2:
            return NamedSharding(mesh, _spec(mesh, shape, [None, dp]))
        return NamedSharding(mesh, P())

    def route(leaf):
        shape = leaf.shape
        if len(shape) == 6:  # stacked mlstm C: (R, B, H, hd, hd)? → 5D
            return NamedSharding(mesh, _spec(
                mesh, shape, [None, dp, None, None, "model", None]))
        return one(leaf)

    return jax.tree_util.tree_map(route, cache_struct)


def whisper_cache_shardings(model, cfg, mesh, data_axes, batch, max_len,
                            params_struct=None):
    if params_struct is not None:  # cached cross-KV variant (§Perf)
        cache_struct = jax.eval_shape(
            lambda p: model.init_cache(batch, max_len, params=p),
            params_struct)
    else:
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(batch, max_len))
    dp = data_axes
    seq_axes = ("model",) if batch > 1 else tuple(dp) + ("model",)

    def one(leaf):
        shape = leaf.shape
        if len(shape) == 5:   # self_kv k/v (L, B, S, K, hd)
            return NamedSharding(mesh, _spec(
                mesh, shape, [None, dp, seq_axes, None, None]))
        if len(shape) == 3:   # enc_out (B, T, d)
            return NamedSharding(mesh, _spec(mesh, shape,
                                             [dp, None, "model"]))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, cache_struct)
