"""Serving driver: batched prefill + decode for an assigned architecture.

Serves the PERSONALIZED model of whichever client the mobile server last
visited (the y token doubles as the deployable checkpoint). On CPU use a
reduced config.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.registry import build_model, random_batch
from .steps import make_prefill_step, make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        from ..checkpoint import load_pytree

        params = load_pytree(args.ckpt, params)

    max_len = args.prompt_len + args.gen + (
        cfg.n_patches if cfg.frontend == "vision_stub" else 0)
    batch = random_batch(cfg, args.batch, args.prompt_len, seed=0)

    if cfg.encoder_layers > 0:
        # enc-dec: encode once, then token-by-token decode
        enc = jax.jit(model.encode)(params, batch["frames"])
        cache = model.init_cache(args.batch, max_len, enc_out=enc)
        serve = jax.jit(make_serve_step(model))
        tok = batch["tokens"][:, :1]
        t0 = time.perf_counter()
        out = [tok]
        for _ in range(args.gen):
            tok, cache = serve(params, cache, tok)
            out.append(tok)
    else:
        prefill = jax.jit(make_prefill_step(model, max_len))
        serve = jax.jit(make_serve_step(model))
        t0 = time.perf_counter()
        tok, cache = prefill(params, batch)
        t_prefill = time.perf_counter() - t0
        print(f"prefill: {args.batch}×{args.prompt_len} tokens "
              f"in {t_prefill * 1e3:.1f} ms")
        out = [tok]
        for _ in range(args.gen - 1):
            tok, cache = serve(params, cache, tok)
            out.append(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"generated {gen.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample token ids:", gen[0].tolist())


if __name__ == "__main__":
    main()
