from .hostdevices import ensure_host_platform_devices

# Must precede backend init (first computation), hence top-of-module.
ensure_host_platform_devices(512)

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh): build the production mesh
from placeholder host devices, lower + compile the appropriate step
(train_step / prefill / serve_step) with full shardings and
ShapeDtypeStruct inputs (no allocation), record memory_analysis,
cost_analysis and the collective-bytes breakdown parsed from the
compiled HLO. Output: JSON consumed by benchmarks/roofline_report.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse  # noqa: E402
import json  # noqa: E402
import os  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import ALL_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from ..core.rwsadmm import RWSADMMHparams  # noqa: E402
from ..models.registry import batch_spec, build_model  # noqa: E402
from ..models.transformer import ShardingCtx  # noqa: E402
from . import sharding as shard_rules  # noqa: E402
from .mesh import data_axes as mesh_data_axes  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .steps import (  # noqa: E402
    TrainState,
    init_train_state,
    make_serve_step,
    make_train_step,
)

# Skips per DESIGN.md §4 (long_500k needs sub-quadratic attention;
# whisper's 500k decode is not meaningful for a 448-token decoder).
LONG_OK = {"xlstm-350m", "recurrentgemma-9b", "gemma3-12b"}

# Matches the OP (not operand names): "= <shapes> all-reduce(", including
# async "-start" forms; "-done" carries no new bytes and is excluded.
COLLECTIVE_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all"
    r"|collective-permute)(?P<start>-start)?(?:\.\d+)?\(")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f32": 4, "f16": 2, "bf16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8,
}


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the compiled HLO,
    per collective kind. (Result size is the standard proxy for moved
    bytes: all-reduce moves ~2× result with ring reduction — the roofline
    report applies per-kind multipliers.)"""
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line.strip())
        if not m:
            continue
        kind = m.group("kind")
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(m.group("shapes")):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
        count[kind] = count.get(kind, 0) + 1
    out["_counts"] = count
    return out


DEFAULT_OPTIONS = {
    "ce_impl": "gather",     # "onehot" = sharded-vocab CE (§Perf)
    "fsdp_params": True,     # False = pure-TP params (§Perf decode)
    "embed_mode": "model",   # "tp_d" = collective-free token lookup
    "logits_bf16": False,    # True = halve the logits psum (§Perf)
    "bf16_gates": False,     # True = bf16 RG-LRU gate activations (§Perf)
    "rglru_row_parallel": False,  # True = row-parallel RG-LRU gates (§Perf)
    "whisper_cross_kv": False,    # True = precomputed cross-attn K/V (§Perf)
}


def _analyze_one(cfg, shape, mesh, dp, hp, *, unroll: bool,
                 options: dict | None = None) -> dict:
    """Lower + compile one config variant; return metrics dict."""
    opt = {**DEFAULT_OPTIONS, **(options or {})}
    n_chips = int(np.prod(list(mesh.shape.values())))

    zero3 = cfg.moe is not None
    ctx = ShardingCtx(mesh=mesh, data_axes=dp, zero3_moe=zero3)
    model = build_model(cfg, ctx, unroll=unroll)
    if opt["logits_bf16"] and hasattr(model, "logits_dtype"):
        model.logits_dtype = jnp.bfloat16
    if opt["bf16_gates"]:
        from ..models import recurrent as _rec

        _rec.GATE_DTYPE = jnp.bfloat16

    params_struct = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    param_axes = dp if opt["fsdp_params"] else None
    p_shard = shard_rules.params_shardings(
        params_struct, cfg, mesh, param_axes, zero3_moe=zero3,
        embed_mode=opt["embed_mode"],
        rglru_row_parallel=opt["rglru_row_parallel"])

    rec = {"n_chips": n_chips, "kind": shape.kind}
    t0 = time.perf_counter()

    if shape.kind in ("train", "prefill"):
        batch_structs = batch_spec(cfg, shape.global_batch, shape.seq_len,
                                   "train")
        b_shard = shard_rules.batch_shardings(cfg, mesh, dp, "train")
        if shape.kind == "train":
            step = make_train_step(model, hp, ce_impl=opt["ce_impl"])
            state_struct = jax.eval_shape(
                lambda p: init_train_state(p, hp), params_struct)
            state_shard = TrainState(
                x=p_shard, z=p_shard, y=p_shard,
                kappa=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
            jitted = jax.jit(step,
                             in_shardings=(state_shard, b_shard),
                             donate_argnums=(0,))
            with mesh:
                lowered = jitted.lower(state_struct, batch_structs)
        else:
            # prefill: forward logits only (cache fill is exercised by the
            # decode shapes; logits-only keeps prefill comparable across
            # enc-dec and decoder-only archs).
            def fwd(p, b):
                return model.loss(p, b)

            jitted = jax.jit(fwd, in_shardings=(p_shard, b_shard))
            with mesh:
                lowered = jitted.lower(params_struct, batch_structs)
    else:  # decode
        batch = shape.global_batch
        max_len = shape.seq_len
        if cfg.encoder_layers > 0:
            if opt["whisper_cross_kv"]:
                cache_struct = jax.eval_shape(
                    lambda p: model.init_cache(batch, max_len, params=p),
                    params_struct)
                c_shard = shard_rules.whisper_cache_shardings(
                    model, cfg, mesh, dp, batch, max_len,
                    params_struct=params_struct)
            else:
                cache_struct = jax.eval_shape(
                    lambda: model.init_cache(batch, max_len))
                c_shard = shard_rules.whisper_cache_shardings(
                    model, cfg, mesh, dp, batch, max_len)
        else:
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(batch, max_len))
            c_shard = shard_rules.cache_shardings(
                model, cfg, mesh, dp, batch, max_len)
        tok_struct = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        tok_shard = shard_rules.batch_shardings(
            cfg, mesh, dp, "decode", batch=batch)["tokens"]
        step = make_serve_step(model)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, c_shard, tok_shard),
                         donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(params_struct, cache_struct, tok_struct)

    compiled = lowered.compile()
    rec["lower_compile_s"] = round(time.perf_counter() - t0, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k, 0)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes")
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    rec["cost"] = {k: float(v) for k, v in dict(cost).items()
                   if isinstance(v, (int, float)) and (
                       "flops" in k or "bytes" in k or "utilization" not in k)
                   and not k.startswith("utilization")}
    rec["flops"] = float(dict(cost).get("flops", 0.0))
    rec["bytes_accessed"] = float(dict(cost).get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    rec["collectives"] = parse_collective_bytes(hlo)
    rec["hlo_lines"] = hlo.count("\n")
    return rec


def _variant_unit(cfg):
    """(unit pattern, effective repeats) for the flop-accounting variants.

    Long irregular patterns (recurrentgemma's 19-layer unit) would make
    the unrolled variants pathologically slow to compile; use a 3-layer
    prototype unit instead. The layer-kind mix of the prototype (2:1
    rglru:local) matches the real 26:12 mix to within one layer (~2%
    flops error, noted in EXPERIMENTS.md)."""
    pat = cfg.layer_pattern
    if len(pat) <= 8:
        return pat, float(cfg.pattern_repeats)
    unit = pat[:3]
    return unit, cfg.n_layers / float(len(unit))


def _variant_cfg(cfg, k: int):
    """Config with k unit-groups of layers (fully unrolled for flop
    accounting). Encoder layers (whisper) scale equally."""
    import dataclasses

    unit, _ = _variant_unit(cfg)
    enc = 0
    if cfg.encoder_layers:
        enc = k * max(1, cfg.encoder_layers // cfg.pattern_repeats)
    return dataclasses.replace(cfg, layer_pattern=unit,
                               n_layers=len(unit) * k, encoder_layers=enc)


def _linear_correct(main: dict, v1: dict, v2: dict, repeats: int) -> dict:
    """XLA cost_analysis counts a lax.scan body ONCE, not ×trip-count, so
    the scanned layer stack is undercounted by the repeat factor. We lower
    two fully-unrolled shallow variants (1 and 2 pattern groups), solve
    total = base + R·group exactly, and correct flops / bytes /
    per-kind collective bytes. (memory_analysis stays from the real
    scanned artifact — that IS what production executes.)"""
    out = dict(main)

    def corr(a1, a2, floor):
        grp = max(0.0, a2 - a1)
        base = max(0.0, a1 - grp)
        return max(float(floor), base + repeats * grp)

    out["flops_scan_reported"] = main["flops"]
    out["flops"] = corr(v1["flops"], v2["flops"], main["flops"])
    out["bytes_accessed"] = corr(v1["bytes_accessed"], v2["bytes_accessed"],
                                 main["bytes_accessed"])
    coll = {}
    kinds = (set(main["collectives"]) | set(v1["collectives"])
             | set(v2["collectives"])) - {"_counts"}
    for k in kinds:
        coll[k] = int(corr(v1["collectives"].get(k, 0),
                           v2["collectives"].get(k, 0),
                           main["collectives"].get(k, 0)))
    coll["_counts"] = main["collectives"].get("_counts", {})
    out["collectives"] = coll
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            hp: RWSADMMHparams | None = None,
            options: dict | None = None) -> dict:
    """Lower + compile one (arch × shape × mesh) combination, with the
    scan-undercount flop correction via two unrolled shallow variants.
    ``options`` selects §Perf variants (see DEFAULT_OPTIONS)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    hp = hp or RWSADMMHparams(beta=10.0)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = mesh_data_axes(mesh)

    main = _analyze_one(cfg, shape, mesh, dp, hp, unroll=False,
                        options=options)
    v1 = _analyze_one(_variant_cfg(cfg, 1), shape, mesh, dp, hp,
                      unroll=True, options=options)
    v2 = _analyze_one(_variant_cfg(cfg, 2), shape, mesh, dp, hp,
                      unroll=True, options=options)
    _, eff_repeats = _variant_unit(cfg)
    rec = _linear_correct(main, v1, v2, eff_repeats)
    rec.update({
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "options": {**DEFAULT_OPTIONS, **(options or {})},
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for arch in ALL_ARCHS:
            for shape in INPUT_SHAPES:
                combos.append((arch, shape, args.multi_pod))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in combos:
        cfg = get_config(arch)
        if shape == "long_500k" and arch not in LONG_OK:
            print(f"SKIP {arch} × {shape}: full attention (DESIGN.md §4)")
            continue
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"CACHED {tag}")
            continue
        print(f"RUN {tag} ...", flush=True)
        try:
            rec = run_one(arch, shape, multi_pod=mp)
            rec["status"] = "ok"
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  ERROR: {rec['error'][:200]}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec.get("status") == "ok":
            print(f"  ok: flops={rec['flops']:.3e} "
                  f"coll={ {k: v for k, v in rec['collectives'].items() if k != '_counts'} } "
                  f"compile={rec['lower_compile_s']}s")


if __name__ == "__main__":
    main()
