"""Launch layer: production mesh, sharding rules, dry-run, drivers."""
