"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run process
overrides the device count via XLA_FLAGS before first jax init, while
tests/benches must keep seeing the single real device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_data_mesh(n_devices: int | None = None):
    """1-D "data" mesh over all (or the first ``n_devices``) local
    devices — the FL client plane's shard unit is the leading
    client/capacity axis, so a single data axis is the whole story."""
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"requested {n_devices} devices, have {len(devs)} "
                "(set --xla_force_host_platform_device_count before "
                "backend init for CPU hosts)")
        devs = devs[:n_devices]
    return jax.make_mesh((len(devs),), ("data",), devices=devs)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CI-grade dry-run tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
