"""Host-platform device bootstrap (shared by benches, dry-run, tests).

XLA can split one CPU host into N "host platform devices"
(``--xla_force_host_platform_device_count=N``), which is how the
dry-run mesh, the multi-device CPU bench harness, and the sharded-plane
tests get a mesh without real accelerators. The flag only takes effect
if it is present in ``XLA_FLAGS`` *before* the JAX backend initializes
(first computation / first ``jax.devices()`` call — NOT import), so the
helpers here must run at the very top of an entrypoint.

This module deduplicates the copy-pasted env blocks that used to live
at the top of ``benchmarks/perf_iterations.py`` and
``repro/launch/dryrun.py``, and adds the olmax-style tcmalloc env for
multi-device CPU runs (SNIPPETS §1–2).
"""
from __future__ import annotations

import os

_FLAG = "xla_force_host_platform_device_count"

# Common Debian/Ubuntu locations, preferred order (olmax uses the
# first). Only used when the file actually exists — never forced.
_TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
)


def ensure_host_platform_devices(count: int = 512) -> bool:
    """Prepend ``--xla_force_host_platform_device_count=count`` to
    XLA_FLAGS unless some value for the flag is already set.

    Idempotent; returns True when the env now requests the flag (either
    set here or pre-existing). Must run before the JAX backend
    initializes — callers that cannot guarantee that (e.g. a bench
    registry where earlier jobs already ran computations) should spawn
    a fresh subprocess with this env instead (see
    ``subprocess_env``)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if _FLAG in flags:
        return True
    os.environ["XLA_FLAGS"] = (
        f"--{_FLAG}={int(count)} " + flags).strip()
    return True


def host_device_env(count: int, base: dict | None = None,
                    *, tcmalloc: bool = True) -> dict:
    """Environment dict for a FRESH subprocess that should see ``count``
    host platform devices: XLA flag + (when available) the olmax
    tcmalloc LD_PRELOAD, which keeps many-device CPU allocation from
    serializing on glibc malloc."""
    env = dict(os.environ if base is None else base)
    flags = env.get("XLA_FLAGS", "")
    if _FLAG not in flags:
        env["XLA_FLAGS"] = (f"--{_FLAG}={int(count)} " + flags).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    if tcmalloc and "LD_PRELOAD" not in env:
        for p in _TCMALLOC_PATHS:
            if os.path.exists(p):
                env["LD_PRELOAD"] = p
                env.setdefault(
                    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                    str(2 ** 37))
                break
    return env
