"""Compiled step functions: RWSADMM zone-round training and serving.

``train_step`` is one RWSADMM zone round at datacenter scale (DESIGN.md
§3): the active client's personalized model x, dual z and the server
token y live sharded on the mesh; the zone's minibatch is sharded over
the data axes (each data shard = one zone member's samples, Eq. 31), so
the gradient mean IS the zone aggregation (one all-reduce / reduce-
scatter); the closed-form x/z/y updates are elementwise.

``serve_step`` is one-token decode against the KV cache (decode shapes).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core import rwsadmm
from ..core.rwsadmm import RWSADMMHparams


class TrainState(NamedTuple):
    """RWSADMM state for the active zone at scale."""

    x: Any          # active client's personalized params
    z: Any          # dual
    y: Any          # mobile-server token
    kappa: jnp.ndarray


def init_train_state(params, hp: RWSADMMHparams) -> TrainState:
    return TrainState(
        x=params,
        z=jax.tree_util.tree_map(jnp.zeros_like, params),
        y=params,
        kappa=jnp.asarray(hp.kappa, jnp.float32),
    )


def make_train_step(model, hp: RWSADMMHparams, n_total: float = 20.0,
                    *, ce_impl: str = "gather"):
    """One RWSADMM round: stochastic grad at x' + fused x/z/y update.

    n_total: the client population size n the host launcher tracks (the
    y-fold weight — see core.rwsadmm.y_update).
    ce_impl: cross-entropy formulation (see LM.loss) — "onehot" is the
    sharded-vocab-friendly §Perf variant."""

    def train_step(state: TrainState, batch):
        def loss_fn(p):
            try:
                return model.loss(p, batch, ce_impl=ce_impl)
            except TypeError:  # EncDecLM has no ce_impl knob
                return model.loss(p, batch)

        loss, g = jax.value_and_grad(loss_fn)(state.x)
        # Elementwise triple update (kernels/rwsadmm_update math; expressed
        # in jnp here so GSPMD shards it with the params — XLA fuses the
        # chain into one pass; the Pallas kernel is the single-device /
        # client-edge build of the same op).
        client = rwsadmm.ClientState(x=state.x, z=state.z)
        new_client, c_new, c_old = rwsadmm.client_round(
            client, state.y, g, hp, state.kappa)
        y_new = rwsadmm.y_update(state.y, c_new, c_old, n_total=n_total)
        new_state = TrainState(
            x=new_client.x, z=new_client.z, y=y_new,
            kappa=state.kappa * hp.kappa_decay,
        )
        return new_state, loss

    return train_step


def make_serve_step(model):
    """(params, cache, tokens (B,1)) → (next_token (B,1), cache)."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step


def make_prefill_step(model, max_len: int):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, max_len)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(
            jnp.int32)[:, None]
        return next_tok, cache

    return prefill_step
