"""Training driver: RWSADMM federated rounds over an assigned architecture.

Runs the full mobile-server control plane (dynamic graph + random walk,
exactly the paper's Algorithm 1) around the compiled zone step from
launch/steps.py. On CPU, use a reduced config; on a real cluster the same
driver runs the full config over the production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --clients 8 --rounds 20 --batch 2 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.graph import DynamicGraph
from ..core.markov import RandomWalkServer
from ..core.rwsadmm import RWSADMMHparams
from ..models.registry import build_model, random_batch
from .steps import TrainState, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--beta", type=float, default=1.0)
    ap.add_argument("--kappa", type=float, default=0.001)
    ap.add_argument("--epsilon", type=float, default=1e-5)
    ap.add_argument("--min-degree", type=int, default=3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    hp = RWSADMMHparams(beta=args.beta, kappa=args.kappa,
                        epsilon=args.epsilon)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.arch_id}  params={n_params/1e6:.2f}M  "
          f"clients={args.clients}")

    # Every client gets its own token stream (heterogeneous corpora).
    client_batches = [
        random_batch(cfg, args.batch, args.seq, seed=100 + c)
        for c in range(args.clients)
    ]

    # One TrainState per client (x_i, z_i) + the wandering y token.
    step = jax.jit(make_train_step(model, hp, n_total=args.clients))
    states = [init_train_state(params, hp) for _ in range(args.clients)]

    dyn = DynamicGraph(args.clients, min_degree=args.min_degree,
                       regen_every=10, seed=0)
    walker = RandomWalkServer(seed=1)
    walker.reset(dyn.current())

    y_token = states[0].y
    kappa = jnp.asarray(hp.kappa, jnp.float32)
    t0 = time.perf_counter()
    for r in range(args.rounds):
        graph = dyn.step() if r else dyn.current()
        i_k = walker.step(graph) if r else walker.position
        st = states[i_k]
        st = TrainState(x=st.x, z=st.z, y=y_token, kappa=kappa)
        st, loss = step(st, client_batches[i_k])
        states[i_k] = st
        y_token, kappa = st.y, st.kappa
        print(f"round {r:4d}  client {i_k:3d}  loss {float(loss):8.4f}  "
              f"kappa {float(kappa):.5f}")
    dt = time.perf_counter() - t0
    print(f"done: {args.rounds} rounds in {dt:.1f}s "
          f"({dt / args.rounds * 1e3:.0f} ms/round)")

    if args.ckpt:
        from ..checkpoint import save_pytree

        save_pytree(args.ckpt, y_token, step=args.rounds)
        print(f"saved server token to {args.ckpt}")


if __name__ == "__main__":
    main()
