"""Run-report CLI: render a recorded telemetry run as a text summary.

``python -m repro.telemetry.report runs/<id>`` reads ``manifest.json`` +
``events.jsonl`` and prints:

* ``== Run ==``                  manifest (algo, seed, backend, git SHA)
* ``== Convergence ==``          eval snapshots + an ASCII accuracy curve
* ``== Coverage & staleness ==`` visit-trace coverage timeline and the
                                 staleness distribution trajectory
* ``== Communication ==``        byte / latency / energy totals
* ``== Phase times ==``          fenced phase-timer breakdown (compile-
                                 inclusive first calls split out)
* ``== Walkers ==``              per-walker fleet table (fleet runs)

The same renderer is importable (:func:`render_report`) so tests and CI
assert on the exact artifact users see. ``--json`` emits the summary as
machine-readable JSON instead.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict

from .events import read_events, split_by_type

_BLOCKS = " ▁▂▃▄▅▆▇█"


def _spark(vals: list[float], width: int = 48) -> str:
    """ASCII sparkline, resampled to ``width`` columns."""
    if not vals:
        return ""
    if len(vals) > width:
        step = len(vals) / width
        vals = [vals[int(i * step)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(_BLOCKS[1 + int((v - lo) / span * (len(_BLOCKS) - 2))]
                   for v in vals)


def _fmt_row(cols, widths) -> str:
    return "  ".join(str(c).ljust(w) for c, w in zip(cols, widths)).rstrip()


def _table(header: list[str], rows: list[list]) -> list[str]:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              if rows else len(str(h)) for i, h in enumerate(header)]
    out = [_fmt_row(header, widths),
           _fmt_row(["-" * w for w in widths], widths)]
    out += [_fmt_row(r, widths) for r in rows]
    return out


def load_run(run_dir: str) -> tuple[dict, dict[str, list[dict]]]:
    """(manifest, events bucketed by type) for one run directory."""
    mpath = os.path.join(run_dir, "manifest.json")
    if not os.path.exists(mpath):
        raise FileNotFoundError(
            f"no manifest.json under {run_dir!r} — not a telemetry run")
    with open(mpath) as f:
        manifest = json.load(f)
    epath = os.path.join(run_dir, manifest.get("events", "events.jsonl"))
    buckets = split_by_type(read_events(epath)
                            if os.path.exists(epath) else [])
    return manifest, buckets


def summarize(run_dir: str) -> dict:
    """Machine-readable summary (what ``--json`` prints)."""
    manifest, b = load_run(run_dir)
    rounds = b["round"]
    visits = b["visit"]
    snaps = b["snapshot"]
    phases = b["phase"]

    comm = sum(int(r.get("comm_bytes", 0)) for r in rounds)
    latency = sum(float(r.get("latency_s", 0.0)) for r in rounds)
    energy = sum(float(r.get("energy_j", 0.0)) for r in rounds)

    seen: set[int] = set()
    coverage: list[tuple[int, int]] = []
    for v in visits:
        seen.add(v["client"])
        coverage.append((v["round"], len(seen)))

    phase_agg: dict[tuple, dict] = {}
    for p in phases:
        key = (p["name"], bool(p.get("includes_compile")))
        a = phase_agg.setdefault(key, {"calls": 0, "seconds": 0.0})
        a["calls"] += 1
        a["seconds"] += float(p["seconds"])

    walkers: dict[int, dict] = defaultdict(
        lambda: {"visits": 0, "unique": set(), "zone": 0, "energy_j": 0.0})
    for v in visits:
        if "walker" in v:
            w = walkers[int(v["walker"])]
            w["visits"] += 1
            w["unique"].add(v["client"])
            w["zone"] += int(v.get("zone", 0))
            w["energy_j"] += float(v.get("energy_j", 0.0))

    return {
        "manifest": manifest,
        "n_rounds": len(rounds),
        "n_visits": len(visits),
        "snapshots": snaps,
        "final": snaps[-1] if snaps else {},
        "loss_curve": [float(r["train_loss"]) for r in rounds
                       if "train_loss" in r],
        "coverage": coverage,
        "unique_clients": len(seen),
        "staleness": [(r["round"], r["staleness_p50"], r["staleness_max"])
                      for r in rounds if "staleness_max" in r],
        "comm_bytes_total": comm,
        "latency_s_total": latency,
        "energy_j_total": energy,
        "phases": [
            {"name": k[0], "includes_compile": k[1], **a}
            for k, a in sorted(phase_agg.items())],
        "walkers": {
            k: {"visits": w["visits"], "unique_clients": len(w["unique"]),
                "mean_zone": (w["zone"] / w["visits"]) if w["visits"] else 0,
                "energy_j": w["energy_j"]}
            for k, w in sorted(walkers.items())},
        "counters": {c["name"]: c["value"] for c in b["counter"]},
    }


def render_report(run_dir: str) -> str:
    s = summarize(run_dir)
    m = s["manifest"]
    cfg = m.get("config", {})
    L: list[str] = []

    L.append("== Run ==")
    L.append(f"run_id:    {m.get('run_id')}   status: {m.get('status')}")
    L.append(f"algo:      {cfg.get('algo', '?')}   "
             f"engine: {cfg.get('engine', '?')}   "
             f"rounds: {s['n_rounds']}   seed: {m.get('seed')}")
    jx = m.get("jax") or {}
    L.append(f"backend:   {jx.get('backend', '?')} "
             f"x{jx.get('device_count', '?')}   "
             f"jax {m.get('packages', {}).get('jax', '?')}   "
             f"git {str(m.get('git_sha'))[:12]}")
    L.append(f"dir:       {os.path.abspath(run_dir)}")
    L.append("")

    L.append("== Convergence ==")
    snaps = s["snapshots"]
    if snaps:
        accs = [float(sn.get("acc", float("nan"))) for sn in snaps]
        L.append(f"acc  [{min(accs):.4f} … {max(accs):.4f}]  "
                 f"{_spark(accs)}")
        rows = [[sn.get("round"),
                 f"{float(sn.get('acc', float('nan'))):.4f}",
                 f"{float(sn.get('loss_personalized', sn.get('loss_global', float('nan')))):.4f}",
                 sn.get("comm_bytes_total", "")] for sn in snaps]
        L += _table(["round", "acc", "loss", "comm_bytes_total"], rows)
    elif s["loss_curve"]:
        lc = s["loss_curve"]
        L.append(f"train_loss  [{min(lc):.4f} … {max(lc):.4f}]  "
                 f"{_spark(lc)}")
    else:
        L.append("(no snapshots recorded)")
    L.append("")

    L.append("== Coverage & staleness ==")
    if s["coverage"]:
        frac = [c / max(s['unique_clients'], 1) for _, c in s["coverage"]]
        L.append(f"coverage    {s['unique_clients']} unique clients "
                 f"over {s['n_visits']} visits  {_spark(frac)}")
    else:
        L.append("(no visit trace recorded)")
    if s["staleness"]:
        p50 = [x[1] for x in s["staleness"]]
        mx = [x[2] for x in s["staleness"]]
        L.append(f"staleness_p50  last={p50[-1]:g}  max-seen="
                 f"{max(p50):g}  {_spark(p50)}")
        L.append(f"staleness_max  last={mx[-1]:g}  max-seen="
                 f"{max(mx):g}  {_spark([float(v) for v in mx])}")
    L.append("")

    L.append("== Communication ==")
    L.append(f"comm_bytes: {s['comm_bytes_total']:,}   "
             f"latency_s: {s['latency_s_total']:.6g}   "
             f"energy_j: {s['energy_j_total']:.6g}")
    L.append("")

    L.append("== Phase times ==")
    if s["phases"]:
        rows = [[p["name"] + (" (incl. compile)" if p["includes_compile"]
                              else ""),
                 p["calls"], f"{p['seconds']:.4f}",
                 f"{p['seconds'] / p['calls'] * 1e3:.2f}"]
                for p in s["phases"]]
        L += _table(["phase", "calls", "total_s", "mean_ms"], rows)
    else:
        L.append("(no phase spans recorded)")
    L.append("")

    if s["walkers"]:
        L.append("== Walkers ==")
        rows = [[k, w["visits"], w["unique_clients"],
                 f"{w['mean_zone']:.2f}", f"{w['energy_j']:.4g}"]
                for k, w in s["walkers"].items()]
        L += _table(["walker", "visits", "unique_clients", "mean_zone",
                     "energy_j"], rows)
        L.append("")

    if s["counters"]:
        L.append("== Counters ==")
        L += [f"{k}: {v}" for k, v in sorted(s["counters"].items())]
        L.append("")
    return "\n".join(L)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Render a recorded telemetry run as a text summary.")
    ap.add_argument("run_dir", help="run directory (e.g. runs/<id>)")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable summary instead")
    args = ap.parse_args(argv)
    if args.json:
        out = summarize(args.run_dir)
        json.dump(out, sys.stdout, indent=1, default=str)
        print()
    else:
        print(render_report(args.run_dir))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
