"""Opt-in device-level profiling hooks (``jax.profiler``).

Phase timers (``TelemetryRun.phase``) give wall-clock spans; when that
is not enough, a run opened with ``profile=True`` (or with
``REPRO_PROFILE=1`` in the environment) additionally wraps its training
loop in ``jax.profiler.trace`` writing a TensorBoard-loadable trace to
``runs/<id>/profile/``, and hot-path call sites can annotate compiled
regions with :func:`annotate` (``jax.profiler.TraceAnnotation``) so the
device timeline carries the same phase names as the event stream.

Everything degrades to a no-op when profiling is off or the profiler is
unavailable, so these hooks are safe to leave in library code.
"""
from __future__ import annotations

import contextlib
import os


def profiling_enabled(run=None) -> bool:
    """True when this run (or the environment) opted into profiling."""
    if run is not None and getattr(run, "profile", False):
        return True
    return os.environ.get("REPRO_PROFILE", "") not in ("", "0")


@contextlib.contextmanager
def maybe_trace(run=None):
    """``jax.profiler.trace`` over the wrapped block, writing under the
    run's ``profile/`` directory — a no-op unless profiling is enabled
    and a run directory exists to hold the trace."""
    if run is None or not profiling_enabled(run):
        yield None
        return
    logdir = os.path.join(run.run_dir, "profile")
    try:
        import jax.profiler as jp

        os.makedirs(logdir, exist_ok=True)
        with jp.trace(logdir):
            yield logdir
        run.update_manifest(profile_dir="profile")
    except Exception:
        # Profiler unavailable (or a second concurrent trace): never
        # let observability take down the run being observed.
        yield None


def annotate(name: str):
    """Named region on the device trace (``TraceAnnotation``); a cheap
    no-op context manager when the profiler is unavailable."""
    try:
        import jax.profiler as jp

        return jp.TraceAnnotation(name)
    except Exception:  # pragma: no cover - jax always present in CI
        return contextlib.nullcontext()
