"""Walk/zone trace stream: per-visit event records.

The scan drivers already materialize everything a walk trace needs as
fixed-shape host arrays (``core.markov.ZoneSchedule`` /
``FleetZoneSchedule``: visited clients, zone sizes, importance weights,
CommModel latency/energy columns), so tracing a whole chunk is one
vectorized column extraction + one serialization loop — never per-step
Python inside the hot path, and never a device sync (the columns are
host-side control plane by construction).

Eager rounds trace through :func:`visit_events_from_round`, which reads
the round's already-built ``round_metrics`` entry.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

#: per-round metric keys copied onto that round's visit events
_ROUND_CARRY = ("staleness_p50", "staleness_max")


def _opt(col, j):
    return None if col is None else float(np.asarray(col[j]))


def visit_events_from_schedule(sched, start_round: int,
                               round_entries: list[dict] | None = None,
                               ) -> Iterator[dict]:
    """Yield one ``visit`` event dict per walker visit in a finished
    schedule chunk (single-walker and round-robin fleet: one per round;
    simultaneous fleet: one per walker per wall step).

    ``round_entries`` (the chunk's ``chunk_round_metrics`` output,
    aligned by round) contributes the per-round staleness columns —
    those live on the trainer's service clock, not in the schedule.
    """
    clients = np.asarray(sched.clients)
    fleet_sim = clients.ndim == 2            # simultaneous: (R, K)
    active = np.asarray(sched.active)
    n_i = np.asarray(sched.n_i)
    walker = getattr(sched, "walker", None)  # round-robin fleet: (R,)
    iw = sched.iw
    lat = sched.latency_s
    en = sched.energy_j
    lat_w = getattr(sched, "latency_s_walkers", None)   # (R, K) or None
    en_w = getattr(sched, "energy_j_walkers", None)
    for j in range(sched.rounds):
        carry: dict = {}
        if round_entries is not None:
            entry = round_entries[j]
            carry = {k: entry[k] for k in _ROUND_CARRY if k in entry}
        if fleet_sim:
            for k in range(clients.shape[1]):
                e = {"round": start_round + j, "walker": k,
                     "client": int(clients[j, k]),
                     "zone": int(active[j, k]), "n_i": int(n_i[j, k]),
                     **carry}
                if iw is not None:
                    e["iw"] = float(np.asarray(iw[j, k]))
                if lat_w is not None:
                    e["latency_s"] = float(np.asarray(lat_w[j, k]))
                    e["energy_j"] = float(np.asarray(en_w[j, k]))
                yield e
        else:
            e = {"round": start_round + j, "client": int(clients[j]),
                 "zone": int(active[j]), "n_i": int(n_i[j]), **carry}
            if walker is not None:
                e["walker"] = int(walker[j])
            if iw is not None:
                e["iw"] = float(np.asarray(iw[j]))
            if lat is not None:
                e["latency_s"] = _opt(lat, j)
                e["energy_j"] = _opt(en, j)
            yield e


def visit_events_from_round(metrics: dict) -> Iterator[dict]:
    """Visit event(s) for one eager round, from its ``round_metrics``
    entry. Single-walker / round-robin entries carry ``client`` (and
    maybe ``walker``); simultaneous-fleet entries carry a ``clients``
    tuple and only wall-step aggregates, so their per-visit events hold
    the shared round columns."""
    carry = {k: metrics[k] for k in _ROUND_CARRY if k in metrics}
    base = {"round": metrics["round"], **carry}
    for k in ("iw", "latency_s", "energy_j"):
        if k in metrics and not isinstance(metrics.get("clients"), tuple):
            base[k] = metrics[k]
    if isinstance(metrics.get("clients"), tuple):
        for w, c in enumerate(metrics["clients"]):
            yield {**base, "walker": w, "client": int(c)}
    elif "client" in metrics:
        e = {**base, "client": int(metrics["client"]),
             "zone": metrics.get("zone"), "n_i": metrics.get("n_i")}
        if "walker" in metrics:
            e["walker"] = int(metrics["walker"])
        yield {k: v for k, v in e.items() if v is not None}
