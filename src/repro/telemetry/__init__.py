"""Telemetry: structured run recording for the whole stack.

One :class:`TelemetryRun` per recorded run captures a manifest (config,
seed, git SHA, jax backend/devices, package versions) and streams typed
events — ``round`` / ``visit`` / ``snapshot`` / ``phase`` / ``counter``
— to ``runs/<id>/events.jsonl``. Every layer emits into it through an
optional ``telemetry=`` hook (``run_simulation``, the RWSADMM single
and fleet trainers, the FedAvg-family baselines, ``Scenario``); the
default ``None`` keeps today's behavior bit-identical.

Render a recorded run with ``python -m repro.telemetry.report
runs/<id>``; see ``docs/observability.md`` for the event schema,
phase-timer semantics, and profiler opt-in.
"""
from .artifacts import (
    atomic_write_json,
    atomic_write_text,
    load_bench_rows,
    merge_bench_rows,
)
from .events import (
    EVENT_TYPES,
    SCHEMA_VERSION,
    TelemetryError,
    read_events,
    split_by_type,
    validate_event,
)
from .profiler import annotate, maybe_trace, profiling_enabled
from .recorder import (
    PhaseSpan,
    TelemetryRun,
    manifest_fingerprint,
    null_phase,
    telemetry_print,
)
from .trace import visit_events_from_round, visit_events_from_schedule

__all__ = [
    "EVENT_TYPES",
    "SCHEMA_VERSION",
    "PhaseSpan",
    "TelemetryError",
    "TelemetryRun",
    "annotate",
    "atomic_write_json",
    "atomic_write_text",
    "load_bench_rows",
    "manifest_fingerprint",
    "maybe_trace",
    "merge_bench_rows",
    "null_phase",
    "profiling_enabled",
    "read_events",
    "split_by_type",
    "telemetry_print",
    "validate_event",
    "visit_events_from_round",
    "visit_events_from_schedule",
]
