"""Tiny end-to-end telemetry smoke run.

``python -m repro.telemetry.smoke --out runs/ci-smoke`` builds a small
federated workload, runs a few RWSADMM rounds with telemetry enabled
(through the compiled scan driver and a wireless scenario so every
event type — round / visit / snapshot / phase / counter — is
exercised), and prints the run directory. CI then renders the artifact
with the report CLI and greps the summary sections; tests reuse
:func:`smoke_run` for the write → read → report round-trip.
"""
from __future__ import annotations

import argparse

from .recorder import TelemetryRun


def smoke_run(run_dir: str, *, rounds: int = 6, eval_every: int = 3,
              n_clients: int = 8, engine: str = "scan",
              fleet: int = 0, seed: int = 0,
              profile: bool = False) -> TelemetryRun:
    """Run the smoke workload into ``run_dir`` and return the closed
    telemetry run. ``fleet=K`` (K > 0) drives the K-walker fleet
    trainer instead of the single walker."""
    from ..core.rwsadmm import RWSADMMHparams
    from ..data import make_image_dataset, pathological_split
    from ..data.loader import build_federated
    from ..fl.base import to_device_data
    from ..fl.fleet_trainer import FleetRWSADMMTrainer
    from ..fl.rwsadmm_trainer import RWSADMMTrainer
    from ..fl.simulation import run_simulation
    from ..models.small import get_model

    imgs, labels = make_image_dataset(40 * n_clients, seed=seed)
    parts = pathological_split(labels, n_clients, seed=seed)
    data = to_device_data(build_federated(imgs, labels, parts))
    model = get_model("mlr", (28, 28, 1))
    kw = dict(zone_size=4, batch_size=16, solver="closed_form",
              scenario="lossy_links", seed=seed)
    if fleet > 0:
        trainer = FleetRWSADMMTrainer(
            model, data, RWSADMMHparams(beta=10.0), n_walkers=fleet,
            sync_every=4, **kw)
    else:
        trainer = RWSADMMTrainer(model, data, RWSADMMHparams(beta=10.0),
                                 **kw)
    tel = TelemetryRun(run_dir, seed=seed, profile=profile,
                       config={"workload": "telemetry_smoke",
                               "fleet": fleet})
    with tel:
        run_simulation(trainer, rounds=rounds, eval_every=eval_every,
                       seed=seed, engine=engine, telemetry=tel,
                       verbose=True)
    return tel


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.smoke",
        description="Record a tiny telemetry run (CI smoke workload).")
    ap.add_argument("--out", default="runs/smoke", help="run directory")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--engine", default="scan",
                    choices=["eager", "scan", "scan_fused"])
    ap.add_argument("--fleet", type=int, default=0,
                    help="K > 0: run the K-walker fleet trainer")
    ap.add_argument("--profile", action="store_true",
                    help="also capture a jax.profiler trace")
    args = ap.parse_args(argv)
    tel = smoke_run(args.out, rounds=args.rounds, engine=args.engine,
                    fleet=args.fleet, profile=args.profile)
    print(tel.run_dir)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
