"""Run-scoped telemetry recorder: manifest + JSONL event stream.

A :class:`TelemetryRun` owns one run directory (``runs/<id>/`` by
default) holding:

* ``manifest.json`` — config, seed, git SHA, jax backend/device count,
  package versions, status; written atomically at open, on
  :meth:`update_manifest`, and at :meth:`close`.
* ``events.jsonl``  — the typed event stream (``telemetry.events``),
  one line per event, appended as the run executes.
* ``profile/``      — optional ``jax.profiler`` traces
  (``telemetry.profiler``, opt-in).

Every layer of the stack emits into the same run: ``run_simulation``
(rounds, snapshots, phase spans), the trainers' scan drivers (schedule
precompute / chunk execution spans), ``Scenario.schedule`` (rollout
spans), and the walk/zone trace stream (``telemetry.trace``). The
recorder never touches an RNG and never forces a device sync the caller
didn't ask for (phase fencing is explicit via :meth:`PhaseSpan.fence`),
so telemetry-on trajectories are bit-identical to telemetry-off — pinned
in ``tests/test_telemetry.py``.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import subprocess
import sys
import threading
import time
from typing import Any

from . import events as ev
from .artifacts import atomic_write_json

log = logging.getLogger("repro.telemetry")

#: manifest keys that must be identical across runs of the same seeded
#: workload on the same checkout/toolchain (the determinism contract
#: asserted by manifest_fingerprint and its test).
DETERMINISTIC_MANIFEST_KEYS = (
    "schema_version", "seed", "config", "git_sha", "jax", "packages",
)


def _git_sha() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _environment() -> tuple[dict, dict]:
    """(jax runtime info, package versions) — best-effort, import-gated
    so the recorder also works in jax-free tooling contexts."""
    jx: dict[str, Any] = {}
    pkgs: dict[str, str] = {
        "python": ".".join(map(str, sys.version_info[:3])),
    }
    try:
        import jax

        jx = {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "devices": [d.device_kind for d in jax.devices()],
        }
        pkgs["jax"] = jax.__version__
        import jaxlib

        pkgs["jaxlib"] = getattr(jaxlib, "__version__", "?")
    except Exception:  # pragma: no cover - jax always present in CI
        pass
    try:
        import numpy

        pkgs["numpy"] = numpy.__version__
    except Exception:  # pragma: no cover
        pass
    return jx, pkgs


def manifest_fingerprint(manifest: dict) -> str:
    """sha256 over the deterministic manifest subset — two runs of the
    same seeded workload on the same checkout must agree on this even
    though run ids and timestamps differ."""
    sub = {k: manifest.get(k) for k in DETERMINISTIC_MANIFEST_KEYS}
    blob = json.dumps(sub, sort_keys=True, separators=(",", ":"),
                      default=ev._json_default)
    return hashlib.sha256(blob.encode()).hexdigest()


class PhaseSpan:
    """One fenced phase-timer span (context manager).

    The span opens at ``__enter__`` and records at ``__exit__``; call
    :meth:`fence` on device values before the context closes so async
    dispatch doesn't end the span early — the span then measures
    completed device work, not enqueue time. The fence is explicit
    (never implicit) so a span can also time pure host work without
    forcing a sync.
    """

    def __init__(self, run: "TelemetryRun", name: str, meta: dict):
        self._run = run
        self.name = name
        self.meta = meta
        self.seconds: float | None = None

    def fence(self, value):
        """``jax.block_until_ready`` on ``value`` (pass-through), so the
        span covers the device work that produced it."""
        import jax

        return jax.block_until_ready(value)

    def __enter__(self) -> "PhaseSpan":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = time.perf_counter() - self._t0
        if exc_type is None:
            # ``t0`` (seconds since the run opened) lets the report CLI
            # reconstruct the span timeline — e.g. show the prefetch
            # staging span overlapping the scan_chunk span it hides
            # behind. Wall-clock, so (like ``seconds``) excluded from
            # the byte-identical-events determinism contract.
            self._run.emit("phase", name=self.name,
                           seconds=self.seconds,
                           t0=round(self._t0 - self._run._t_open, 6),
                           **self.meta)


class _NullSpan(PhaseSpan):
    """Phase span with no recorder attached (telemetry disabled)."""

    def __init__(self):  # noqa: D401 - trivial
        super().__init__(None, "", {})  # type: ignore[arg-type]

    def __exit__(self, exc_type, exc, tb) -> None:
        self.seconds = time.perf_counter() - self._t0


def null_phase() -> PhaseSpan:
    """A fence-capable span that records nowhere — what phase-timer call
    sites use when no telemetry run is attached, keeping the disabled
    path allocation-trivial and sync-free (fence is never called on it
    by the built-in call sites)."""
    return _NullSpan()


class TelemetryRun:
    """One recorded run: manifest + event stream under ``run_dir``.

    Parameters
    ----------
    run_dir:  explicit directory for this run's artifacts; or
    root/run_id: ``<root>/<run_id>`` (``run_id`` defaults to a
              wall-clock + pid tag — pass one for reproducible paths).
    config:   free-form JSON-serializable run configuration, captured
              verbatim in the manifest (and in its fingerprint).
    seed:     the run's base RNG seed (manifest + fingerprint).
    profile:  opt-in ``jax.profiler`` tracing (``telemetry.profiler``).
    """

    def __init__(self, run_dir: str | None = None, *, root: str = "runs",
                 run_id: str | None = None, config: dict | None = None,
                 seed: int | None = None, profile: bool = False):
        if run_dir is None:
            if run_id is None:
                run_id = time.strftime("%Y%m%d-%H%M%S") + f"-{os.getpid()}"
            run_dir = os.path.join(root, run_id)
        self.run_dir = run_dir
        self.run_id = run_id or os.path.basename(os.path.normpath(run_dir))
        self.profile = bool(profile)
        self.events_path = os.path.join(run_dir, "events.jsonl")
        self.manifest_path = os.path.join(run_dir, "manifest.json")
        os.makedirs(run_dir, exist_ok=True)
        self._fh = open(self.events_path, "a", buffering=1)
        # Serializes appends: the lazy plane's prefetch worker emits its
        # staging phase span from a background thread while the main
        # thread streams round events.
        self._emit_lock = threading.Lock()
        self._counts: dict[str, int] = {}
        self._t_open = time.perf_counter()
        jx, pkgs = _environment()
        self.manifest: dict[str, Any] = {
            "schema_version": ev.SCHEMA_VERSION,
            "run_id": self.run_id,
            "created_unix": time.time(),
            "seed": seed,
            "config": config or {},
            "git_sha": _git_sha(),
            "jax": jx,
            "packages": pkgs,
            "events": "events.jsonl",
            "status": "open",
        }
        self.manifest["fingerprint"] = manifest_fingerprint(self.manifest)
        self._write_manifest()

    # -- manifest ---------------------------------------------------------
    def _write_manifest(self) -> None:
        atomic_write_json(self.manifest_path, self.manifest)

    def update_manifest(self, **fields) -> None:
        """Merge fields into the manifest and rewrite it atomically.
        ``config`` merges key-wise (late writers — e.g. run_simulation
        adding engine/rounds — extend rather than clobber), and the
        fingerprint is recomputed since config is part of it."""
        cfg = fields.pop("config", None)
        if cfg:
            self.manifest["config"] = {**self.manifest["config"], **cfg}
        self.manifest.update(fields)
        self.manifest["fingerprint"] = manifest_fingerprint(self.manifest)
        self._write_manifest()

    # -- event stream -----------------------------------------------------
    def emit(self, etype: str, **fields) -> None:
        """Append one typed event to ``events.jsonl``."""
        if self._fh.closed:
            raise ev.TelemetryError(
                f"telemetry run {self.run_id!r} is closed")
        line = ev.encode_event({"t": etype, **fields})
        with self._emit_lock:
            self._fh.write(line + "\n")
            self._counts[etype] = self._counts.get(etype, 0) + 1

    def round(self, metrics: dict) -> None:
        """One training round's ``round_metrics`` entry."""
        self.emit("round", **metrics)

    def visit(self, **fields) -> None:
        self.emit("visit", **fields)

    def snapshot(self, snap: dict) -> None:
        self.emit("snapshot", **snap)

    def counter(self, name: str, value) -> None:
        self.emit("counter", name=name, value=value)

    def phase(self, name: str, **meta) -> PhaseSpan:
        """A fenced phase-timer span (see :class:`PhaseSpan`):

        >>> with run.phase("scan_chunk", engine="scan") as sp:
        ...     state, stacked = trainer.run_chunk(state, sched)
        ...     sp.fence(stacked)
        """
        return PhaseSpan(self, name, meta)

    # -- console ----------------------------------------------------------
    def log(self, msg: str) -> None:
        """Route human-facing progress lines through the telemetry
        logger (stderr handler installed lazily so library users who
        configure logging themselves are not double-printed)."""
        telemetry_print(msg)

    # -- lifecycle --------------------------------------------------------
    def close(self, **fields) -> None:
        """Finalize: flush events, stamp status/wall time/event counts."""
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()
        self.update_manifest(
            status="finalized",
            wall_time_s=round(time.perf_counter() - self._t_open, 6),
            event_counts=dict(sorted(self._counts.items())),
            **fields)

    def __enter__(self) -> "TelemetryRun":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(**({"status": "failed"} if exc_type else {}))


def telemetry_print(msg: str) -> None:
    """Print via the ``repro.telemetry`` logger, installing a bare
    stderr handler on first use when the app configured none — the
    replacement for ad-hoc ``print()`` progress lines."""
    if not log.handlers and not logging.getLogger().handlers:
        h = logging.StreamHandler()
        h.setFormatter(logging.Formatter("%(message)s"))
        log.addHandler(h)
        log.setLevel(logging.INFO)
    log.info(msg)
