"""Atomic on-disk artifacts shared by telemetry runs and benchmarks.

Everything durable the repo writes — run manifests, ``BENCH_scaling.json``
rows, rendered reports — goes through :func:`atomic_write_text` /
:func:`atomic_write_json`: write to a temp file in the target directory,
fsync, then ``os.replace``, so an interrupted writer can never leave a
truncated artifact behind (readers see the old file or the new one,
nothing in between).

Benchmark rows additionally merge through :func:`merge_bench_rows`,
keyed by ``(name, n, K, engine)`` — partial benchmark runs update their
own rows without clobbering the rest of the trajectory file.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

#: identity of one benchmark row in BENCH_scaling.json
BENCH_ROW_KEY = ("name", "n", "K", "engine")


def atomic_write_text(path: str, text: str) -> str:
    """Durably replace ``path`` with ``text`` (temp file + os.replace)."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def atomic_write_json(path: str, obj: Any, *, indent: int = 1) -> str:
    return atomic_write_text(
        path, json.dumps(obj, indent=indent, sort_keys=False) + "\n")


def _row_key(row: dict) -> tuple:
    return tuple(row.get(k) for k in BENCH_ROW_KEY)


def merge_bench_rows(existing: list[dict], rows: list[dict]) -> list[dict]:
    """Merge ``rows`` into ``existing`` keyed by ``(name, n, K, engine)``
    (new rows win their own key; everything else is preserved), sorted
    by key for stable diffs."""
    merged = {_row_key(r): r for r in existing}
    for r in rows:
        merged[_row_key(r)] = r
    return [merged[k] for k in sorted(merged, key=lambda t: tuple(
        (v is None, v) for v in t))]


def load_bench_rows(path: str) -> list[dict]:
    """Rows currently in a bench trajectory file ([] when absent)."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)
