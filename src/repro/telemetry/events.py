"""Typed telemetry event schema: one JSONL line per event.

Every event is a flat JSON object with a ``t`` discriminator naming its
type plus that type's required fields (free-form extras ride along).
The same schema serves training runs (``run_simulation``), the fleet
driver, the FedAvg-family baselines, and the benchmark harness, so one
report CLI can read any artifact under ``runs/``.

Event types
-----------
``round``    — one training/communication round: ``round`` plus whatever
               the trainer's ``round_metrics`` entry carries
               (``train_loss``, ``comm_bytes``, ``latency_s``, …).
``visit``    — one walker visit in the walk/zone trace stream:
               ``round``, ``client``; optionally ``walker``, ``zone``,
               ``n_i``, ``iw``, ``staleness_p50``/``staleness_max``,
               ``latency_s``/``energy_j`` (CommModel columns).
``snapshot`` — one evaluation snapshot: ``round`` plus the eval dict
               (``acc``, ``acc_personalized``, ``comm_bytes_total``, …).
``phase``    — one fenced phase-timer span: ``name``, ``seconds``;
               optionally ``round``, ``engine``, ``includes_compile``.
``counter``  — one named scalar: ``name``, ``value`` (totals, config
               echoes, benchmark readings).
"""
from __future__ import annotations

import json
from typing import Any, Iterable, Iterator

SCHEMA_VERSION = 1

#: required keys per event type (beyond the ``t`` discriminator)
EVENT_TYPES: dict[str, tuple[str, ...]] = {
    "round": ("round",),
    "visit": ("round", "client"),
    "snapshot": ("round",),
    "phase": ("name", "seconds"),
    "counter": ("name", "value"),
}


class TelemetryError(ValueError):
    """Malformed event or artifact."""


def _json_default(o: Any):
    """Serialize numpy scalars/arrays without importing numpy eagerly."""
    if hasattr(o, "item") and callable(o.item) and getattr(
            o, "ndim", None) == 0:
        return o.item()
    if hasattr(o, "tolist") and callable(o.tolist):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def validate_event(event: dict) -> dict:
    """Check the discriminator and required fields; return the event."""
    etype = event.get("t")
    if etype not in EVENT_TYPES:
        raise TelemetryError(
            f"unknown event type {etype!r}; expected one of "
            f"{sorted(EVENT_TYPES)}")
    missing = [k for k in EVENT_TYPES[etype] if k not in event]
    if missing:
        raise TelemetryError(
            f"{etype!r} event missing required field(s) {missing}: "
            f"{sorted(event)}")
    return event


def encode_event(event: dict) -> str:
    """One JSONL line (validated, compact separators, sorted keys so a
    fixed-seed run writes byte-identical event streams)."""
    validate_event(event)
    return json.dumps(event, separators=(",", ":"), sort_keys=True,
                      default=_json_default)


def read_events(path: str) -> Iterator[dict]:
    """Stream events back from a JSONL file, re-validating each line."""
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                event = json.loads(line)
            except json.JSONDecodeError as e:
                raise TelemetryError(
                    f"{path}:{lineno}: bad JSON: {e}") from e
            yield validate_event(event)


def split_by_type(events: Iterable[dict]) -> dict[str, list[dict]]:
    """Bucket an event stream by type (missing types → empty lists)."""
    out: dict[str, list[dict]] = {t: [] for t in EVENT_TYPES}
    for e in events:
        out[e["t"]].append(e)
    return out
