"""``python -m repro.analysis`` — alias for the check CLI."""
import sys

from .check import main

sys.exit(main())
