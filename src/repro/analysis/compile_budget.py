"""Layer 3: compile-budget sentinel.

XLA compilations are the dominant fixed cost of the smoke sweeps, and a
silent retrace (a closure rebuilt per call, a python float leaking into
a traced signature, a cache keyed on the wrong tuple) multiplies them
without failing any numeric test. The sentinel runs a FIXED tiny sweep
(``registry.SMOKE`` × eager/scan/scan_fused) under a compile-event
listener and compares the per-closure distinct-compilation counts to a
golden manifest (``analysis/compile_budget.json``). Any drift — up OR
down — fails, so both regressions and stale manifests surface.

Counting mechanism: jax's dispatch layer logs one
``Finished XLA compilation of jit(<name>) in <secs> sec`` line per
actual backend compile on the ``jax._src.dispatch`` logger. A handler
parses the closure name out of each line; ambient tiny-op compiles
(``jit(broadcast_in_dim)`` warm-up noise that varies with process
history) are filtered out by keeping only the step/driver closure names
the trainers own.
"""
from __future__ import annotations

import collections
import contextlib
import json
import logging
import re
from pathlib import Path
from typing import Iterator, Sequence

_COMPILE_RE = re.compile(
    r"Finished (?:XLA |jaxpr to MLIR module )?"
    r"(?:compilation|conversion) of jit\((?P<name>[^)]*)\)")

#: closure names the trainers own — everything else (ambient jnp-op
#: compiles, eval closures) is noise for the budget
_INTERESTING = re.compile(r"^(chunk|_round_impl|_rr_step_impl|"
                          r"_sim_step_impl|round_impl)")

_LOGGER_NAME = "jax._src.dispatch"


class _CompileCounter(logging.Handler):
    def __init__(self, counts: collections.Counter):
        super().__init__(level=logging.DEBUG)
        self.counts = counts

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILE_RE.match(record.getMessage())
        if m and "compilation" in record.getMessage():
            self.counts[m.group("name")] += 1


@contextlib.contextmanager
def compile_log() -> Iterator[collections.Counter]:
    """Count XLA compilations by jitted-closure name inside the block."""
    counts: collections.Counter = collections.Counter()
    handler = _CompileCounter(counts)
    logger = logging.getLogger(_LOGGER_NAME)
    old_level, old_prop = logger.level, logger.propagate
    logger.addHandler(handler)
    logger.setLevel(logging.DEBUG)
    logger.propagate = False      # keep DEBUG spew off the root logger
    try:
        yield counts
    finally:
        logger.removeHandler(handler)
        logger.setLevel(old_level)
        logger.propagate = old_prop


def _filter(counts: collections.Counter) -> dict[str, int]:
    return {k: int(v) for k, v in sorted(counts.items())
            if _INTERESTING.match(k)}


def measure_budget(engines: Sequence[str] = ("eager", "scan",
                                             "scan_fused"),
                   ) -> dict[str, int]:
    """Run the fixed smoke sweep cold and return per-closure distinct
    compile counts. Trainers are built fresh inside, so the counts are
    deterministic regardless of what the process compiled before."""
    from .registry import SMOKE, run_cell

    with compile_log() as counts:
        for spec in SMOKE:
            run_cell(spec, engines)
    return _filter(counts)


def compare_budget(measured: dict[str, int], golden: dict[str, int]
                   ) -> list[str]:
    """Human-readable drift lines; empty means the budget holds."""
    problems = []
    for name in sorted(set(measured) | set(golden)):
        got, want = measured.get(name, 0), golden.get(name, 0)
        if got > want:
            problems.append(
                f"{name}: {got} compilations (golden {want}) — retrace "
                "or cache-key regression")
        elif got < want:
            problems.append(
                f"{name}: {got} compilations (golden {want}) — sweep "
                "shrank; refresh analysis/compile_budget.json")
    return problems


def load_golden(path: str | Path) -> dict[str, int]:
    data = json.loads(Path(path).read_text())
    return {str(k): int(v) for k, v in data["compilations"].items()}


def write_golden(path: str | Path, measured: dict[str, int]) -> None:
    payload = {
        "comment": "Golden distinct-XLA-compilation counts for the "
                   "fixed smoke sweep (repro.analysis.compile_budget). "
                   "Regenerate with python -m repro.analysis.check "
                   "--write-budget.",
        "sweep": "registry.SMOKE x (eager, scan, scan_fused)",
        "compilations": dict(sorted(measured.items())),
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")
