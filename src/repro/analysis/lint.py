"""AST lint engine: file discovery, rule execution, suppressions.

Suppression syntax (checked by tests):

* ``# repro: allow(rule-a, rule-b)`` on the offending line — or on a
  comment-only line directly above it — suppresses those rules there.
  A suppression MUST carry a justification after a ``--``::

      x = np.asarray(v)  # repro: allow(host-sync-in-jit) -- host path

  (the justification is free text; its presence is enforced so every
  baseline carries its own "why").
* ``# repro: allow-file(rule-a)`` anywhere in the first 20 lines
  suppresses a rule for the whole file (same ``--`` rule).

Suppressions that fire are collected (they become part of
``analysis/baseline.json``); suppressions that match nothing are
reported as ``unused-suppression`` findings so stale allows rot away.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, Sequence

from .findings import Finding
from .rules import ALL_RULES, ModuleContext, Rule

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow(?P<scope>-file)?\(\s*(?P<rules>[a-z0-9_,\s-]+)\)"
    r"(?P<just>\s*--\s*\S.*)?")

#: directories never linted (fixtures live inline in tests; runs/ is
#: generated output)
_SKIP_PARTS = {"__pycache__", ".git", "runs", ".claude"}


@dataclasses.dataclass(frozen=True)
class Suppression:
    path: str
    line: int            # line the comment sits on
    rules: tuple[str, ...]
    file_wide: bool
    justified: bool


def parse_suppressions(path: str, source: str) -> list[Suppression]:
    # Real COMMENT tokens only — the allow() syntax inside docstrings
    # (docs, this module) must not register as live suppressions.
    out = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except tokenize.TokenizeError:  # unparseable: no suppressions
        return out
    for i, text in comments:
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        out.append(Suppression(
            path=path, line=i, rules=rules,
            file_wide=m.group("scope") == "-file",
            justified=m.group("just") is not None))
    return out


class LintEngine:
    def __init__(self, rules: Sequence[Rule] = ALL_RULES,
                 *, root: Path | None = None):
        self.rules = tuple(rules)
        self.root = Path(root) if root is not None else Path.cwd()

    # -- file discovery -----------------------------------------------
    def iter_files(self, paths: Iterable[str | Path]):
        for p in paths:
            p = Path(p)
            if p.is_file() and p.suffix == ".py":
                yield p
            elif p.is_dir():
                for f in sorted(p.rglob("*.py")):
                    if not _SKIP_PARTS.intersection(f.parts):
                        yield f

    # -- one file ------------------------------------------------------
    def lint_source(self, source: str, path: str) -> list[Finding]:
        """Run every rule on one module's source; apply suppressions."""
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            return [Finding(rule="syntax-error", path=path,
                            line=e.lineno or 1, col=(e.offset or 1) - 1,
                            message=f"cannot parse: {e.msg}",
                            snippet=(e.text or "").strip())]
        ctx = ModuleContext(path, source, tree)
        raw: list[Finding] = []
        for rule in self.rules:
            raw.extend(rule.check(ctx))
        # Dedupe (overlapping reachable subtrees can double-report).
        raw = sorted(set(raw), key=lambda f: (f.line, f.col, f.rule))

        sups = parse_suppressions(path, source)
        file_wide = {r for s in sups if s.file_wide and s.line <= 20
                     for r in s.rules}
        by_line: dict[tuple[int, str], Suppression] = {}
        for s in sups:
            if s.file_wide:
                continue
            for r in s.rules:
                # a same-line allow also covers the next line, so a
                # comment-only line can precede the offending statement
                by_line[(s.line, r)] = s
                by_line[(s.line + 1, r)] = s

        used: set[tuple[str, int, tuple[str, ...]]] = set()
        kept: list[Finding] = []
        unjustified: list[Finding] = []
        for f in raw:
            sup = by_line.get((f.line, f.rule))
            if f.rule in file_wide or sup is not None:
                if sup is not None:
                    used.add((sup.path, sup.line, sup.rules))
                    if not sup.justified:
                        unjustified.append(Finding(
                            rule="unjustified-suppression", path=path,
                            line=sup.line, col=0,
                            message=(f"allow({f.rule}) needs a '-- why'"
                                     " justification"),
                            snippet=f.snippet))
                continue
            kept.append(f)
        kept.extend(unjustified)
        for s in sups:
            if s.file_wide:
                if not s.justified:
                    kept.append(Finding(
                        rule="unjustified-suppression", path=path,
                        line=s.line, col=0,
                        message="allow-file(...) needs a '-- why' "
                                "justification", snippet=""))
                continue
            if (s.path, s.line, s.rules) not in used:
                kept.append(Finding(
                    rule="unused-suppression", path=path, line=s.line,
                    col=0,
                    message=(f"suppression for {', '.join(s.rules)} "
                             "matches no finding; remove it"),
                    snippet=""))
        return sorted(kept, key=lambda f: (f.line, f.col, f.rule))

    def lint_file(self, path: Path) -> list[Finding]:
        rel = path.resolve()
        try:
            rel = rel.relative_to(self.root.resolve())
        except ValueError:
            pass
        return self.lint_source(path.read_text(), rel.as_posix())

    def run(self, paths: Iterable[str | Path]) -> list[Finding]:
        findings: list[Finding] = []
        for f in self.iter_files(paths):
            findings.extend(self.lint_file(f))
        return findings

    def suppression_inventory(self, paths: Iterable[str | Path]
                              ) -> list[dict]:
        """Every active suppression (the baselined-violation ledger)."""
        out = []
        for f in self.iter_files(paths):
            rel = f.resolve()
            try:
                rel = rel.relative_to(self.root.resolve())
            except ValueError:
                pass
            for s in parse_suppressions(rel.as_posix(), f.read_text()):
                out.append({"path": s.path, "line": s.line,
                            "rules": list(s.rules),
                            "file_wide": s.file_wide})
        return out


def lint_paths(paths: Iterable[str | Path], *,
               root: Path | None = None) -> list[Finding]:
    return LintEngine(root=root).run(paths)
