"""Layer 2: jaxpr auditor for the registered jitted step closures.

Every step closure the trainers register (``TrainerBase.capture_jitted``
records ``(name, fn, args, kwargs)`` at the exact call sites) is traced
— NOT re-executed — and its jaxpr checked for the compiled-path
invariants the repo pins elsewhere by behaviour:

* **no-float64-op** — no equation output is float64/complex128 (the
  whole stack is float32; a stray f64 silently doubles bandwidth and
  breaks the bit-identity pins).
* **baked-constant** — closure constants stay under a per-closure byte
  budget. The dense client plane deliberately bakes the dataset (its
  budget is the dataset size + slack); the lazy plane must NOT (its
  budget is far below the store's packed-data size), which is the
  traced-not-baked invariant the lazy-plane PR established.
* **callback-in-jit** — no ``debug_callback`` / ``pure_callback`` /
  ``io_callback`` primitives survive into the step jaxprs (leftover
  ``jax.debug.print`` forces host syncs every round).
* **donation-mismatch** — the sharded chunk path must actually donate
  its carry (``donate_argnums=(0,)`` shows up as ``tf.aliasing_output``
  in the lowered StableHLO); the unsharded path must not.

``fn.trace(*args).jaxpr`` is used instead of ``jax.make_jaxpr`` because
only the former exposes the closure constants (``.consts``) — wrapping
a jitted fn in ``make_jaxpr`` yields one opaque ``pjit`` equation with
an empty const list.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Iterator

import numpy as np

from .findings import Finding

#: primitives that escape to the host from inside a compiled step
_CALLBACK_PRIMS = {
    "debug_callback", "pure_callback", "io_callback", "callback",
    "debug_print",
}

_WIDE_DTYPES = {"float64", "complex128"}

#: default const budget for closures that must not bake bulk data
DEFAULT_CONST_BUDGET = 256 * 1024


@dataclasses.dataclass
class ClosureAudit:
    """Result of auditing one captured closure."""
    name: str
    n_eqns: int
    const_bytes: int
    const_budget: int
    donated: bool | None      # None: donation not checked for this one
    findings: list[Finding]

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "n_eqns": self.n_eqns,
            "const_bytes": self.const_bytes,
            "const_budget": self.const_budget,
            "donated": self.donated,
            "findings": [f.to_dict() for f in self.findings],
        }


def _sub_jaxprs(value: Any) -> Iterator[Any]:
    """Yield jaxprs hiding inside an eqn param (scan/cond bodies…)."""
    if hasattr(value, "eqns"):                       # Jaxpr
        yield value
    elif hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr                            # ClosedJaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def iter_eqns(jaxpr) -> Iterator[Any]:
    """All equations, recursing into sub-jaxprs (scan bodies etc.)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from iter_eqns(sub)


def _const_nbytes(consts: Iterable[Any]) -> int:
    total = 0
    for c in consts:
        nbytes = getattr(c, "nbytes", None)
        if nbytes is None:
            try:
                nbytes = np.asarray(c).nbytes
            except Exception:
                nbytes = 0
        total += int(nbytes)
    return total


def audit_closure(name: str, fn, args, kwargs=None, *,
                  const_budget: int = DEFAULT_CONST_BUDGET,
                  expect_donation: bool | None = None) -> ClosureAudit:
    """Trace one captured jitted closure and check the invariants.

    ``expect_donation`` — ``True``/``False`` asserts the lowered module
    does / does not alias an input to an output; ``None`` skips the
    (more expensive) lowering entirely.
    """
    kwargs = dict(kwargs or {})
    path = f"<jaxpr:{name}>"
    findings: list[Finding] = []

    closed = fn.trace(*args, **kwargs).jaxpr        # ClosedJaxpr
    const_bytes = _const_nbytes(closed.consts)
    if const_bytes > const_budget:
        findings.append(Finding(
            rule="baked-constant", path=path, line=1, col=0,
            message=(f"{const_bytes} bytes of closure constants exceed "
                     f"the {const_budget}-byte budget — bulk data must "
                     "enter as a traced argument, not a baked const"),
            snippet=name))

    n_eqns = 0
    wide_seen: set[str] = set()
    callback_seen: set[str] = set()
    for eqn in iter_eqns(closed.jaxpr):
        n_eqns += 1
        prim = eqn.primitive.name
        if prim in _CALLBACK_PRIMS and prim not in callback_seen:
            callback_seen.add(prim)
            findings.append(Finding(
                rule="callback-in-jit", path=path, line=1, col=0,
                message=(f"host-callback primitive '{prim}' in the "
                         "compiled step — remove leftover debugging / "
                         "host escapes"),
                snippet=f"{name}:{prim}"))
        for var in eqn.outvars:
            dtype = getattr(getattr(var, "aval", None), "dtype", None)
            dname = getattr(dtype, "name", None)
            if dname in _WIDE_DTYPES and prim not in wide_seen:
                wide_seen.add(prim)
                findings.append(Finding(
                    rule="float64-op", path=path, line=1, col=0,
                    message=(f"'{prim}' produces {dname} — the step "
                             "closures are pinned to float32"),
                    snippet=f"{name}:{prim}"))

    donated: bool | None = None
    if expect_donation is not None:
        text = fn.lower(*args, **kwargs).as_text()
        donated = "tf.aliasing_output" in text
        if donated != expect_donation:
            what = ("carry not donated on the sharded path (resident "
                    "state doubles per chunk)" if expect_donation else
                    "unexpected donation on the default path (input "
                    "states must stay alive)")
            findings.append(Finding(
                rule="donation-mismatch", path=path, line=1, col=0,
                message=what, snippet=name))

    return ClosureAudit(name=name, n_eqns=n_eqns,
                        const_bytes=const_bytes,
                        const_budget=const_budget, donated=donated,
                        findings=findings)
