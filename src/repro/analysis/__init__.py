"""Repo-wide static analysis: lint rules, jaxpr audits, compile budget.

Three layers, one CLI (``python -m repro.analysis.check``):

1. **AST lint** (`lint.py` / `rules.py`) — repo-specific invariant
   rules the generic linters can't express: ambient ``np.random``
   calls, unseeded generators, JAX PRNG key reuse, host syncs inside
   jit-reachable functions, Python branches on traced values, leftover
   ``jax.debug`` calls, mutable default arguments. Suppress a finding
   inline with ``# repro: allow(<rule>)``.
2. **jaxpr audit** (`jaxpr_audit.py` / `registry.py`) — traces every
   registered jitted step closure across the real trainer matrix
   (single/fleet x eager/scan/scan_fused x dense/lazy x sharded) and
   walks the jaxprs: no float64 ops, no baked-in constants above the
   per-closure byte budget, donation applied on the sharded path, no
   callback primitives in hot paths.
3. **compile-budget sentinel** (`compile_budget.py`) — runs the smoke
   sweep under JAX's compile logging and asserts the per-closure
   distinct-compilation counts match ``analysis/compile_budget.json``.

See ``docs/static_analysis.md`` for the rule catalog and workflows.
"""
from .findings import Finding
from .jaxpr_audit import ClosureAudit, audit_closure
from .lint import LintEngine, lint_paths
from .rules import ALL_RULES

__all__ = ["Finding", "LintEngine", "lint_paths", "ALL_RULES",
           "ClosureAudit", "audit_closure"]
