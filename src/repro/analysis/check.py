"""``python -m repro.analysis.check`` — the repo's invariant gate.

Runs the three analyzer layers (plus ruff, when installed) and exits
non-zero on any unbaselined problem:

1. **lint** — the AST rules (``repro.analysis.rules``) over the repo's
   Python surface; findings whose churn-stable fingerprints appear in
   ``analysis/baseline.json`` are tolerated (the baseline ships empty —
   it exists so a future grandfathered finding is an explicit artifact,
   not a silent allow).
2. **audit** — jaxpr invariants over every registered jitted step
   closure across the trainer × engine × plane × sharding matrix
   (``repro.analysis.registry``).
3. **budget** — distinct-XLA-compilation counts for the fixed smoke
   sweep vs the golden ``analysis/compile_budget.json``.

Flags: ``--json`` machine output; ``--skip-lint/--skip-audit/
--skip-budget`` to run a subset (CI's fast lane runs lint only);
``--write-baseline`` / ``--write-budget`` regenerate the artifacts;
``--paths`` overrides the linted roots.
"""
from __future__ import annotations

import argparse
import json
import shutil
import subprocess
import sys
from pathlib import Path

from .lint import LintEngine

#: repo root = parents[3] of src/repro/analysis/check.py
REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")
BASELINE_PATH = REPO_ROOT / "analysis" / "baseline.json"
BUDGET_PATH = REPO_ROOT / "analysis" / "compile_budget.json"


def _load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {str(f["fingerprint"]) for f in data.get("findings", [])}


def run_lint(paths, baseline: set[str]):
    engine = LintEngine(root=REPO_ROOT)
    findings = engine.run(paths)
    new = [f for f in findings if f.fingerprint not in baseline]
    baselined = len(findings) - len(new)
    return new, baselined, engine


def run_ruff(paths) -> dict:
    """Optional layer 0: ruff with the repo config, when installed.

    The pinned dev environment (requirements-dev.txt) carries ruff; a
    bare container without it degrades to a visible skip, never a pass
    masquerading as clean.
    """
    exe = shutil.which("ruff")
    if exe is None:
        return {"status": "skipped", "detail": "ruff not installed "
                "(pip install -r requirements-dev.txt)"}
    proc = subprocess.run(
        [exe, "check", *[str(p) for p in paths]],
        cwd=REPO_ROOT, capture_output=True, text=True)
    out = (proc.stdout + proc.stderr).strip()
    return {"status": "ok" if proc.returncode == 0 else "failed",
            "detail": out[-4000:]}


def run_audit():
    from .registry import audit_matrix
    reports = audit_matrix()
    findings = [f for r in reports for f in r.findings]
    return findings, reports


def run_budget():
    from .compile_budget import compare_budget, load_golden, \
        measure_budget
    measured = measure_budget()
    if not BUDGET_PATH.exists():
        return measured, [f"golden manifest missing: {BUDGET_PATH} "
                          "(run --write-budget)"]
    return measured, compare_budget(measured, load_golden(BUDGET_PATH))


def write_baseline(engine: LintEngine, findings, paths) -> None:
    BASELINE_PATH.parent.mkdir(exist_ok=True)
    payload = {
        "comment": "Machine-readable clean-run artifact for "
                   "repro.analysis. 'findings' fingerprints are "
                   "tolerated by the lint gate (grandfathered "
                   "violations — keep this empty); 'suppressions' "
                   "inventories every inline '# repro: allow' so the "
                   "baselined-violation ledger lives in one place.",
        "findings": [f.to_dict() for f in findings],
        "suppressions": engine.suppression_inventory(paths),
    }
    BASELINE_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="repo-wide JAX invariant analyzer")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--baseline", type=Path, default=BASELINE_PATH)
    ap.add_argument("--paths", nargs="*", default=None,
                    help="roots to lint (default: src tests benchmarks "
                         "examples)")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-ruff", action="store_true")
    ap.add_argument("--skip-audit", action="store_true")
    ap.add_argument("--skip-budget", action="store_true")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate analysis/baseline.json from the "
                         "current lint run")
    ap.add_argument("--write-budget", action="store_true",
                    help="regenerate analysis/compile_budget.json from "
                         "a fresh smoke sweep")
    args = ap.parse_args(argv)

    paths = [REPO_ROOT / p for p in (args.paths or DEFAULT_PATHS)]
    paths = [p for p in paths if p.exists()]
    report: dict = {}
    failed = False

    if not args.skip_lint:
        new, baselined, engine = run_lint(paths, _load_baseline(
            args.baseline))
        report["lint"] = {
            "new_findings": [f.to_dict() for f in new],
            "baselined": baselined,
        }
        if args.write_baseline:
            write_baseline(engine, new, paths)
            report["lint"]["baseline_written"] = str(BASELINE_PATH)
            new = []
        if new:
            failed = True

    if not args.skip_ruff:
        report["ruff"] = run_ruff(paths)
        if report["ruff"]["status"] == "failed":
            failed = True

    if not args.skip_audit:
        findings, reports = run_audit()
        report["audit"] = {
            "closures": len(reports),
            "findings": [f.to_dict() for f in findings],
            "summary": [{"name": r.name, "n_eqns": r.n_eqns,
                         "const_bytes": r.const_bytes,
                         "donated": r.donated} for r in reports],
        }
        if findings:
            failed = True

    if not args.skip_budget:
        from .compile_budget import write_golden
        measured, problems = run_budget()
        if args.write_budget:
            BUDGET_PATH.parent.mkdir(exist_ok=True)
            write_golden(BUDGET_PATH, measured)
            problems = []
            report.setdefault("budget", {})["golden_written"] = \
                str(BUDGET_PATH)
        report.setdefault("budget", {}).update(
            {"measured": measured, "problems": problems})
        if problems:
            failed = True

    if args.as_json:
        print(json.dumps(report, indent=2))
    else:
        _render_text(report)
    return 1 if failed else 0


def _render_text(report: dict) -> None:
    if "lint" in report:
        lint = report["lint"]
        for f in lint["new_findings"]:
            print(f"{f['path']}:{f['line']}:{f['col'] + 1}: "
                  f"[{f['rule']}] {f['message']}")
        tol = f" ({lint['baselined']} baselined)" if lint["baselined"] \
            else ""
        print(f"lint: {len(lint['new_findings'])} new finding(s){tol}")
    if "ruff" in report:
        r = report["ruff"]
        print(f"ruff: {r['status']}"
              + (f" — {r['detail']}" if r["status"] != "ok" else ""))
    if "audit" in report:
        a = report["audit"]
        for f in a["findings"]:
            print(f"{f['path']}: [{f['rule']}] {f['message']}")
        print(f"audit: {len(a['findings'])} finding(s) across "
              f"{a['closures']} closures")
    if "budget" in report:
        b = report["budget"]
        for p in b.get("problems", []):
            print(f"budget: {p}")
        print(f"budget: measured {b.get('measured')}"
              + (" [golden refreshed]" if "golden_written" in b else ""))


if __name__ == "__main__":
    sys.exit(main())
