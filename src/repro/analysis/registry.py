"""Layer 2 registry: the trainer × engine × plane × sharding matrix.

The jaxpr auditor needs *live* closures with their exact traced call
signatures — the trainers build them lazily and cache them, so the only
faithful way to enumerate "every registered jitted step closure" is to
run a tiny workload with capture armed (``TrainerBase.capture_jitted``)
and collect what the drivers actually called.

The matrix mirrors the pinned test surface:

* trainer:  single ``RWSADMMTrainer`` / ``FleetRWSADMMTrainer``
  (round-robin), plus one simultaneous-fleet cell so ``_sim_step_impl``
  is covered;
* engine:   ``eager`` (the per-round jitted step) and the compiled
  ``scan`` / ``scan_fused`` chunk drivers;
* plane:    ``dense`` (dataset baked as closure const — deliberate) and
  ``lazy`` (ClientStore data enters as a traced argument — enforced by
  the baked-constant budget);
* sharding: unsharded and a 1-device ``FLSharding`` mesh (the in-process
  sharded-path pin from the sharded-plane tests) — the sharded chunk
  must donate its carry, the unsharded one must not.

Workloads are deliberately tiny (8 clients, 400 MNIST-synthetic rows)
so the whole sweep stays in CI-smoke territory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Sequence

import jax
import numpy as np

from .jaxpr_audit import DEFAULT_CONST_BUDGET, ClosureAudit, audit_closure

N_CLIENTS = 8
EAGER_ROUNDS = 2
CHUNK_ROUNDS = 3
SCAN_ENGINES = ("scan", "scan_fused")

#: slack on top of the measured dense-plane bytes (model params, masks,
#: schedule constants…)
_CONST_SLACK = 256 * 1024


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One cell of the audit matrix."""
    trainer: str          # "single" | "fleet" | "fleet_sim"
    plane: str            # "dense" | "lazy"
    sharded: bool

    @property
    def key(self) -> str:
        shard = "sharded" if self.sharded else "unsharded"
        return f"{self.trainer}/{self.plane}/{shard}"


#: the full audited matrix (fleet_sim covers _sim_step_impl once)
MATRIX: tuple[CellSpec, ...] = tuple(
    CellSpec(trainer, plane, sharded)
    for trainer in ("single", "fleet")
    for plane in ("dense", "lazy")
    for sharded in (False, True)
) + (CellSpec("fleet_sim", "dense", False),)

#: the compile-budget smoke subset (Layer 3) — fixed forever so the
#: golden counts in analysis/compile_budget.json stay comparable
SMOKE: tuple[CellSpec, ...] = (
    CellSpec("single", "dense", False),
    CellSpec("single", "lazy", False),
    CellSpec("fleet", "dense", False),
)


@dataclasses.dataclass
class CapturedClosure:
    """One jitted step call recorded by a trainer, audit-ready."""
    cell: str
    engine: str
    name: str             # trainer-side label, e.g. "chunk:scan"
    fn: object
    args: tuple
    kwargs: dict
    const_budget: int
    expect_donation: bool | None

    @property
    def key(self) -> str:
        return f"{self.cell}/{self.engine}/{self.name}"

    def audit(self) -> ClosureAudit:
        report = audit_closure(
            self.name, self.fn, self.args, self.kwargs,
            const_budget=self.const_budget,
            expect_donation=self.expect_donation)
        report.name = self.key
        for i, f in enumerate(report.findings):
            report.findings[i] = dataclasses.replace(
                f, path=f"<jaxpr:{self.key}>")
        return report


@functools.lru_cache(maxsize=1)
def _workload():
    """The shared tiny federated workload (built once per process)."""
    from repro.data import (factory_from_federated, make_image_dataset,
                            pathological_split)
    from repro.data.loader import build_federated
    from repro.fl.base import to_device_data
    from repro.models.small import get_model

    imgs, labels = make_image_dataset(400, seed=0)
    parts = pathological_split(labels, N_CLIENTS, seed=0)
    fed = build_federated(imgs, labels, parts)
    dense = to_device_data(fed)
    factory = factory_from_federated(fed)
    model = get_model("mlr", (28, 28, 1))
    return dense, factory, model


def _tree_nbytes(tree) -> int:
    return int(sum(np.asarray(leaf).nbytes
                   for leaf in jax.tree_util.tree_leaves(tree)))


def build_cell(spec: CellSpec):
    """Construct the trainer for one matrix cell (fresh every call —
    the compile-budget sentinel depends on cold jit caches)."""
    import dataclasses as _dc

    from repro.core.rwsadmm import RWSADMMHparams
    from repro.fl.fleet_trainer import FleetRWSADMMTrainer
    from repro.fl.rwsadmm_trainer import RWSADMMTrainer
    from repro.fl.sharding import FLSharding
    from repro.scenarios import get_scenario_config

    dense, factory, model = _workload()
    scen = _dc.replace(get_scenario_config("lossy_links"),
                       graph_backend="dense",
                       neighbor_k_max=N_CLIENTS)
    kw = dict(zone_size=4, batch_size=16, solver="closed_form",
              scenario=scen, seed=0,
              mesh=FLSharding() if spec.sharded else None)
    lazy = spec.plane == "lazy"
    data = factory if lazy else dense
    if lazy:
        kw["store_capacity"] = N_CLIENTS
    hp = RWSADMMHparams(beta=10.0)
    if spec.trainer == "single":
        return RWSADMMTrainer(model, data, hp, **kw)
    mode = "simultaneous" if spec.trainer == "fleet_sim" else "roundrobin"
    return FleetRWSADMMTrainer(model, data, hp, n_walkers=3,
                               sync_every=3, fleet_mode=mode, **kw)


def _const_budget(trainer, spec: CellSpec) -> int:
    """Per-closure const byte budget: the dense plane deliberately bakes
    the dataset, so its budget is the measured data size plus slack; the
    lazy plane's data is a traced argument, so anything near the store's
    packed bytes in the consts means it leaked back in."""
    if spec.plane == "dense":
        return _tree_nbytes(trainer.data) + _CONST_SLACK
    return DEFAULT_CONST_BUDGET


def run_cell(spec: CellSpec,
             engines: Sequence[str] = ("eager",) + SCAN_ENGINES,
             ) -> list[CapturedClosure]:
    """Run one cell's tiny workload with capture armed; return every
    jitted step call the drivers made, audit-ready."""
    captured: list[CapturedClosure] = []
    trainer = build_cell(spec)
    budget = _const_budget(trainer, spec)

    for engine in engines:
        # Fresh state per engine: the sharded chunk donates its carry,
        # so a state that went through one chunk is already consumed.
        state = trainer.init_state(jax.random.PRNGKey(0))
        with trainer.capture_jitted() as entries:
            if engine == "eager":
                rng = np.random.default_rng(0)
                s = state
                for rnd in range(EAGER_ROUNDS):
                    s, _ = trainer.round(s, rnd, rng)
            else:
                rng = np.random.default_rng(1)
                sched = trainer.schedule(CHUNK_ROUNDS, rng)
                trainer.run_chunk(state, sched, engine=engine)
        seen: set[str] = set()
        for name, fn, args, kwargs in entries:
            if name in seen:          # eager records one call per round
                continue
            seen.add(name)
            # Donation is asserted on the chunk drivers only (the eager
            # step is never donated); sharded ⇒ donated, else not.
            expect = spec.sharded if name.startswith("chunk") else None
            captured.append(CapturedClosure(
                cell=spec.key, engine=engine, name=name, fn=fn,
                args=args, kwargs=kwargs, const_budget=budget,
                expect_donation=expect))
    return captured


def collect_closures(cells: Iterable[CellSpec] = MATRIX,
                     engines: Sequence[str] = ("eager",) + SCAN_ENGINES,
                     ) -> list[CapturedClosure]:
    out: list[CapturedClosure] = []
    for spec in cells:
        out.extend(run_cell(spec, engines))
    return out


def audit_matrix(cells: Iterable[CellSpec] = MATRIX,
                 engines: Sequence[str] = ("eager",) + SCAN_ENGINES,
                 ) -> list[ClosureAudit]:
    return [c.audit() for c in collect_closures(cells, engines)]
