"""Finding: one analyzer hit, with a churn-stable fingerprint.

Fingerprints hash (rule, repo-relative path, whitespace-normalized
source line) — NOT the line number — so a baseline survives unrelated
edits above a finding and diffs stay meaningful across PRs.
"""
from __future__ import annotations

import dataclasses
import hashlib


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # rule name, e.g. "ambient-np-random"
    path: str          # repo-relative posix path
    line: int          # 1-based
    col: int           # 0-based
    message: str
    snippet: str = ""  # the offending source line, stripped

    @property
    def fingerprint(self) -> str:
        norm = " ".join(self.snippet.split())
        digest = hashlib.sha256(
            f"{self.rule}|{self.path}|{norm}".encode()).hexdigest()
        return digest[:16]

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "snippet": self.snippet,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"[{self.rule}] {self.message}")
