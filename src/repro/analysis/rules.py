"""Repo-specific AST lint rules.

Every rule here guards an invariant the test suite can only pin by
example: seeded-RNG-everywhere (reproducible trajectories), sync-free
jitted hot paths (eager == scan bit-identity and no hidden device
round-trips), and no leftover debug plumbing. Rules that generic
linters express natively (import hygiene, unused names) live in the
ruff config instead — see docs/static_analysis.md.

A rule sees one :class:`ModuleContext` (parsed tree + import-alias map
+ the jit-reachable function set) and yields :class:`Finding`s. The
engine applies ``# repro: allow(<rule>)`` suppressions afterwards.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from .findings import Finding

# ---------------------------------------------------------------------------
# Module context: parsed source + alias resolution + jit reachability.
# ---------------------------------------------------------------------------

#: transforms whose function argument runs traced (first positional arg)
_TRACING_ENTRYPOINTS = {
    "jax.jit", "jit",
    "jax.lax.scan", "lax.scan",
    "jax.vmap", "vmap",
    "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat",
    "jax.pmap",
}

#: jax.random constructors/derivations that do NOT consume a key
_KEY_NONCONSUMING = {
    "PRNGKey", "key", "fold_in", "key_data", "wrap_key_data", "clone",
    "split",  # split consumes, but tracked separately (it *retires* a key)
}

#: numpy.random attributes that are seeded-constructor machinery, not
#: ambient global-state sampling
_NP_RANDOM_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64",
}


def _attr_chain(node: ast.AST) -> str | None:
    """Dotted source text of a Name/Attribute chain ("np.random.rand")."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ModuleContext:
    """Everything the rules need to know about one parsed module."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.aliases = self._collect_aliases(tree)
        self._functions = [n for n in ast.walk(tree)
                           if isinstance(n, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))]
        self.jit_reachable = self._jit_reachable()

    # -- alias map ----------------------------------------------------
    @staticmethod
    def _collect_aliases(tree: ast.Module) -> dict[str, str]:
        """local name -> canonical dotted module/attribute path."""
        aliases: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}")
        return aliases

    def resolve(self, node: ast.AST) -> str | None:
        """Canonical dotted path of a call target / attribute chain,
        with the leading segment resolved through the import aliases
        ("jnp.asarray" -> "jax.numpy.asarray")."""
        chain = _attr_chain(node)
        if chain is None:
            return None
        head, _, rest = chain.partition(".")
        head = self.aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    # -- jit reachability ---------------------------------------------
    def _jit_reachable(self) -> set[ast.AST]:
        """Function nodes whose bodies run under trace: jit-decorated,
        passed to jax.jit / lax.scan / vmap / grad at some call site,
        or (transitively) called by such a function within this module.
        Nested defs are covered by walking the reachable subtrees."""
        by_name: dict[str, list[ast.AST]] = {}
        for fn in self._functions:
            by_name.setdefault(fn.name, []).append(fn)

        # Local function aliases: ``impl = self._rr_step_impl if cond
        # else self._sim_step_impl`` — map the variable name to every
        # known def its RHS references, so jit(partial(impl, ...)) and
        # calls through the alias still mark the real bodies.
        var_refs: dict[str, set[str]] = {}
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            refs = {(_attr_chain(sub) or "").rsplit(".", 1)[-1]
                    for sub in ast.walk(node.value)
                    if isinstance(sub, (ast.Name, ast.Attribute))}
            refs &= set(by_name)
            if refs:
                var_refs.setdefault(node.targets[0].id, set()).update(refs)

        def defs_for(name: str) -> list[ast.AST]:
            out = list(by_name.get(name, []))
            for ref in var_refs.get(name, ()):
                out.extend(by_name.get(ref, []))
            return out

        entries: set[ast.AST] = set()
        for fn in self._functions:
            for dec in fn.decorator_list:
                if self._is_tracing_transform(dec):
                    entries.add(fn)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            target = self.resolve(node.func)
            is_entry = target in _TRACING_ENTRYPOINTS
            is_partial_entry = (
                target in ("functools.partial", "partial") and node.args
                and self.resolve(node.args[0]) in _TRACING_ENTRYPOINTS)
            if not (is_entry or is_partial_entry):
                continue
            cands: Iterable[ast.AST] = (
                node.args[1:] if is_partial_entry else node.args)
            for arg in cands:
                resolved = self.resolve(arg)
                name = (resolved or "").rsplit(".", maxsplit=1)[-1]
                entries.update(defs_for(name))
                if isinstance(arg, ast.Lambda):
                    entries.add(arg)
                if (isinstance(arg, ast.Call)
                        and self.resolve(arg.func) in (
                            "functools.partial", "partial")
                        and arg.args):
                    inner = (self.resolve(arg.args[0]) or "")
                    entries.update(defs_for(inner.rsplit(".", 1)[-1]))

        # Transitive closure over same-module calls (bare name or
        # self.<method>), walking reachable subtrees.
        reachable = set(entries)
        frontier = list(entries)
        while frontier:
            fn = frontier.pop()
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = _attr_chain(node.func) or ""
                name = chain.rsplit(".", maxsplit=1)[-1]
                if chain in (name, f"self.{name}", f"cls.{name}"):
                    for cand in defs_for(name):
                        if cand not in reachable:
                            reachable.add(cand)
                            frontier.append(cand)
        return reachable

    @staticmethod
    def _is_tracing_transform(dec: ast.AST) -> bool:
        chain = _attr_chain(dec)
        if chain in _TRACING_ENTRYPOINTS:
            return True
        if isinstance(dec, ast.Call):
            target = _attr_chain(dec.func)
            if target in _TRACING_ENTRYPOINTS:
                return True
            if target in ("functools.partial", "partial") and dec.args:
                return _attr_chain(dec.args[0]) in _TRACING_ENTRYPOINTS
        return False

    # -- helpers -------------------------------------------------------
    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        snippet = (self.lines[line - 1].strip()
                   if 0 < line <= len(self.lines) else "")
        return Finding(rule=rule, path=self.path, line=line,
                       col=getattr(node, "col_offset", 0),
                       message=message, snippet=snippet)

    def reachable_subtrees(self) -> Iterator[ast.AST]:
        """Jit-reachable function nodes, outermost-first, with nested
        reachable functions pruned (their subtree is already covered)."""
        covered: set[ast.AST] = set()
        for fn in sorted(self.jit_reachable,
                         key=lambda n: (n.lineno, n.col_offset)):
            if fn in covered:
                continue
            for sub in ast.walk(fn):
                if sub is not fn and sub in self.jit_reachable:
                    covered.add(sub)
            yield fn


# ---------------------------------------------------------------------------
# Rules.
# ---------------------------------------------------------------------------

class Rule:
    name: str = ""
    description: str = ""

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        raise NotImplementedError  # pragma: no cover - interface


class AmbientNpRandomRule(Rule):
    """Ambient ``np.random.*`` sampling mutates hidden global state —
    one call anywhere desynchronizes every seeded trajectory pin in the
    repo. Only seeded ``Generator`` streams are allowed."""

    name = "ambient-np-random"
    description = ("ambient numpy.random global-state call; use a "
                   "seeded np.random.default_rng(seed) Generator")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.resolve(node.func) or ""
            if not target.startswith("numpy.random."):
                continue
            attr = target.removeprefix("numpy.random.").split(".")[0]
            if attr not in _NP_RANDOM_OK:
                yield ctx.finding(
                    self.name, node,
                    f"ambient numpy.random.{attr}() uses hidden global "
                    "RNG state; draw from a seeded default_rng stream")


class UnseededDefaultRngRule(Rule):
    """``default_rng()`` without a seed draws OS entropy — every run
    takes a different trajectory, which silently defeats the repo's
    bit-identity pins."""

    name = "unseeded-default-rng"
    description = "np.random.default_rng() without an explicit seed"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.resolve(node.func) or ""
            if not target.endswith("random.default_rng"):
                continue
            unseeded = (not node.args and not node.keywords) or (
                len(node.args) == 1 and isinstance(node.args[0],
                                                   ast.Constant)
                and node.args[0].value is None)
            if unseeded:
                yield ctx.finding(
                    self.name, node,
                    "default_rng() without a seed is entropy-seeded; "
                    "pass an explicit seed (or derived SeedSequence)")


class PrngKeyReuseRule(Rule):
    """A JAX PRNG key consumed twice yields correlated randomness: two
    samplers see identical bits. Straight-line double consumption of
    the same key name (without re-binding via split/fold_in) is flagged.
    """

    name = "prng-key-reuse"
    description = ("jax.random key consumed twice without split/fold_in"
                   " between uses")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn in ctx._functions:
            yield from self._check_fn(ctx, fn)

    def _check_fn(self, ctx: ModuleContext, fn) -> Iterator[Finding]:
        # Linear event stream in source order; loop bodies are skipped
        # (per-iteration derivation is the common legit pattern there).
        events: list[tuple[int, int, str, str]] = []

        def visit(node, in_loop: bool):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not fn:
                return  # nested scopes analyzed on their own
            if isinstance(node, (ast.For, ast.While)):
                # Loop bodies get their own analysis: a key consumed
                # every iteration WITHOUT per-iteration re-binding
                # (split/fold_in assignment, or being the loop target)
                # hands every iteration identical bits.
                assigned: set[str] = set()
                consumed: list[tuple[int, int, str]] = []

                def scan_loop(n):
                    if isinstance(n, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Lambda)):
                        return       # nested scopes analyzed on their own
                    for tgt, _ in _assignment_targets(n):
                        assigned.add(tgt.id)
                    if isinstance(n, ast.Call):
                        target = ctx.resolve(n.func) or ""
                        if (target.startswith("jax.random.")
                                and n.args
                                and isinstance(n.args[0], ast.Name)):
                            attr = target.removeprefix("jax.random.")
                            if attr not in _KEY_NONCONSUMING:
                                consumed.append((n.lineno, n.col_offset,
                                                 n.args[0].id))
                    for child in ast.iter_child_nodes(n):
                        scan_loop(child)

                if isinstance(node, ast.For):
                    for sub in ast.walk(node.target):
                        if isinstance(sub, ast.Name):
                            assigned.add(sub.id)
                for child in node.body + getattr(node, "orelse", []):
                    scan_loop(child)
                for line, col, name in consumed:
                    if name not in assigned:
                        events.append((line, col, "loop-consume", name))
                return
            if isinstance(node, ast.Call):
                target = ctx.resolve(node.func) or ""
                if (target.startswith("jax.random.")
                        and node.args
                        and isinstance(node.args[0], ast.Name)):
                    attr = target.removeprefix("jax.random.")
                    if attr not in _KEY_NONCONSUMING and not in_loop:
                        events.append((node.lineno, node.col_offset,
                                       "consume", node.args[0].id))
                    elif attr == "split":
                        events.append((node.lineno, node.col_offset,
                                       "retire", node.args[0].id))
            for tgt_node, kind in _assignment_targets(node):
                events.append((tgt_node.lineno, tgt_node.col_offset,
                               kind, tgt_node.id))
            for child in ast.iter_child_nodes(node):
                visit(child, in_loop)

        for stmt in fn.body:
            visit(stmt, False)

        events.sort(key=lambda e: (e[0], e[1]))
        used: dict[str, str] = {}   # name -> how it was last consumed
        for line, col, kind, name in events:
            if kind == "assign":
                used.pop(name, None)
            elif kind == "loop-consume":
                snippet = (ctx.lines[line - 1].strip()
                           if 0 < line <= len(ctx.lines) else "")
                yield Finding(
                    rule=self.name, path=ctx.path, line=line, col=col,
                    snippet=snippet,
                    message=(f"PRNG key {name!r} consumed every loop "
                             "iteration without re-binding; split or "
                             "fold_in per iteration"))
                used[name] = kind
            elif kind in ("consume", "retire"):
                if name in used:
                    snippet = (ctx.lines[line - 1].strip()
                               if 0 < line <= len(ctx.lines) else "")
                    yield Finding(
                        rule=self.name, path=ctx.path, line=line,
                        col=col, snippet=snippet,
                        message=(f"PRNG key {name!r} already consumed "
                                 f"({used[name]}); split or fold_in "
                                 "before reusing it"))
                used[name] = kind


def _assignment_targets(node):
    """(Name node, "assign") pairs this statement (re)binds."""
    out = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign,
                           ast.NamedExpr)):
        targets = [node.target]
    else:
        return out
    for t in targets:
        for sub in ast.walk(t):
            if isinstance(sub, ast.Name):
                out.append((sub, "assign"))
    return out


class HostSyncInJitRule(Rule):
    """Host syncs inside jit-reachable code either crash at trace time
    (``float()`` on a tracer) or — worse — silently bake a trace-time
    value into the executable. ``np.asarray`` / ``.item()`` /
    ``device_get`` inside a traced body are always wrong; ``float(x)``
    is flagged when ``x`` is a traced function parameter."""

    name = "host-sync-in-jit"
    description = ("host-synchronizing call inside a jit/scan-reachable"
                   " function")

    _ALWAYS = {"numpy.asarray", "numpy.array", "jax.device_get"}
    _CASTS = {"float", "int", "bool", "complex"}

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn in ctx.reachable_subtrees():
            params = _subtree_param_names(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                target = ctx.resolve(node.func) or ""
                if target in self._ALWAYS:
                    yield ctx.finding(
                        self.name, node,
                        f"{target}() forces a device->host transfer "
                        "inside a traced function; use jnp instead")
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr in ("item",
                                               "block_until_ready")
                        and not node.args):
                    yield ctx.finding(
                        self.name, node,
                        f".{node.func.attr}() blocks on device inside "
                        "a traced function")
                elif (target in self._CASTS and len(node.args) == 1
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id in params):
                    yield ctx.finding(
                        self.name, node,
                        f"{target}({node.args[0].id}) on a traced "
                        "argument concretizes it at trace time; keep "
                        "it a jnp array (or mark it static)")


def _subtree_param_names(fn) -> set[str]:
    names: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            a = node.args
            for arg in (list(a.posonlyargs) + list(a.args)
                        + list(a.kwonlyargs)):
                names.add(arg.arg)
    names.discard("self")
    names.discard("cls")
    return names


class TracedBranchRule(Rule):
    """``if``/``while`` on a traced value raises ConcretizationError at
    best; at worst (when the value is concrete at trace time) it bakes
    one branch into the executable and silently retraces per value.
    Flagged: branch conditions that *compute* on jnp/jax values inside
    jit-reachable code — static config flags stay legal."""

    name = "traced-branch"
    description = ("Python branch on a jnp/jax expression inside a "
                   "traced function; use lax.cond/jnp.where")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn in ctx.reachable_subtrees():
            for node in ast.walk(fn):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                if self._is_traced_expr(ctx, node.test):
                    kw = "while" if isinstance(node, ast.While) else "if"
                    yield ctx.finding(
                        self.name, node,
                        f"`{kw}` on a traced jnp/jax expression; use "
                        "jax.lax.cond / jnp.where (or hoist the value "
                        "out of the traced body)")

    @staticmethod
    def _is_traced_expr(ctx: ModuleContext, test: ast.AST) -> bool:
        for node in ast.walk(test):
            if isinstance(node, ast.Call):
                target = ctx.resolve(node.func) or ""
                if (target.startswith("jax.numpy.")
                        or target.startswith("jax.lax.")
                        or target.startswith("jax.random.")):
                    return True
        return False


class JaxDebugRule(Rule):
    """``jax.debug.print`` / ``jax.debug.breakpoint`` lower to callback
    primitives: they force host round-trips in the hot path and change
    XLA scheduling. Debug-only — never committed on a hot path."""

    name = "jax-debug"
    description = "leftover jax.debug.* call"

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.resolve(node.func) or ""
            if target.startswith("jax.debug."):
                yield ctx.finding(
                    self.name, node,
                    f"{target}() lowers to a host callback primitive; "
                    "remove before committing (or suppress for "
                    "intentional tooling)")


class MutableDefaultRule(Rule):
    """A mutable default argument is one shared object across calls —
    state leaks between rounds/trainers. (ruff B006 also covers this
    when installed; this rule keeps the check dependency-free.)"""

    name = "mutable-default"
    description = "mutable default argument value"

    _MUTABLE_CALLS = {"list", "dict", "set", "bytearray",
                      "collections.defaultdict", "defaultdict"}

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for fn in ctx._functions:
            args = fn.args
            for default in list(args.defaults) + [
                    d for d in args.kw_defaults if d is not None]:
                if self._is_mutable(ctx, default):
                    yield ctx.finding(
                        self.name, default,
                        f"mutable default in {fn.name}(); use None and "
                        "construct inside the body")

    def _is_mutable(self, ctx: ModuleContext, node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            return (ctx.resolve(node.func) or "") in self._MUTABLE_CALLS
        return False


ALL_RULES: tuple[Rule, ...] = (
    AmbientNpRandomRule(),
    UnseededDefaultRngRule(),
    PrngKeyReuseRule(),
    HostSyncInJitRule(),
    TracedBranchRule(),
    JaxDebugRule(),
    MutableDefaultRule(),
)

RULES_BY_NAME = {r.name: r for r in ALL_RULES}
