"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def step_decay(value: float, decay: float = 0.99, every: int = 1):
    def fn(step):
        k = jnp.floor_divide(step, every).astype(jnp.float32)
        return jnp.asarray(value, jnp.float32) * decay**k
    return fn


def cosine(value: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return value * (final_frac + (1.0 - final_frac) * cos)
    return fn
