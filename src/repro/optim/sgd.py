"""Minimal optimizer substrate (optax-style pure transforms).

Used by the *baselines'* local solvers (FedAvg/Per-FedAvg/pFedMe/Ditto/APFL
all run local SGD/Adam); RWSADMM itself needs no optimizer — its updates are
closed-form (core/rwsadmm.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]  # (grads, state, params)


def sgd(lr: float | Callable[[jnp.ndarray], jnp.ndarray],
        momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        mu = (jax.tree_util.tree_map(jnp.zeros_like, params)
              if momentum else None)
        return {"mu": mu, "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step_lr = lr(state["count"]) if callable(lr) else lr
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params
            )
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mu"], grads
            )
            new_params = jax.tree_util.tree_map(
                lambda p, m: p - step_lr * m, params, mu
            )
            return new_params, {"mu": mu, "count": state["count"] + 1}
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - step_lr * g, params, grads
        )
        return new_params, {"mu": None, "count": state["count"] + 1}

    return Optimizer(init, update)


def adam(lr: float | Callable[[jnp.ndarray], jnp.ndarray],
         b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"m": z, "v": z, "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        step_lr = lr(count) if callable(lr) else lr
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
        )
        mc = 1.0 - b1 ** count.astype(jnp.float32)
        vc = 1.0 - b2 ** count.astype(jnp.float32)

        def leaf(p, m_, v_):
            upd = (m_ / mc) / (jnp.sqrt(v_ / vc) + eps)
            if weight_decay:
                upd = upd + weight_decay * p
            return p - step_lr * upd

        return (jax.tree_util.tree_map(leaf, params, m, v),
                {"m": m, "v": v, "count": count})

    return Optimizer(init, update)
