from .sgd import adam, sgd  # noqa: F401
from .schedule import constant, cosine, step_decay  # noqa: F401
