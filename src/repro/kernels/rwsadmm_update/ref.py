"""Pure-jnp oracle for the fused RWSADMM triple update.

One zone round's elementwise math over flat parameter vectors (the
Pallas kernel computes exactly this, one HBM pass):

    t' = y − x;  s' = sgn(t')
    x⁺ = y − g/β + s' ⊙ (z − βε)/β              (derived Eq. 10 solver)
    z⁺ = z + κβ (x⁺ − y − ε)                     (Eq. 15)
    c  = x  − (z /β + ε) ⊙ sgn(y − x)            (Eq. 13 contribution)
    c⁺ = x⁺ − (z⁺/β + ε) ⊙ sgn(y − x⁺)
    y⁺ = y + (c⁺ − c)/n                          (Eq. 14 incremental)
"""
from __future__ import annotations

import jax.numpy as jnp


def rwsadmm_fused_update_ref(x, z, y, g, kappa, *, beta: float,
                             eps_half: float, n_total: float):
    s_prev = jnp.sign(y - x)
    x_new = y - g / beta + s_prev * (z - beta * eps_half) / beta
    z_new = z + kappa * beta * (x_new - y - eps_half)
    c_old = x - (z / beta + eps_half) * jnp.sign(y - x)
    c_new = x_new - (z_new / beta + eps_half) * jnp.sign(y - x_new)
    y_new = y + (c_new - c_old) / n_total
    return x_new, z_new, y_new


def rwsadmm_zone_fused_update_ref(x, z, y, g, mask, kappa, *, beta: float,
                                  eps_half: float, n_total: float):
    """Masked multi-client zone oracle (Eq. 31): x/z/g (Z, N) stacked
    active clients, y (N,), mask (Z,). Padded slots (mask=0) pass x/z
    through unchanged and contribute zero to the y fold."""
    m = mask[:, None]
    s_prev = jnp.sign(y[None] - x)
    x_new = y[None] - g / beta + s_prev * (z - beta * eps_half) / beta
    z_new = z + kappa * beta * (x_new - y[None] - eps_half)
    c_old = x - (z / beta + eps_half) * s_prev
    c_new = x_new - (z_new / beta + eps_half) * jnp.sign(y[None] - x_new)
    y_new = y + jnp.sum(m * (c_new - c_old), axis=0) / n_total
    return (m * x_new + (1.0 - m) * x,
            m * z_new + (1.0 - m) * z,
            y_new)
