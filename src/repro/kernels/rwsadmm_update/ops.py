"""jit wrapper: pytree-level fused RWSADMM update via the Pallas kernel.

Flattens the parameter pytree once, pads to the block size, runs the
fused kernel, and unflattens. On non-TPU backends the kernel executes in
interpret mode (Python/CPU) for correctness validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import tree as tree_util
from . import kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("beta", "eps_half", "n_total",
                                             "block"))
def rwsadmm_fused_update(x, z, y, g, kappa, *, beta: float, eps_half: float,
                         n_total: float, block: int = kernel.BLOCK):
    """Pytree version of the fused triple update. Returns (x⁺, z⁺, y⁺)."""
    xf = tree_util.flatten(x)
    zf = tree_util.flatten(z)
    yf = tree_util.flatten(y)
    gf = tree_util.flatten(g)
    n = xf.shape[0]
    pad = (-n) % block
    if pad:
        xf, zf, yf, gf = (jnp.pad(a, (0, pad)) for a in (xf, zf, yf, gf))
    kappa_arr = jnp.reshape(jnp.asarray(kappa, xf.dtype), (1,))
    x_new, z_new, y_new = kernel.fused_update_flat(
        xf, zf, yf, gf, kappa_arr, beta=beta, eps_half=eps_half,
        n_total=n_total, interpret=_interpret(), block=block,
    )
    if pad:
        x_new, z_new, y_new = (a[:n] for a in (x_new, z_new, y_new))
    return (tree_util.unflatten(x, x_new),
            tree_util.unflatten(z, z_new),
            tree_util.unflatten(y, y_new))
