"""jit wrapper: pytree-level fused RWSADMM update via the Pallas kernel.

Flattens the parameter pytree once, pads to the block size, runs the
fused kernel, and unflattens. On non-TPU backends the kernel executes in
interpret mode (Python/CPU) for correctness validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import tree as tree_util
from . import kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("beta", "eps_half", "n_total",
                                             "block"))
def rwsadmm_fused_update(x, z, y, g, kappa, *, beta: float, eps_half: float,
                         n_total: float, block: int = kernel.BLOCK):
    """Pytree version of the fused triple update. Returns (x⁺, z⁺, y⁺)."""
    xf = tree_util.flatten(x)
    zf = tree_util.flatten(z)
    yf = tree_util.flatten(y)
    gf = tree_util.flatten(g)
    n = xf.shape[0]
    pad = (-n) % block
    if pad:
        xf, zf, yf, gf = (jnp.pad(a, (0, pad)) for a in (xf, zf, yf, gf))
    kappa_arr = jnp.reshape(jnp.asarray(kappa, xf.dtype), (1,))
    x_new, z_new, y_new = kernel.fused_update_flat(
        xf, zf, yf, gf, kappa_arr, beta=beta, eps_half=eps_half,
        n_total=n_total, interpret=_interpret(), block=block,
    )
    if pad:
        x_new, z_new, y_new = (a[:n] for a in (x_new, z_new, y_new))
    return (tree_util.unflatten(x, x_new),
            tree_util.unflatten(z, z_new),
            tree_util.unflatten(y, y_new))


@functools.partial(jax.jit, static_argnames=("beta", "eps_half", "n_total",
                                             "block"))
def rwsadmm_zone_fused_update(x, z, y, g, mask, kappa, *, beta: float,
                              eps_half: float, n_total: float,
                              block: int = kernel.ZONE_BLOCK):
    """Masked multi-client zone update (Eq. 31) via the fused kernel.

    x/z/g: pytrees with a padded leading ``Z`` axis (stacked active
    clients); y: the server token pytree; mask: (Z,) float (0 = padding).
    Returns (x⁺, z⁺, y⁺) with the same layouts — one HBM pass for the
    whole zone round. Oracle: ``core.rwsadmm.zone_round_masked``.
    """
    xf = jax.vmap(tree_util.flatten)(x)   # (Z, N)
    zf = jax.vmap(tree_util.flatten)(z)
    gf = jax.vmap(tree_util.flatten)(g)
    yf = tree_util.flatten(y)             # (N,)
    n = yf.shape[0]
    pad = (-n) % block
    if pad:
        xf, zf, gf = (jnp.pad(a, ((0, 0), (0, pad))) for a in (xf, zf, gf))
        yf = jnp.pad(yf, (0, pad))
    kappa_arr = jnp.reshape(jnp.asarray(kappa, yf.dtype), (1,))
    mask_arr = jnp.asarray(mask, yf.dtype)
    x_new, z_new, y_new = kernel.zone_fused_update_flat(
        xf, zf, yf, gf, mask_arr, kappa_arr, beta=beta, eps_half=eps_half,
        n_total=n_total, interpret=_interpret(), block=block,
    )
    if pad:
        x_new, z_new = (a[:, :n] for a in (x_new, z_new))
        y_new = y_new[:n]
    template = jax.tree_util.tree_map(lambda l: l[0], x)
    unstack = jax.vmap(lambda f: tree_util.unflatten(template, f))
    return (unstack(x_new), unstack(z_new), tree_util.unflatten(y, y_new))


@functools.partial(jax.jit, static_argnames=("beta", "eps_half", "n_total",
                                             "block"))
def rwsadmm_multizone_fused_update(x, z, y, g, mask, kappa, *, beta: float,
                                   eps_half: float, n_total: float,
                                   block: int = kernel.ZONE_BLOCK):
    """K simultaneous masked zone rounds via one fused-kernel launch.

    x/z/g: pytrees with padded leading ``(K, Z)`` axes (K walkers, each
    with a stacked active zone); y: stacked ``(K, ...)`` token pytree
    (one token per walker); mask: (K, Z) float (0 = padding). Returns
    (x⁺, z⁺, y⁺) with the same layouts — the whole fleet wall step in
    one HBM pass. Oracle: ``core.rwsadmm.multizone_round_masked``.
    """
    flat2 = jax.vmap(jax.vmap(tree_util.flatten))
    xf = flat2(x)                         # (K, Z, N)
    zf = flat2(z)
    gf = flat2(g)
    yf = jax.vmap(tree_util.flatten)(y)   # (K, N)
    n = yf.shape[-1]
    pad = (-n) % block
    if pad:
        xf, zf, gf = (jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
                      for a in (xf, zf, gf))
        yf = jnp.pad(yf, ((0, 0), (0, pad)))
    kappa_arr = jnp.reshape(jnp.asarray(kappa, yf.dtype), (1,))
    mask_arr = jnp.asarray(mask, yf.dtype)
    x_new, z_new, y_new = kernel.multizone_fused_update_flat(
        xf, zf, yf, gf, mask_arr, kappa_arr, beta=beta, eps_half=eps_half,
        n_total=n_total, interpret=_interpret(), block=block,
    )
    if pad:
        x_new, z_new = (a[..., :n] for a in (x_new, z_new))
        y_new = y_new[..., :n]
    template = jax.tree_util.tree_map(lambda l: l[0, 0], x)
    unstack2 = jax.vmap(jax.vmap(
        lambda f: tree_util.unflatten(template, f)))
    y_template = jax.tree_util.tree_map(lambda l: l[0], y)
    unstack_y = jax.vmap(lambda f: tree_util.unflatten(y_template, f))
    return (unstack2(x_new), unstack2(z_new), unstack_y(y_new))
