"""Pallas TPU kernel: fused RWSADMM triple update (x, z, y).

Why a kernel: the zone round's update is ~10 elementwise HLO ops over four
model-sized tensors (x, z, y, g). Unfused, XLA streams each intermediate
through HBM; fused, it is a single HBM pass: read 4·P, write 3·P — the
roofline floor for this memory-bound op. VMEM tiling: flat vectors in
(8, 1024)-shaped blocks (8×128-lane aligned), all operands resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024  # elements per program: 7 arrays × 32 KB fp32 in VMEM


def _kernel(x_ref, z_ref, y_ref, g_ref, kappa_ref,
            x_out, z_out, y_out, *, beta, eps_half, n_total):
    x = x_ref[...]
    z = z_ref[...]
    y = y_ref[...]
    g = g_ref[...]
    kappa = kappa_ref[0]

    s_prev = jnp.sign(y - x)
    x_new = y - g / beta + s_prev * (z - beta * eps_half) / beta
    z_new = z + kappa * beta * (x_new - y - eps_half)
    c_old = x - (z / beta + eps_half) * s_prev
    c_new = x_new - (z_new / beta + eps_half) * jnp.sign(y - x_new)
    y_new = y + (c_new - c_old) / n_total

    x_out[...] = x_new
    z_out[...] = z_new
    y_out[...] = y_new


def fused_update_flat(x, z, y, g, kappa, *, beta: float, eps_half: float,
                      n_total: float, interpret: bool = True,
                      block: int = BLOCK):
    """x/z/y/g: flat (N,) arrays, N a multiple of ``block`` (ops.py pads).
    kappa: (1,) array (decayed per round, so not compile-time)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    vspec = pl.BlockSpec((block,), lambda i: (i,))
    kspec = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), x.dtype)] * 3
    return pl.pallas_call(
        functools.partial(_kernel, beta=beta, eps_half=eps_half,
                          n_total=n_total),
        grid=grid,
        in_specs=[vspec, vspec, vspec, vspec, kspec],
        out_specs=[vspec, vspec, vspec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, z, y, g, kappa)
