"""Pallas TPU kernel: fused RWSADMM triple update (x, z, y).

Why a kernel: the zone round's update is ~10 elementwise HLO ops over four
model-sized tensors (x, z, y, g). Unfused, XLA streams each intermediate
through HBM; fused, it is a single HBM pass: read 4·P, write 3·P — the
roofline floor for this memory-bound op. VMEM tiling: flat vectors in
(8, 1024)-shaped blocks (8×128-lane aligned), all operands resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024  # elements per program: 7 arrays × 32 KB fp32 in VMEM


def _kernel(x_ref, z_ref, y_ref, g_ref, kappa_ref,
            x_out, z_out, y_out, *, beta, eps_half, n_total):
    x = x_ref[...]
    z = z_ref[...]
    y = y_ref[...]
    g = g_ref[...]
    kappa = kappa_ref[0]

    s_prev = jnp.sign(y - x)
    x_new = y - g / beta + s_prev * (z - beta * eps_half) / beta
    z_new = z + kappa * beta * (x_new - y - eps_half)
    c_old = x - (z / beta + eps_half) * s_prev
    c_new = x_new - (z_new / beta + eps_half) * jnp.sign(y - x_new)
    y_new = y + (c_new - c_old) / n_total

    x_out[...] = x_new
    z_out[...] = z_new
    y_out[...] = y_new


def fused_update_flat(x, z, y, g, kappa, *, beta: float, eps_half: float,
                      n_total: float, interpret: bool = True,
                      block: int = BLOCK):
    """x/z/y/g: flat (N,) arrays, N a multiple of ``block`` (ops.py pads).
    kappa: (1,) array (decayed per round, so not compile-time)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    vspec = pl.BlockSpec((block,), lambda i: (i,))
    kspec = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), x.dtype)] * 3
    return pl.pallas_call(
        functools.partial(_kernel, beta=beta, eps_half=eps_half,
                          n_total=n_total),
        grid=grid,
        in_specs=[vspec, vspec, vspec, vspec, kspec],
        out_specs=[vspec, vspec, vspec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, z, y, g, kappa)


# ---------------------------------------------------------------------------
# Masked multi-client zone variant (paper Eq. 31): all Z active clients'
# x/z updates plus the server's folded y update in ONE HBM pass. Per block
# of P parameters the pass reads (3Z+1)·P (x, z, g per client + y) and
# writes (2Z+1)·P (x⁺, z⁺ per client + y⁺) — the roofline floor; the
# unfused zone round streams every per-client intermediate (s', c, c⁺, Δ)
# through HBM. The Z loop is unrolled at trace time (Z ≤ ~16), all
# operands VMEM-resident.
# ---------------------------------------------------------------------------

ZONE_BLOCK = 2 * 1024  # elements/program: (5Z+2) arrays ≈ 42 × 8 KB @ Z=8


def _zone_kernel(x_ref, z_ref, y_ref, g_ref, mask_ref, kappa_ref,
                 x_out, z_out, y_out, *, beta, eps_half, n_total, zone):
    y = y_ref[...]
    kappa = kappa_ref[0]
    acc = jnp.zeros_like(y)
    for j in range(zone):          # static unroll over the padded zone
        m = mask_ref[j]
        x = x_ref[j]
        z = z_ref[j]
        g = g_ref[j]
        s_prev = jnp.sign(y - x)
        x_new = y - g / beta + s_prev * (z - beta * eps_half) / beta
        z_new = z + kappa * beta * (x_new - y - eps_half)
        c_old = x - (z / beta + eps_half) * s_prev
        c_new = x_new - (z_new / beta + eps_half) * jnp.sign(y - x_new)
        # Padded slots (m=0) pass through untouched and fold zero into y.
        x_out[j] = m * x_new + (1.0 - m) * x
        z_out[j] = m * z_new + (1.0 - m) * z
        acc = acc + m * (c_new - c_old)
    y_out[...] = y + acc / n_total


def _multizone_kernel(x_ref, z_ref, y_ref, g_ref, mask_ref, kappa_ref,
                      x_out, z_out, y_out, *, beta, eps_half, n_total,
                      zone):
    """One walker's zone block (leading size-1 walker axis carved out by
    the grid) — same math as :func:`_zone_kernel` against that walker's
    own token slice."""
    y = y_ref[0]
    kappa = kappa_ref[0]
    acc = jnp.zeros_like(y)
    for j in range(zone):          # static unroll over the padded zone
        m = mask_ref[0, j]
        x = x_ref[0, j]
        z = z_ref[0, j]
        g = g_ref[0, j]
        s_prev = jnp.sign(y - x)
        x_new = y - g / beta + s_prev * (z - beta * eps_half) / beta
        z_new = z + kappa * beta * (x_new - y - eps_half)
        c_old = x - (z / beta + eps_half) * s_prev
        c_new = x_new - (z_new / beta + eps_half) * jnp.sign(y - x_new)
        x_out[0, j] = m * x_new + (1.0 - m) * x
        z_out[0, j] = m * z_new + (1.0 - m) * z
        acc = acc + m * (c_new - c_old)
    y_out[0] = y + acc / n_total


def multizone_fused_update_flat(x, z, y, g, mask, kappa, *, beta: float,
                                eps_half: float, n_total: float,
                                interpret: bool = True,
                                block: int = ZONE_BLOCK):
    """K simultaneous zones in ONE kernel launch (fleet mode).

    x/z/g: (K, Z, N) stacked walker zones; y: (K, N) stacked tokens;
    mask: (K, Z); kappa: (1,). N a multiple of ``block`` (ops.py pads).
    Grid (K, N/block): each program serves one walker's parameter block,
    so the whole fleet wall step is a single HBM pass — K independent
    :func:`zone_fused_update_flat` launches would re-dispatch per
    walker. Returns (x⁺ (K, Z, N), z⁺ (K, Z, N), y⁺ (K, N)).
    """
    k_walkers, zone, n = x.shape
    assert n % block == 0, (n, block)
    grid = (k_walkers, n // block)
    mspec = pl.BlockSpec((1, zone, block), lambda k, i: (k, 0, i))
    yspec = pl.BlockSpec((1, block), lambda k, i: (k, i))
    maskspec = pl.BlockSpec((1, zone), lambda k, i: (k, 0))
    kspec = pl.BlockSpec((1,), lambda k, i: (0,))
    out_shape = [
        jax.ShapeDtypeStruct((k_walkers, zone, n), x.dtype),
        jax.ShapeDtypeStruct((k_walkers, zone, n), x.dtype),
        jax.ShapeDtypeStruct((k_walkers, n), x.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_multizone_kernel, beta=beta, eps_half=eps_half,
                          n_total=n_total, zone=zone),
        grid=grid,
        in_specs=[mspec, mspec, yspec, mspec, maskspec, kspec],
        out_specs=[mspec, mspec, yspec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, z, y, g, mask, kappa)


def zone_fused_update_flat(x, z, y, g, mask, kappa, *, beta: float,
                           eps_half: float, n_total: float,
                           interpret: bool = True, block: int = ZONE_BLOCK):
    """x/z/g: (Z, N) stacked active clients; y: (N,); mask: (Z,);
    kappa: (1,). N a multiple of ``block`` (ops.py pads). Returns
    (x⁺ (Z, N), z⁺ (Z, N), y⁺ (N,))."""
    zone, n = x.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    mspec = pl.BlockSpec((zone, block), lambda i: (0, i))
    vspec = pl.BlockSpec((block,), lambda i: (i,))
    maskspec = pl.BlockSpec((zone,), lambda i: (0,))
    kspec = pl.BlockSpec((1,), lambda i: (0,))
    out_shape = [
        jax.ShapeDtypeStruct((zone, n), x.dtype),
        jax.ShapeDtypeStruct((zone, n), x.dtype),
        jax.ShapeDtypeStruct((n,), x.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_zone_kernel, beta=beta, eps_half=eps_half,
                          n_total=n_total, zone=zone),
        grid=grid,
        in_specs=[mspec, mspec, vspec, mspec, maskspec, kspec],
        out_specs=[mspec, mspec, vspec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, z, y, g, mask, kappa)
