from . import kernel, ops, ref  # noqa: F401
from .ops import rwsadmm_fused_update  # noqa: F401
