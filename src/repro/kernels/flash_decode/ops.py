"""jit wrapper for the flash-decode kernel (pads S, picks interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "seq_block"))
def flash_decode(q, k, v, length, *, window: int | None = None,
                 seq_block: int = kernel.SEQ_BLOCK):
    """q: (B,H,hd); k/v: (B,S,K,hd); length: (B,). Returns (B,H,hd)."""
    s = k.shape[1]
    sb = min(seq_block, max(128, 1 << (s - 1).bit_length())) \
        if s < seq_block else seq_block
    pad = (-s) % sb
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return kernel.flash_decode_gqa(
        q, k, v, jnp.asarray(length, jnp.int32), window=window,
        seq_block=sb, interpret=_interpret(),
    )
