from . import kernel, ops, ref  # noqa: F401
from .ops import flash_decode  # noqa: F401
