"""Pallas TPU kernel: single-token GQA flash decode.

The decode_32k / long_500k bottleneck is streaming the KV cache once per
token: it is purely memory-bound (arithmetic intensity ~2 flops/byte). The
kernel tiles the cache sequence dimension into VMEM blocks and keeps the
online-softmax running state (m, l, acc) in VMEM scratch across the
sequence grid dimension (sequential on TPU), so HBM traffic is exactly one
pass over K and V. Grid: (B, K_heads, S/block); the G = H/K query heads of
one KV head ride along in a (G, hd) tile (MXU-aligned for hd ∈ {64..256}).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
SEQ_BLOCK = 512


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, window, seq_block, n_blocks):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)       # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)    # (Sb, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)    # (Sb, hd)
    length = len_ref[0]

    s = jnp.dot(q, k.T) * scale               # (G, Sb)
    pos = sb * seq_block + jax.lax.broadcasted_iota(
        jnp.int32, (1, seq_block), 1)
    valid = pos < length
    if window is not None:
        valid &= pos >= length - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                        # (G, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                     # (G, Sb)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = alpha * acc_scr[...] + jnp.dot(p, v)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(sb == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_gqa(q, k, v, length, *, window: int | None = None,
                     seq_block: int = SEQ_BLOCK, interpret: bool = True):
    """q: (B, H, hd); k/v: (B, S, K, hd); length: (B,) int32.

    Returns (B, H, hd). S must be a multiple of seq_block (ops.py pads).
    """
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    assert s % seq_block == 0, (s, seq_block)
    n_blocks = s // seq_block
    scale = 1.0 / (hd ** 0.5)

    from jax.experimental.pallas import tpu as pltpu

    qg = q.reshape(b, kvh, g, hd)
    grid = (b, kvh, n_blocks)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window,
                          seq_block=seq_block, n_blocks=n_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki, si: (bi,)),            # length
            pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, seq_block, 1, hd),
                         lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, seq_block, 1, hd),
                         lambda bi, ki, si: (bi, si, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max m
            pltpu.VMEM((g, 1), jnp.float32),     # running sum l
            pltpu.VMEM((g, hd), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(length, qg, k, v)
    return out.reshape(b, h, hd)
