"""Pure-jnp oracle for single-token GQA flash decode.

q: (B, H, hd); k/v: (B, S, K, hd); length: (B,) valid prefix; optional
sliding window (attend to positions (length−window, length])."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_decode_ref(q, k, v, length, *, window: int | None = None):
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    pos = jnp.arange(s)[None, :]                  # (1, S)
    valid = pos < length[:, None]
    if window is not None:
        valid &= pos >= (length[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)
