"""Pallas TPU kernels for the framework's compute hot-spots.

  * rwsadmm_update — the paper's per-round fused elementwise triple update
    (x, z, y): one HBM pass instead of ~10 unfused elementwise HLO ops.
  * flash_decode — single-token GQA attention against a long KV cache
    (decode_32k / long_500k bottleneck), online softmax in VMEM scratch.
  * rglru_scan — blocked linear recurrence for RG-LRU / hybrid archs.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit
wrapper; interpret=True off-TPU), ref.py (pure-jnp oracle).
"""
from . import flash_decode, rglru_scan, rwsadmm_update  # noqa: F401
