"""jit wrapper for the RG-LRU scan kernel (pads S/D, picks interpret)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("seq_block", "ch_block"))
def rglru_scan(a, b, *, seq_block: int | None = None,
               ch_block: int = kernel.CH_BLOCK):
    """a, b: (B, S, D) → h with h_t = a_t h_{t−1} + b_t."""
    bsz, s, d = a.shape
    sb = seq_block or min(kernel.SEQ_BLOCK, s)
    pad_s = (-s) % sb
    pad_d = (-d) % ch_block
    if pad_s or pad_d:
        # a=1 on padded channels keeps the carry intact; padded rows are
        # sliced off afterwards so any a value works — use 0 for safety.
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_d)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_d)))
    h = kernel.rglru_scan_blocked(a, b, seq_block=sb, ch_block=ch_block,
                                  interpret=_interpret())
    return h[:, :s, :d]
