from . import kernel, ops, ref  # noqa: F401
from .ops import rglru_scan  # noqa: F401
