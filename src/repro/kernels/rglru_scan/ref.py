"""Pure-jnp oracle for the RG-LRU linear recurrence h_t = a_t·h_{t−1}+b_t
(diagonal, per-channel) — the associative-scan form from
models/recurrent.linear_scan."""
from __future__ import annotations

from ...models.recurrent import linear_scan


def rglru_scan_ref(a, b):
    """a, b: (B, S, D) fp32 → h: (B, S, D)."""
    return linear_scan(a, b)
