"""Pallas TPU kernel: blocked RG-LRU linear recurrence.

h_t = a_t ⊙ h_{t−1} + b_t over (B, S, D). The channel dim is tiled into
128-lane blocks; the sequence dim into VMEM-resident chunks, with the
carry h kept in VMEM scratch across the (sequential) seq grid dimension.
Within a chunk, the recurrence runs as a Blelloch-free sequential
fori_loop over rows — each step is a (1, Db) VPU FMA; HBM traffic is one
pass over a and b (the memory-bound roofline floor for this op).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SEQ_BLOCK = 512
CH_BLOCK = 128


def _kernel(a_ref, b_ref, h_ref, carry_scr, *, seq_block):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    def body(t, carry):
        h = a_ref[0, t] * carry + b_ref[0, t]      # (Db,)
        h_ref[0, t] = h
        return h

    carry = carry_scr[0]
    carry = jax.lax.fori_loop(0, seq_block, body, carry)
    carry_scr[0] = carry


def rglru_scan_blocked(a, b, *, seq_block: int = SEQ_BLOCK,
                       ch_block: int = CH_BLOCK, interpret: bool = True):
    """a, b: (B, S, D) with S % seq_block == 0 and D % ch_block == 0
    (ops.py pads). Returns h (B, S, D)."""
    bsz, s, d = a.shape
    assert s % seq_block == 0 and d % ch_block == 0
    grid = (bsz, d // ch_block, s // seq_block)
    spec = pl.BlockSpec((1, seq_block, ch_block),
                        lambda bi, ci, si: (bi, si, ci))
    return pl.pallas_call(
        functools.partial(_kernel, seq_block=seq_block),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, ch_block), a.dtype)],
        interpret=interpret,
    )(a, b)
