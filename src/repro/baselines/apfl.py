"""APFL (Deng et al. 2020) — adaptive personalized FL.

Each client keeps a local model v_i and mixing weight α; the served model
is v̄_i = α v_i + (1−α) w. Local steps update the global copy w_i with
∇f(w_i) and v_i with α·∇f(v̄_i); the server averages w_i.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fl.base import DeviceData, TrainerBase, sample_batch


class APFLState(NamedTuple):
    w: dict
    v: dict  # stacked (n, ...)


class APFLTrainer(TrainerBase):
    name = "apfl"
    personalized = True
    # The stacked (n, …) personal models v_i live in the trainer state —
    # incompatible with the bounded-store lazy plane.
    lazy_capable = False

    def __init__(self, model, data: DeviceData, *, alpha: float = 0.5,
                 lr: float = 0.05, local_steps: int = 10,
                 clients_per_round: int = 10, batch_size: int = 20,
                 telemetry=None):
        super().__init__(model, data, batch_size, telemetry=telemetry)
        self.m = int(min(clients_per_round, self.n_clients))
        self.alpha = alpha

        def local(w, v, client, key):
            def body(carry, k):
                w_i, v_i = carry
                xb, yb = sample_batch(self.data, client, k, batch_size)
                gw = self.grad_fn(w_i, xb, yb, k)
                w_i = jax.tree_util.tree_map(
                    lambda a, b: a - lr * b, w_i, gw
                )
                mixed = jax.tree_util.tree_map(
                    lambda a, b: alpha * a + (1 - alpha) * b, v_i, w_i
                )
                gv = self.grad_fn(mixed, xb, yb, k)
                v_i = jax.tree_util.tree_map(
                    lambda a, b: a - lr * alpha * b, v_i, gv
                )
                return (w_i, v_i), None

            keys = jax.random.split(key, local_steps)
            (w_i, v_i), _ = jax.lax.scan(body, (w, v), keys)
            return w_i, v_i

        def round_fn(w, v_all, sel, key):
            keys = jax.random.split(key, self.m)
            v_sel = jax.tree_util.tree_map(lambda l: l[sel], v_all)
            w_locals, v_upd = jax.vmap(
                lambda v_, c, k: local(w, v_, c, k)
            )(v_sel, sel, keys)
            w_new = jax.tree_util.tree_map(
                lambda ls: jnp.mean(ls, axis=0), w_locals
            )
            v_all = jax.tree_util.tree_map(
                lambda full, old, new: full.at[sel].add(new - old),
                v_all, v_sel, v_upd,
            )
            return w_new, v_all

        self._round_fn = jax.jit(round_fn)

    def init_state(self, key) -> APFLState:
        w = self.model.init(key)
        v = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (self.n_clients,) + l.shape), w
        )
        return APFLState(w=w, v=v)

    def round(self, state, rnd: int, rng: np.random.Generator):
        sel = self.select_clients(rnd, rng, self.m)
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        w, v = self._round_fn(state.w, state.v, jnp.asarray(sel), key)
        return APFLState(w=w, v=v), {
            "round": rnd,
            "comm_bytes": self.comm_bytes_per_round(self.m),
            **self.scenario_round_costs(sel),
        }

    def personalized_params(self, state):
        return jax.tree_util.tree_map(
            lambda v, w: self.alpha * v + (1 - self.alpha) * w[None],
            state.v, state.w,
        )

    def global_params(self, state):
        return state.w
