"""Ditto (Li et al. 2021) — global FedAvg + per-client personal model v_i
trained with the proximal objective  f_i(v) + (λ/2)||v − w_global||²."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fl.base import DeviceData, TrainerBase, sample_batch


class DittoState(NamedTuple):
    w: dict       # global model
    v: dict       # stacked personal models (n, ...)


class DittoTrainer(TrainerBase):
    name = "ditto"
    personalized = True
    # The stacked (n, …) personal models v_i live in the trainer state —
    # incompatible with the bounded-store lazy plane.
    lazy_capable = False

    def __init__(self, model, data: DeviceData, *, lam: float = 1.0,
                 lr: float = 0.05, local_steps: int = 10,
                 personal_steps: int = 5, clients_per_round: int = 10,
                 batch_size: int = 20, telemetry=None):
        super().__init__(model, data, batch_size, telemetry=telemetry)
        self.m = int(min(clients_per_round, self.n_clients))
        self.lam = lam
        local = self.make_local_sgd(lr, local_steps)

        def personal_update(v, w, client, key):
            def body(v_, k):
                xb, yb = sample_batch(self.data, client, k, batch_size)
                g = self.grad_fn(v_, xb, yb, k)
                v_ = jax.tree_util.tree_map(
                    lambda a, b, c: a - lr * (b + lam * (a - c)), v_, g, w
                )
                return v_, None

            keys = jax.random.split(key, personal_steps)
            v, _ = jax.lax.scan(body, v, keys)
            return v

        def round_fn(w, v_all, sel, key):
            keys = jax.random.split(key, self.m)
            # Global part (FedAvg).
            w_locals = jax.vmap(lambda c, k: local(w, c, k))(sel, keys)
            w_new = jax.tree_util.tree_map(
                lambda ls: jnp.mean(ls, axis=0), w_locals
            )
            # Personal part for selected clients.
            v_sel = jax.tree_util.tree_map(lambda l: l[sel], v_all)
            keys2 = jax.random.split(jax.random.fold_in(key, 7), self.m)
            v_upd = jax.vmap(
                lambda v_, c, k: personal_update(v_, w, c, k)
            )(v_sel, sel, keys2)
            v_all = jax.tree_util.tree_map(
                lambda full, old, new: full.at[sel].add(new - old),
                v_all, v_sel, v_upd,
            )
            return w_new, v_all

        self._round_fn = jax.jit(round_fn)

    def init_state(self, key) -> DittoState:
        w = self.model.init(key)
        v = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (self.n_clients,) + l.shape), w
        )
        return DittoState(w=w, v=v)

    def round(self, state, rnd: int, rng: np.random.Generator):
        sel = self.select_clients(rnd, rng, self.m)
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        w, v = self._round_fn(state.w, state.v, jnp.asarray(sel), key)
        return DittoState(w=w, v=v), {
            "round": rnd,
            "comm_bytes": self.comm_bytes_per_round(self.m),
            **self.scenario_round_costs(sel),
        }

    def personalized_params(self, state):
        return state.v

    def global_params(self, state):
        return state.w
