"""Per-FedAvg (Fallah et al. 2020) — first-order MAML variant (FO).

Each local step: w⁺ = w − α∇f(w; ξ₁);  w ← w − β∇f(w⁺; ξ₂).
Personalized evaluation adapts the global model with one α-step on the
client's own data (the Per-FedAvg deployment protocol).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fl.base import DeviceData, TrainerBase, sample_batch


class PerFedAvgState(NamedTuple):
    w: dict


class PerFedAvgTrainer(TrainerBase):
    name = "perfedavg"
    personalized = True

    def __init__(self, model, data: DeviceData, *, alpha: float = 0.03,
                 beta: float = 0.05, local_steps: int = 10,
                 clients_per_round: int = 10, batch_size: int = 20,
                 telemetry=None):
        super().__init__(model, data, batch_size, telemetry=telemetry)
        self.alpha, self.beta = alpha, beta
        self.m = int(min(clients_per_round, self.n_clients))

        def maml_steps(w, client, key):
            def body(p, k):
                k1, k2 = jax.random.split(k)
                x1, y1 = sample_batch(self.data, client, k1, batch_size)
                g1 = self.grad_fn(p, x1, y1, k1)
                p_in = jax.tree_util.tree_map(
                    lambda a, b: a - alpha * b, p, g1
                )
                x2, y2 = sample_batch(self.data, client, k2, batch_size)
                g2 = self.grad_fn(p_in, x2, y2, k2)
                p = jax.tree_util.tree_map(lambda a, b: a - beta * b, p, g2)
                return p, None

            keys = jax.random.split(key, local_steps)
            w, _ = jax.lax.scan(body, w, keys)
            return w

        def round_fn(w, sel, key):
            keys = jax.random.split(key, self.m)
            locals_ = jax.vmap(lambda c, k: maml_steps(w, c, k))(sel, keys)
            return jax.tree_util.tree_map(
                lambda ls: jnp.mean(ls, axis=0), locals_
            )

        self._round_fn = jax.jit(round_fn)

        def adapt(w, client, key):
            xb, yb = sample_batch(self.data, client, key, batch_size)
            g = self.grad_fn(w, xb, yb, key)
            return jax.tree_util.tree_map(lambda a, b: a - alpha * b, w, g)

        self._adapt_all = jax.jit(
            jax.vmap(adapt, in_axes=(None, 0, 0))
        )

    def init_state(self, key) -> PerFedAvgState:
        return PerFedAvgState(w=self.model.init(key))

    def round(self, state, rnd: int, rng: np.random.Generator):
        sel = self.select_clients(rnd, rng, self.m)
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        w = self._round_fn(state.w, jnp.asarray(sel), key)
        return PerFedAvgState(w=w), {
            "round": rnd,
            "comm_bytes": self.comm_bytes_per_round(self.m),
            **self.scenario_round_costs(sel),
        }

    def personalized_params(self, state):
        clients = jnp.arange(self.n_clients)
        keys = jax.random.split(jax.random.PRNGKey(1234), self.n_clients)
        return self._adapt_all(state.w, clients, keys)

    def global_params(self, state):
        return state.w
