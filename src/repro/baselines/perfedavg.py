"""Per-FedAvg (Fallah et al. 2020) — first-order MAML variant (FO).

Each local step: w⁺ = w − α∇f(w; ξ₁);  w ← w − β∇f(w⁺; ξ₂).
Personalized evaluation adapts the global model with one α-step on the
client's own data (the Per-FedAvg deployment protocol).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fl.base import TrainerBase, sample_batch


class PerFedAvgState(NamedTuple):
    w: dict


class PerFedAvgTrainer(TrainerBase):
    name = "perfedavg"
    personalized = True

    def __init__(self, model, data, *, alpha: float = 0.03,
                 beta: float = 0.05, local_steps: int = 10,
                 clients_per_round: int = 10, batch_size: int = 20,
                 store_capacity: int = 4096, prefetch: bool = False,
                 mesh=None, telemetry=None):
        # ``data``: stacked DeviceData or a ClientDataFactory (lazy
        # plane — datasets materialize through the bounded LRU store).
        super().__init__(model, data, batch_size, telemetry=telemetry,
                         store_capacity=store_capacity, prefetch=prefetch,
                         mesh=mesh)
        self.alpha, self.beta = alpha, beta
        self.m = int(min(clients_per_round, self.n_clients))

        def maml_steps(w, client, key, data=None):
            data_ = self.data if data is None else data

            def body(p, k):
                k1, k2 = jax.random.split(k)
                x1, y1 = sample_batch(data_, client, k1, batch_size)
                g1 = self.grad_fn(p, x1, y1, k1)
                p_in = jax.tree_util.tree_map(
                    lambda a, b: a - alpha * b, p, g1
                )
                x2, y2 = sample_batch(data_, client, k2, batch_size)
                g2 = self.grad_fn(p_in, x2, y2, k2)
                p = jax.tree_util.tree_map(lambda a, b: a - beta * b, p, g2)
                return p, None

            keys = jax.random.split(key, local_steps)
            w, _ = jax.lax.scan(body, w, keys)
            return w

        def round_fn(w, sel, key, data=None):
            # Lazy plane: ``sel`` are store slots, ``data`` the packed
            # block as a traced argument (dense: client ids + closure).
            data_ = self.data if data is None else data
            keys = jax.random.split(key, self.m)
            locals_ = jax.vmap(lambda c, k: maml_steps(w, c, k, data_))(
                sel, keys)
            return jax.tree_util.tree_map(
                lambda ls: jnp.mean(ls, axis=0), locals_
            )

        self._round_fn = jax.jit(round_fn)

        def adapt(w, client, key, data=None):
            data_ = self.data if data is None else data
            xb, yb = sample_batch(data_, client, key, batch_size)
            g = self.grad_fn(w, xb, yb, key)
            return jax.tree_util.tree_map(lambda a, b: a - alpha * b, w, g)

        self._adapt_all = jax.jit(
            jax.vmap(adapt, in_axes=(None, 0, 0))
        )
        # Row-based twin for the lazy plane's resident-set eval: adapt
        # over every store slot against the packed data block.
        self._adapt_rows = jax.jit(
            jax.vmap(adapt, in_axes=(None, 0, 0, None))
        )

    def init_state(self, key) -> PerFedAvgState:
        if self.store is not None:
            self._reset_store()
        return PerFedAvgState(w=self.model.init(key))

    def round(self, state, rnd: int, rng: np.random.Generator):
        sel = self.select_clients(rnd, rng, self.m)
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        if self.store is not None:
            _, slots = self._ensure_round(state, sel)
            w = self._round_fn(state.w, jnp.asarray(slots), key,
                               data=self.store.data)
        else:
            w = self._round_fn(state.w, jnp.asarray(sel), key)
        return PerFedAvgState(w=w), {
            "round": rnd,
            "comm_bytes": self.comm_bytes_per_round(self.m),
            **self.scenario_round_costs(sel),
        }

    def personalized_params(self, state):
        clients = jnp.arange(self.n_clients)
        keys = jax.random.split(jax.random.PRNGKey(1234), self.n_clients)
        return self._adapt_all(state.w, clients, keys)

    def _lazy_personalized_rows(self, state):
        # Per-slot deployment protocol (one α-step on the slot's own
        # rows); keys are slot-indexed, so this is the dense eval's
        # sampling scheme applied to the resident set.
        cap = self.store.capacity
        keys = jax.random.split(jax.random.PRNGKey(1234), cap)
        return self._adapt_rows(state.w, jnp.arange(cap), keys,
                                self.store.data)

    def global_params(self, state):
        return state.w
