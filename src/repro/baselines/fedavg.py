"""FedAvg (McMahan et al. 2017) — the paper's non-personalized benchmark."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fl.base import DeviceData, TrainerBase


class FedAvgState(NamedTuple):
    w: dict  # global model


class FedAvgTrainer(TrainerBase):
    name = "fedavg"
    personalized = False

    def __init__(self, model, data: DeviceData, *, lr: float = 0.05,
                 local_steps: int = 10, clients_per_round: int = 10,
                 batch_size: int = 20, telemetry=None):
        super().__init__(model, data, batch_size, telemetry=telemetry)
        self.lr = lr
        self.local_steps = local_steps
        self.m = int(min(clients_per_round, self.n_clients))
        local = self.make_local_sgd(lr, local_steps)

        def round_fn(w, sel, key):
            keys = jax.random.split(key, self.m)
            locals_ = jax.vmap(lambda c, k: local(w, c, k))(sel, keys)
            weights = self.data.n_train[sel].astype(jnp.float32)
            weights = weights / jnp.sum(weights)

            def avg(ls):
                ww = weights.reshape((-1,) + (1,) * (ls.ndim - 1))
                return jnp.sum(ww * ls, axis=0)

            return jax.tree_util.tree_map(avg, locals_)

        self._round_fn = jax.jit(round_fn)

    def init_state(self, key) -> FedAvgState:
        return FedAvgState(w=self.model.init(key))

    def round(self, state: FedAvgState, rnd: int, rng: np.random.Generator):
        sel = self.select_clients(rnd, rng, self.m)
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        w = self._round_fn(state.w, jnp.asarray(sel), key)
        return FedAvgState(w=w), {
            "round": rnd,
            "comm_bytes": self.comm_bytes_per_round(self.m),
            **self.scenario_round_costs(sel),
        }

    def global_params(self, state: FedAvgState):
        return state.w
