"""FedAvg (McMahan et al. 2017) — the paper's non-personalized benchmark."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fl.base import TrainerBase


class FedAvgState(NamedTuple):
    w: dict  # global model


class FedAvgTrainer(TrainerBase):
    name = "fedavg"
    personalized = False

    def __init__(self, model, data, *, lr: float = 0.05,
                 local_steps: int = 10, clients_per_round: int = 10,
                 batch_size: int = 20, store_capacity: int = 4096,
                 prefetch: bool = False, mesh=None, telemetry=None):
        # ``data`` is stacked DeviceData (dense plane) or a
        # ClientDataFactory (lazy plane: the base builds the bounded LRU
        # ClientStore; FedAvg keeps no per-client state, so the store
        # manages only the packed dataset block).
        super().__init__(model, data, batch_size, telemetry=telemetry,
                         store_capacity=store_capacity, prefetch=prefetch,
                         mesh=mesh)
        self.lr = lr
        self.local_steps = local_steps
        self.m = int(min(clients_per_round, self.n_clients))
        local = self.make_local_sgd(lr, local_steps)

        def round_fn(w, sel, key, data=None):
            # Dense: ``sel`` are client ids into the captured stack.
            # Lazy: ``sel`` are store slots and ``data`` the packed
            # block as a traced argument — same gather arithmetic, so
            # the two planes pin bit-identical (tests/test_lazy_plane).
            data_ = self.data if data is None else data
            keys = jax.random.split(key, self.m)
            locals_ = jax.vmap(lambda c, k: local(w, c, k, data_))(sel,
                                                                   keys)
            weights = data_.n_train[sel].astype(jnp.float32)
            weights = weights / jnp.sum(weights)

            def avg(ls):
                ww = weights.reshape((-1,) + (1,) * (ls.ndim - 1))
                return jnp.sum(ww * ls, axis=0)

            return jax.tree_util.tree_map(avg, locals_)

        self._round_fn = jax.jit(round_fn)

    def init_state(self, key) -> FedAvgState:
        if self.store is not None:
            self._reset_store()
        return FedAvgState(w=self.model.init(key))

    def round(self, state: FedAvgState, rnd: int, rng: np.random.Generator):
        sel = self.select_clients(rnd, rng, self.m)
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        if self.store is not None:
            _, slots = self._ensure_round(state, sel)
            w = self._round_fn(state.w, jnp.asarray(slots), key,
                               data=self.store.data)
        else:
            w = self._round_fn(state.w, jnp.asarray(sel), key)
        return FedAvgState(w=w), {
            "round": rnd,
            "comm_bytes": self.comm_bytes_per_round(self.m),
            **self.scenario_round_costs(sel),
        }

    def global_params(self, state: FedAvgState):
        return state.w
