"""pFedMe (Dinh et al. 2020) — Moreau-envelope personalization.

Per selected client, R local rounds; each solves the prox subproblem
θ̃ ≈ argmin_θ f_i(θ; ξ) + (λ/2)||θ − w_i||² with K inner SGD steps, then
w_i ← w_i − ηλ(w_i − θ̃). Server: w ← (1−β)w + β·mean(w_i).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..fl.base import TrainerBase, sample_batch


class PFedMeState(NamedTuple):
    w: dict


class PFedMeTrainer(TrainerBase):
    name = "pfedme"
    personalized = True

    def __init__(self, model, data, *, lam: float = 15.0,
                 inner_lr: float = 0.05, inner_steps: int = 5,
                 local_rounds: int = 5, eta: float = 0.05,
                 server_beta: float = 1.0, clients_per_round: int = 10,
                 batch_size: int = 20, store_capacity: int = 4096,
                 prefetch: bool = False, mesh=None, telemetry=None):
        # ``data``: stacked DeviceData or a ClientDataFactory (lazy
        # plane — datasets materialize through the bounded LRU store).
        super().__init__(model, data, batch_size, telemetry=telemetry,
                         store_capacity=store_capacity, prefetch=prefetch,
                         mesh=mesh)
        self.m = int(min(clients_per_round, self.n_clients))
        self.lam, self.inner_lr = lam, inner_lr
        self.inner_steps, self.local_rounds = inner_steps, local_rounds
        self.eta, self.server_beta = eta, server_beta

        def prox_solve(w_i, client, key, data=None):
            """K inner SGD steps on h(θ) = f(θ; ξ) + λ/2||θ − w_i||²,
            with a fixed minibatch ξ per prox solve (pFedMe's sampling)."""
            data_ = self.data if data is None else data
            xb, yb = sample_batch(data_, client, key, batch_size)

            def h(theta):
                return (self.loss_fn(theta, xb, yb, key)
                        + 0.5 * lam * _sqdist(theta, w_i))

            theta = w_i
            def body(theta, _):
                g = jax.grad(h)(theta)
                theta = jax.tree_util.tree_map(
                    lambda a, b: a - inner_lr * b, theta, g
                )
                return theta, None

            theta, _ = jax.lax.scan(body, theta, jnp.arange(inner_steps))
            return theta

        def local(w, client, key, data=None):
            def body(w_i, k):
                theta = prox_solve(w_i, client, k, data)
                w_i = jax.tree_util.tree_map(
                    lambda a, t: a - eta * lam * (a - t), w_i, theta
                )
                return w_i, None

            keys = jax.random.split(key, local_rounds)
            w_i, _ = jax.lax.scan(body, w, keys)
            return w_i

        def round_fn(w, sel, key, data=None):
            # Lazy plane: ``sel`` are store slots, ``data`` the packed
            # block as a traced argument (dense: client ids + closure).
            keys = jax.random.split(key, self.m)
            w_locals = jax.vmap(lambda c, k: local(w, c, k, data))(sel,
                                                                   keys)
            w_avg = jax.tree_util.tree_map(
                lambda ls: jnp.mean(ls, axis=0), w_locals
            )
            return jax.tree_util.tree_map(
                lambda a, b: (1.0 - server_beta) * a + server_beta * b,
                w, w_avg,
            )

        self._round_fn = jax.jit(round_fn)
        self._prox_all = jax.jit(
            jax.vmap(prox_solve, in_axes=(None, 0, 0))
        )
        # Row-based twin for the lazy plane's resident-set eval.
        self._prox_rows = jax.jit(
            jax.vmap(prox_solve, in_axes=(None, 0, 0, None))
        )

    def init_state(self, key) -> PFedMeState:
        if self.store is not None:
            self._reset_store()
        return PFedMeState(w=self.model.init(key))

    def round(self, state, rnd: int, rng: np.random.Generator):
        sel = self.select_clients(rnd, rng, self.m)
        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        if self.store is not None:
            _, slots = self._ensure_round(state, sel)
            w = self._round_fn(state.w, jnp.asarray(slots), key,
                               data=self.store.data)
        else:
            w = self._round_fn(state.w, jnp.asarray(sel), key)
        return PFedMeState(w=w), {
            "round": rnd,
            "comm_bytes": self.comm_bytes_per_round(self.m),
            **self.scenario_round_costs(sel),
        }

    def personalized_params(self, state):
        clients = jnp.arange(self.n_clients)
        keys = jax.random.split(jax.random.PRNGKey(99), self.n_clients)
        return self._prox_all(state.w, clients, keys)

    def _lazy_personalized_rows(self, state):
        # Per-slot Moreau-envelope personalization against the packed
        # data block (keys slot-indexed).
        cap = self.store.capacity
        keys = jax.random.split(jax.random.PRNGKey(99), cap)
        return self._prox_rows(state.w, jnp.arange(cap), keys,
                               self.store.data)

    def global_params(self, state):
        return state.w


def _sqdist(a, b):
    leaves = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(jnp.square(x - y)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves)
