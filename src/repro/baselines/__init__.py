"""Baseline FL algorithms the paper compares against (§5) — all fully
implemented: FedAvg, Per-FedAvg(FO), pFedMe, Ditto, APFL, plus Walkman
(the closest ADMM prior, §2)."""
from .fedavg import FedAvgTrainer  # noqa: F401
from .perfedavg import PerFedAvgTrainer  # noqa: F401
from .pfedme import PFedMeTrainer  # noqa: F401
from .ditto import DittoTrainer  # noqa: F401
from .apfl import APFLTrainer  # noqa: F401
from .walkman_trainer import WalkmanTrainer  # noqa: F401

REGISTRY = {
    "fedavg": FedAvgTrainer,
    "perfedavg": PerFedAvgTrainer,
    "pfedme": PFedMeTrainer,
    "ditto": DittoTrainer,
    "apfl": APFLTrainer,
    "walkman": WalkmanTrainer,
}


def get_baseline(name: str):
    try:
        return REGISTRY[name.lower()]
    except KeyError as e:
        raise ValueError(
            f"unknown baseline {name!r}; options: {sorted(REGISTRY)}"
        ) from e
