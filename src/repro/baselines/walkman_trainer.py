"""Walkman trainer — random-walk *consensus* ADMM (paper [35] ablation).

Same mobile-server random-walk control plane as RWSADMM, but the update
rule enforces consensus instead of the paper's hard-inequality proximity.
Isolates the contribution of the personalization mechanism.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import markov, walkman
from ..fl.base import DeviceData, TrainerBase, sample_batch


class WalkmanState(NamedTuple):
    clients: walkman.WalkmanClientState  # stacked (n, ...)
    y: dict
    round: jnp.ndarray


class WalkmanTrainer(TrainerBase):
    name = "walkman"
    personalized = False
    # Walkman's consensus state is a stacked (n, …) client pytree with
    # no store-backed round body — dense plane only.
    lazy_capable = False

    def __init__(self, model, data: DeviceData, *, beta: float = 3.0,
                 min_degree: int = 5, regen_every: int = 10,
                 batch_size: int = 20, scenario=None, telemetry=None,
                 seed: int = 0):
        super().__init__(model, data, batch_size, telemetry=telemetry)
        self.beta = beta
        self._seed = int(seed)
        self._min_degree = int(min_degree)
        self._regen_every = int(regen_every)
        self.attach_scenario(scenario, seed=seed)

        def round_fn(clients, y, i_k, key):
            x_i = jax.tree_util.tree_map(lambda l: l[i_k], clients.x)
            z_i = jax.tree_util.tree_map(lambda l: l[i_k], clients.z)
            xb, yb = sample_batch(self.data, i_k, key, batch_size)
            # Walkman's gradient-type update linearizes at the walker
            # token y (Walkman-B in [35]) — more stable than at x_i.
            loss, g = self.value_and_grad_fn(y, xb, yb, key)
            new_c, c_new, c_old = walkman.client_round(
                walkman.WalkmanClientState(x_i, z_i), y, g, beta
            )
            y_new = walkman.y_update(y, c_new, c_old, self.n_clients)
            clients = walkman.WalkmanClientState(
                x=jax.tree_util.tree_map(
                    lambda full, new: full.at[i_k].set(new),
                    clients.x, new_c.x),
                z=jax.tree_util.tree_map(
                    lambda full, new: full.at[i_k].set(new),
                    clients.z, new_c.z),
            )
            return clients, y_new, loss

        self._round_fn = jax.jit(round_fn)

    def init_state(self, key) -> WalkmanState:
        params = self.model.init(key)
        clients, server = walkman.init_states(params, self.n_clients)
        # Warm start x_i = y = init (same rationale as RWSADMM warm init).
        stacked = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (self.n_clients,) + l.shape),
            params,
        )
        clients = walkman.WalkmanClientState(x=stacked, z=clients.z)
        return WalkmanState(clients=clients, y=params,
                            round=jnp.asarray(0, jnp.int32))

    def attach_scenario(self, spec, seed: int | None = None) -> None:
        """Walkman walks the same environment as RWSADMM: the scenario
        drives its dynamic graph (mobility + link dropouts) via the
        shared graph-walking attach path."""
        seed = self._seed if seed is None else seed
        self._seed = seed   # later re-attaches reuse the latest seed
        self._attach_walking_scenario(
            spec, seed, min_degree=self._min_degree,
            regen_every=self._regen_every,
        )

    def round(self, state, rnd: int, rng: np.random.Generator):
        graph = self.dyn_graph.step() if rnd > 0 else self.dyn_graph.current()
        i_k = self.walker.step(graph) if rnd > 0 else self.walker.position
        key = markov.round_key(rng)   # shared eager/scan key derivation
        clients, y, loss = self._round_fn(
            state.clients, state.y, jnp.asarray(i_k), key
        )
        # Walkman exchanges the token with the one client the server is
        # physically at: a wired/near-field hand-off, not a radio hop,
        # so the wireless ledger prices it at zero (the vehicle's
        # movement is the transport). Bytes still move — comm_bytes
        # counts the exchange; latency/energy count radio only.
        return WalkmanState(clients, y, state.round + 1), {
            "round": rnd,
            "client": int(i_k),
            "train_loss": float(loss),
            "comm_bytes": self.comm_bytes_per_round(1),
            "latency_s": 0.0,
            "energy_j": 0.0,
        }

    def global_params(self, state):
        return state.y
