"""npz-based pytree checkpointing.

Leaves are flattened with '/'-joined key paths so any nested dict /
NamedTuple state (RWSADMM client/server states included) round-trips
without pickling. Suitable for the mobile-server token handoff too: the
y-token IS a checkpoint.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(path: str, tree: PyTree, step: int | None = None) -> str:
    """Save a pytree to ``path`` (.npz). Returns the path written."""
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(kp)] = np.asarray(leaf)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)
    if step is not None:
        meta = path + ".meta.json"
        with open(meta, "w") as f:
            json.dump({"step": step}, f)
    return path


def load_pytree(path: str, template: PyTree) -> PyTree:
    """Load into the structure of ``template`` (shapes must match)."""
    data = np.load(path)
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for kp, leaf in leaves_kp:
        key = _path_str(kp)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != {leaf.shape}"
            )
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def save_client_store(path: str, store) -> str:
    """Save a :class:`~repro.fl.client_store.ClientStore`'s host-side
    bookkeeping (slot map, LRU order, counters, spill buffer) to ``path``
    (.npz). Pairs with the pytree checkpoint of the trainer state: the
    packed client rows live in ``state.clients`` and are saved by
    :func:`save_pytree`; this captures everything else the store needs
    to resume mid-run, including evicted (spilled) client rows."""
    sd = store.state_dict()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **{k: np.asarray(v) for k, v in sd.items()})
    return path


def load_client_store(path: str, store) -> None:
    """Restore ``store`` (already constructed with the same factory and
    capacity) from a file written by :func:`save_client_store`. Packed
    dataset rows for resident clients are re-materialized from the
    store's factory; the caller restores the packed x/z rows separately
    via :func:`load_pytree` on the trainer state."""
    with np.load(path) as data:
        store.load_state_dict({k: data[k] for k in data.files})


def restore_latest(directory: str, template: PyTree,
                   pattern: str = r"ckpt_(\d+)\.npz"):
    """Restore the highest-step checkpoint in ``directory`` or None."""
    if not os.path.isdir(directory):
        return None, -1
    best, best_step = None, -1
    for fn in os.listdir(directory):
        m = re.fullmatch(pattern, fn)
        if m and int(m.group(1)) > best_step:
            best, best_step = fn, int(m.group(1))
    if best is None:
        return None, -1
    return load_pytree(os.path.join(directory, best), template), best_step
