from .checkpoint import load_pytree, restore_latest, save_pytree  # noqa: F401
