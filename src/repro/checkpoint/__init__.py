from .checkpoint import (  # noqa: F401
    load_client_store,
    load_pytree,
    restore_latest,
    save_client_store,
    save_pytree,
)
