"""Mobility models: client positions → per-round connectivity graphs.

All models run host-side (control plane) and share one contract:

    reset(rng) -> ClientGraph     # round-0 graph
    step(rng)  -> ClientGraph     # advance one round

Connectivity for the smooth models derives from a radio range — an edge
(i, j) exists iff ‖p_i − p_j‖ ≤ radio_range — then a ``min_degree``
nearest-neighbor floor and a deterministic connected-components patch
keep the walk chain irreducible (Assumption 3.1), matching the paper's
"at least 5 neighboring nodes" App. D.2 construction.

``static_regen`` reproduces the seed repo's ``DynamicGraph`` draw
sequence bit-for-bit: i.i.d. ``random_geometric_graph`` redraws every
``regen_every`` rounds and *no* RNG consumption in between.
"""
from __future__ import annotations

from typing import Protocol

import numpy as np

from ..core.graph import (
    ClientGraph,
    pairwise_sq_dists,
    patch_connected,
    random_geometric_graph,
    seed_sq_dist_cache,
)
from .config import MobilityConfig


class MobilityModel(Protocol):
    def reset(self, rng: np.random.Generator) -> ClientGraph: ...

    def step(self, rng: np.random.Generator) -> ClientGraph: ...


def range_graph(pos: np.ndarray, radio_range: float,
                min_degree: int) -> ClientGraph:
    """Geometric connectivity: radio-range disk graph with a min-degree
    patch (nodes below the degree floor get their nearest neighbors
    linked in), patched connected. Deterministic given positions; runs
    every round for the smooth mobility models, so the k-NN work is
    restricted to the deficient rows only.
    """
    n = pos.shape[0]
    d2 = pairwise_sq_dists(pos)
    adj = d2 <= radio_range * radio_range
    np.fill_diagonal(adj, False)
    k = min(min_degree, n - 1)
    deficient = np.flatnonzero(adj.sum(axis=1) < k)
    if len(deficient) and k > 0:
        nearest = np.argpartition(d2[deficient], k - 1, axis=1)[:, :k]
        adj[deficient[:, None], nearest] = True
        adj[nearest, deficient[:, None]] = True
    adj = patch_connected(adj, d2)
    graph = ClientGraph(adjacency=adj, positions=pos)
    seed_sq_dist_cache(graph, d2)
    return graph


class StaticRegenMobility:
    """The seed behavior: positions redrawn i.i.d. every ``regen_every``
    rounds (``core.graph.DynamicGraph``), static in between."""

    def __init__(self, n: int, cfg: MobilityConfig):
        self.n = n
        self.cfg = cfg
        self.regen_every = max(1, cfg.regen_every)
        self._round = 0
        self.n_regens = 0
        self.graph: ClientGraph | None = None

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        self._round = 0
        self.n_regens = 0
        self.graph = random_geometric_graph(self.n, self.cfg.min_degree, rng)
        return self.graph

    def step(self, rng: np.random.Generator) -> ClientGraph:
        self._round += 1
        if self._round % self.regen_every == 0:
            self.graph = random_geometric_graph(
                self.n, self.cfg.min_degree, rng
            )
            self.n_regens += 1
        return self.graph


class RandomWaypointMobility:
    """Random waypoint: each client walks toward a uniform waypoint at a
    per-leg speed ∈ [speed_min, speed_max], pauses ``pause_rounds`` on
    arrival, then draws the next leg. The classic ad-hoc-network model
    (Johnson & Maltz); positions move ≤ speed_max per round, so graphs
    evolve smoothly instead of redrawing."""

    def __init__(self, n: int, cfg: MobilityConfig):
        self.n = n
        self.cfg = cfg

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        self.waypoint = rng.uniform(0.0, 1.0, size=(self.n, 2))
        self.speed = rng.uniform(self.cfg.speed_min, self.cfg.speed_max,
                                 size=self.n)
        self.pause = np.zeros(self.n, dtype=np.int64)
        return self._graph()

    def step(self, rng: np.random.Generator) -> ClientGraph:
        delta = self.waypoint - self.pos
        dist = np.linalg.norm(delta, axis=1)
        moving = (self.pause == 0) & (dist > 1e-12)
        frac = np.where(dist > 1e-12,
                        np.minimum(1.0, self.speed / np.maximum(dist, 1e-12)),
                        0.0)
        self.pos = self.pos + (moving * frac)[:, None] * delta
        arrived = moving & (frac >= 1.0)
        self.pause = np.maximum(self.pause - 1, 0)
        self.pause[arrived] = self.cfg.pause_rounds
        # Draw the next leg for every arrival (boolean indexing consumes
        # the RNG in client order, so replays are deterministic).
        if arrived.any():
            k = int(arrived.sum())
            self.waypoint[arrived] = rng.uniform(0.0, 1.0, size=(k, 2))
            self.speed[arrived] = rng.uniform(
                self.cfg.speed_min, self.cfg.speed_max, size=k)
        return self._graph()

    def _graph(self) -> ClientGraph:
        return range_graph(self.pos, self.cfg.radio_range,
                           self.cfg.min_degree)


class GaussMarkovMobility:
    """Gauss-Markov: temporally correlated velocities,

        v_{t+1} = α v_t + (1 − α) v̄_i + σ √(1 − α²) w_t,

    with per-client mean velocities v̄_i (magnitude ``mean_speed``,
    uniform heading) and boundary reflection. α → 1 gives straight-line
    motion, α → 0 memoryless Brownian drift (Camp et al. survey §2.5)."""

    def __init__(self, n: int, cfg: MobilityConfig):
        self.n = n
        self.cfg = cfg

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        heading = rng.uniform(0.0, 2 * np.pi, size=self.n)
        self.mean_v = self.cfg.mean_speed * np.stack(
            [np.cos(heading), np.sin(heading)], axis=1)
        self.vel = self.mean_v.copy()
        return self._graph()

    def step(self, rng: np.random.Generator) -> ClientGraph:
        a, s = self.cfg.alpha, self.cfg.sigma_speed
        noise = rng.normal(size=(self.n, 2))
        self.vel = (a * self.vel + (1.0 - a) * self.mean_v
                    + s * np.sqrt(max(1.0 - a * a, 0.0)) * noise)
        self.pos = self.pos + self.vel
        # Reflect at the unit-square boundary (flip offending velocity
        # components; mean heading reflects too so clients don't fight
        # the wall forever).
        for lo, hi in ((0.0, 1.0),):
            under, over = self.pos < lo, self.pos > hi
            self.pos = np.where(under, 2 * lo - self.pos, self.pos)
            self.pos = np.where(over, 2 * hi - self.pos, self.pos)
            flip = under | over
            self.vel = np.where(flip, -self.vel, self.vel)
            self.mean_v = np.where(flip, -self.mean_v, self.mean_v)
        self.pos = np.clip(self.pos, 0.0, 1.0)
        return self._graph()

    def _graph(self) -> ClientGraph:
        return range_graph(self.pos, self.cfg.radio_range,
                           self.cfg.min_degree)


_MODELS = {
    "static_regen": StaticRegenMobility,
    "random_waypoint": RandomWaypointMobility,
    "gauss_markov": GaussMarkovMobility,
}


def build_mobility(n: int, cfg: MobilityConfig) -> MobilityModel:
    try:
        cls = _MODELS[cfg.model]
    except KeyError:
        raise ValueError(
            f"unknown mobility model {cfg.model!r}; "
            f"known: {sorted(_MODELS)}") from None
    return cls(n, cfg)
