"""Mobility models: client positions → per-round connectivity graphs.

All models run host-side (control plane) and share one contract:

    reset(rng) -> ClientGraph     # round-0 graph
    step(rng)  -> ClientGraph     # advance one round
    rollout(rounds, rng) -> list[ClientGraph]   # batched step×rounds

plus a positions-only lane for consumers that never touch connectivity
(the FedAvg-family base-station baselines — ``scenarios.Scenario``'s
``positions_only`` mode):

    reset_positions(rng) -> (n, 2)
    step_positions(rng)  -> (n, 2)

``rollout`` and the positions-only lane consume the RNG exactly as the
same number of ``step()`` calls would, so every lane replays every other
lane draw-for-draw (pinned in ``tests/test_scenario_rollout.py``).
``rollout`` batches the O(n²) work — pairwise distances, range/kNN
adjacency, degree patching, connectivity checks — across the whole
window in a few vectorized passes; position *advancement* stays a cheap
O(n) per-round recurrence (it is inherently sequential: waypoint
arrivals and boundary reflections depend on the previous round).

Connectivity for the smooth models derives from a radio range — an edge
(i, j) exists iff ‖p_i − p_j‖ ≤ radio_range — then a ``min_degree``
nearest-neighbor floor and a deterministic connected-components patch
keep the walk chain irreducible (Assumption 3.1), matching the paper's
"at least 5 neighboring nodes" App. D.2 construction.

``static_regen`` reproduces the seed repo's ``DynamicGraph`` draw
sequence bit-for-bit: i.i.d. ``random_geometric_graph`` redraws every
``regen_every`` rounds and *no* RNG consumption in between.
"""
from __future__ import annotations

from typing import Protocol

import numpy as np

from ..core.graph import (
    ClientGraph,
    NeighborGraph,
    graphs_from_stack,
    knn_adjacency,
    neighbor_graph_from_pairs,
    pair_sq_dists,
    pairwise_sq_dists,
    pairwise_sq_dists_batch,
    patch_connected,
    patch_connected_lists,
    random_geometric_graph,
    seed_sq_dist_cache,
    segmented_arange,
)
from .config import MobilityConfig

GRAPH_BACKENDS = ("dense", "sparse")


class MobilityModel(Protocol):
    def reset(self, rng: np.random.Generator) -> ClientGraph: ...

    def step(self, rng: np.random.Generator) -> ClientGraph: ...

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]: ...

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray: ...

    def step_positions(self, rng: np.random.Generator) -> np.ndarray: ...


def range_graph(pos: np.ndarray, radio_range: float,
                min_degree: int) -> ClientGraph:
    """Geometric connectivity: radio-range disk graph with a min-degree
    patch (nodes below the degree floor get their nearest neighbors
    linked in), patched connected. Deterministic given positions; runs
    every round for the smooth mobility models, so the k-NN work is
    restricted to the deficient rows only.
    """
    n = pos.shape[0]
    d2 = pairwise_sq_dists(pos)
    adj = d2 <= radio_range * radio_range
    np.fill_diagonal(adj, False)
    k = min(min_degree, n - 1)
    deficient = np.flatnonzero(adj.sum(axis=1) < k)
    if len(deficient) and k > 0:
        nearest = np.argpartition(d2[deficient], k - 1, axis=1)[:, :k]
        adj[deficient[:, None], nearest] = True
        adj[nearest, deficient[:, None]] = True
    adj = patch_connected(adj, d2)
    graph = ClientGraph(adjacency=adj, positions=pos)
    seed_sq_dist_cache(graph, d2)
    return graph


def range_graphs_batch(pos: np.ndarray, radio_range: float,
                       min_degree: int) -> list[ClientGraph]:
    """Batched :func:`range_graph`: R graphs from (R, n, 2) positions.

    One (R, n, n) distance pass, one vectorized degree patch over all
    deficient rows of all rounds at once, one batched connectivity
    check; only rounds that actually come out disconnected pay the
    per-graph component patch. Deterministic and bit-identical to R
    per-round ``range_graph`` calls (same argpartition per row, same
    patch order) — pinned in ``tests/test_scenario_rollout.py``.
    """
    n = pos.shape[1]
    d2 = pairwise_sq_dists_batch(pos)
    adj = d2 <= radio_range * radio_range       # inf diagonal → False
    k = min(min_degree, n - 1)
    if k > 0:
        r_idx, i_idx = np.nonzero(adj.sum(axis=2) < k)
        if len(r_idx):
            nearest = np.argpartition(d2[r_idx, i_idx], k - 1,
                                      axis=1)[:, :k]
            adj[r_idx[:, None], i_idx[:, None], nearest] = True
            adj[r_idx[:, None], nearest, i_idx[:, None]] = True
    return graphs_from_stack(adj, d2, pos)


# ---------------------------------------------------------------------------
# Sparse backend: grid-bucket (cell-list) neighbor search.
#
# The dense lane's O(n²) distance matrix is what blocks large n. The
# sparse lane buckets positions into a uniform grid of cells no smaller
# than the search radius, so every within-radius pair lives in a 3×3
# cell neighborhood: candidate generation is O(n · local density), and
# the resulting graphs are capped-degree neighbor lists — O(n·k) end to
# end. Where the construction is RNG-free (it is: graphs are a
# deterministic function of positions) the sparse graphs are pinned
# bit-identical to the dense lane at small n
# (``tests/test_sparse_backend.py``).
# ---------------------------------------------------------------------------


class _CellGrid:
    """Uniform unit-square grid with CSR-style cell membership."""

    def __init__(self, pos: np.ndarray, cell_size: float):
        self.pos = pos
        self.nc = max(1, int(np.floor(1.0 / max(cell_size, 1e-9))))
        self.side = 1.0 / self.nc
        self.cx = np.clip((pos[:, 0] * self.nc).astype(np.int64),
                          0, self.nc - 1)
        self.cy = np.clip((pos[:, 1] * self.nc).astype(np.int64),
                          0, self.nc - 1)
        cid = self.cx * self.nc + self.cy
        self.order = np.argsort(cid, kind="stable")
        self._sorted_cid = cid[self.order]

    def _cell_bounds(self, cids: np.ndarray):
        starts = np.searchsorted(self._sorted_cid, cids)
        ends = np.searchsorted(self._sorted_cid, cids, side="right")
        return starts, ends

    def candidate_pairs(self, max_pairs: int = 60_000_000
                        ) -> tuple[np.ndarray, np.ndarray]:
        """Directed candidate pairs (i, j), i ≠ j, over every node's 3×3
        cell neighborhood (symmetric by construction). Raises when the
        candidate count explodes — the signal that the radio range is
        far too large for the node density (the sparse backend expects a
        local graph; shrink ``radio_range`` or use the dense lane)."""
        n = self.pos.shape[0]
        nc = self.nc
        pis, pjs = [], []
        total = 0
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                nx, ny = self.cx + dx, self.cy + dy
                ok = (nx >= 0) & (nx < nc) & (ny >= 0) & (ny < nc)
                ncid = np.where(ok, nx * nc + ny, 0)
                starts, ends = self._cell_bounds(ncid)
                cnt = np.where(ok, ends - starts, 0)
                block = int(cnt.sum())
                total += block
                if total > max_pairs:
                    raise ValueError(
                        f"cell-list search would generate > {max_pairs} "
                        "candidate pairs — the search radius is too "
                        "large for n (the graph is effectively dense). "
                        "Reduce radio_range (or min_degree) for the "
                        "sparse backend, or use graph_backend='dense'.")
                if not block:
                    continue
                pi = np.repeat(np.arange(n), cnt)
                within = segmented_arange(cnt)
                pj = self.order[np.repeat(starts, cnt) + within]
                keep = pi != pj
                pis.append(pi[keep])
                pjs.append(pj[keep])
        if not pis:
            e = np.zeros(0, dtype=np.int64)
            return e, e.copy()
        return np.concatenate(pis), np.concatenate(pjs)

    def ring_nodes(self, i: int, r: int) -> np.ndarray:
        """Nodes in cells at Chebyshev cell-distance exactly ``r`` from
        node i's cell (every one of them is ≥ (r−1)·side away)."""
        cxi, cyi = int(self.cx[i]), int(self.cy[i])
        if r == 0:
            cells = [(cxi, cyi)]
        else:
            cells = []
            for x in range(cxi - r, cxi + r + 1):
                for y in (cyi - r, cyi + r):
                    cells.append((x, y))
            for y in range(cyi - r + 1, cyi + r):
                for x in (cxi - r, cxi + r):
                    cells.append((x, y))
        cells = [(x, y) for x, y in cells
                 if 0 <= x < self.nc and 0 <= y < self.nc]
        if not cells:
            return np.zeros(0, dtype=np.int64)
        cids = np.asarray([x * self.nc + y for x, y in cells])
        starts, ends = self._cell_bounds(cids)
        return np.concatenate([self.order[s:e]
                               for s, e in zip(starts, ends)]) \
            if len(cids) else np.zeros(0, dtype=np.int64)

    def exact_knn(self, i: int, k: int) -> np.ndarray:
        """The k nearest neighbors of node i, exactly: expand cell
        rings until the k-th candidate is provably closer than anything
        unexamined (ring r+1 nodes are ≥ r·side away)."""
        cand: list[np.ndarray] = []
        count = 0
        r = 0
        max_r = 2 * self.nc + 1
        while True:
            ring = self.ring_nodes(i, r)
            ring = ring[ring != i]
            if len(ring):
                cand.append(ring)
                count += len(ring)
            if count >= k:
                ids = np.concatenate(cand)
                d2 = pair_sq_dists(self.pos, np.full(len(ids), i), ids)
                kth = np.partition(d2, k - 1)[k - 1]
                if kth < (r * self.side) ** 2 or r > max_r:
                    nearest = ids[np.argpartition(d2, k - 1)[:k]]
                    return nearest
            elif r > max_r:
                return (np.concatenate(cand) if cand
                        else np.zeros(0, dtype=np.int64))
            r += 1


def _cap_degree_pairs(n: int, pi, pj, d2, k_max: int):
    """Truncate per-node degree to the ``k_max`` nearest, then drop the
    asymmetric leftovers (an edge survives only if both endpoints keep
    it) so the graph stays undirected. Returns (i, j)-sorted pairs."""
    order = np.lexsort((pj, pi))
    pi, pj, d2 = pi[order], pj[order], d2[order]
    deg = np.bincount(pi, minlength=n)
    if not len(pi) or deg.max() <= k_max:
        return pi, pj, d2
    by_dist = np.lexsort((d2, pi))
    rank = np.empty(len(pi), dtype=np.int64)
    rank[by_dist] = segmented_arange(deg)
    keep_dir = rank < k_max
    key = pi * n + pj
    ridx = np.searchsorted(key, pj * n + pi)
    keep = keep_dir & keep_dir[ridx]
    return pi[keep], pj[keep], d2[keep]


def _patch_min_degree_lists(nbrs, mask, nd2, pos, grid: _CellGrid,
                            k: int):
    """Link each below-floor node to its exact k nearest neighbors
    (expanding-ring search; deficient rows only — the same semantics as
    the dense lane's argpartition patch). Returns (nbrs, mask, nd2)."""
    if k <= 0:
        return nbrs, mask, nd2
    from ..core.graph import _insert_edge_lists

    deg = mask.sum(axis=1)
    for i in np.flatnonzero(deg < k):
        for j in grid.exact_knn(int(i), k):
            e2 = float(pair_sq_dists(pos, np.asarray([i]),
                                     np.asarray([j]))[0])
            nbrs, mask, nd2 = _insert_edge_lists(
                nbrs, mask, nd2, int(i), int(j), e2)
    return nbrs, mask, nd2


def sparse_range_graph(pos: np.ndarray, radio_range: float,
                       min_degree: int, k_max: int) -> NeighborGraph:
    """Neighbor-list twin of :func:`range_graph`: radio-range disk graph
    from a cell-list search (no O(n²) distance matrix), the same
    min-degree patch (exact k nearest for deficient nodes, via expanding
    cell rings), the same deterministic connectivity patch. With
    ``k_max`` ≥ the realized max degree this is edge-for-edge identical
    to the dense lane (pinned); tighter ``k_max`` keeps only each node's
    nearest ``k_max`` in-range links — the O(n·k) memory cap."""
    n = pos.shape[0]
    grid = _CellGrid(pos, radio_range)
    pi, pj = grid.candidate_pairs()
    d2 = pair_sq_dists(pos, pi, pj)
    keep = d2 <= radio_range * radio_range
    pi, pj, d2 = pi[keep], pj[keep], d2[keep]
    pi, pj, d2 = _cap_degree_pairs(n, pi, pj, d2, k_max)
    graph = neighbor_graph_from_pairs(n, pi, pj, d2, pos,
                                      assume_sorted=True)
    nbrs, mask, nd2 = _patch_min_degree_lists(
        graph.nbrs, graph.nbr_mask, graph.nbr_d2, pos, grid,
        min(min_degree, n - 1))
    nbrs, mask, nd2 = patch_connected_lists(nbrs, mask, nd2, pos)
    return NeighborGraph(nbrs=nbrs, nbr_mask=mask, positions=pos,
                         nbr_d2=nd2)


def sparse_knn_graph(pos: np.ndarray, min_degree: int,
                     k_max: int) -> NeighborGraph:
    """Neighbor-list twin of ``random_geometric_graph``'s body for given
    positions: symmetrized k-nearest-neighbor adjacency + connectivity
    patch, built from a cell-list search sized so the 3×3 block around a
    node is expected to hold ≳ 9·(k+2) candidates. Nodes whose k-th
    candidate isn't provably nearest fall back to the exact
    expanding-ring search. Bit-identical graphs to the dense lane
    (``knn_adjacency`` + ``patch_connected``) — pinned."""
    n = pos.shape[0]
    k = min(min_degree, n - 1)
    if k <= 0:
        e = np.zeros(0, dtype=np.int64)
        g = neighbor_graph_from_pairs(n, e, e.copy(),
                                      np.zeros(0), pos)
        nbrs, mask, nd2 = patch_connected_lists(
            g.nbrs, g.nbr_mask, g.nbr_d2, pos)
        return NeighborGraph(nbrs=nbrs, nbr_mask=mask, positions=pos,
                             nbr_d2=nd2)
    cell = min(max(np.sqrt((k + 2.0) / n), 1e-3), 1.0)
    grid = _CellGrid(pos, cell)
    pi, pj = grid.candidate_pairs()
    d2 = pair_sq_dists(pos, pi, pj)
    by_dist = np.lexsort((d2, pi))
    pi, pj, d2 = pi[by_dist], pj[by_dist], d2[by_dist]
    cnt = np.bincount(pi, minlength=n)
    rank = segmented_arange(cnt)
    take = rank < k
    # Safe iff the node has ≥ k candidates and its k-th candidate beats
    # the 1-cell-gap distance floor of everything unexamined.
    kth = np.full(n, np.inf)
    kth[pi[rank == k - 1]] = d2[rank == k - 1]
    safe = (cnt >= k) & (kth < grid.side ** 2)
    take &= safe[pi]
    ei = [pi[take]]
    ej = [pj[take]]
    for i in np.flatnonzero(~safe):
        nb = grid.exact_knn(int(i), k)
        ei.append(np.full(len(nb), i, dtype=np.int64))
        ej.append(nb.astype(np.int64))
    ei = np.concatenate(ei)
    ej = np.concatenate(ej)
    # Symmetrize (union of directed kNN edges), dedup via canonical keys.
    keys = np.unique(np.concatenate([ei * n + ej, ej * n + ei]))
    pi, pj = keys // n, keys % n
    d2u = pair_sq_dists(pos, pi, pj)
    # Apply the degree cap (hub nodes of the symmetrized union collect
    # every incoming kNN edge), then re-floor: a node whose own kNN
    # edges were dropped by a capped hub gets its k nearest re-linked —
    # so the cap stays soft exactly as on the range lane. With k_max ≥
    # the realized max degree (the dense-parity regime) both steps are
    # no-ops.
    pi, pj, d2u = _cap_degree_pairs(n, pi, pj, d2u, k_max)
    graph = neighbor_graph_from_pairs(n, pi, pj, d2u, pos,
                                      assume_sorted=True)
    nbrs, mask, nd2 = _patch_min_degree_lists(
        graph.nbrs, graph.nbr_mask, graph.nbr_d2, pos, grid, k)
    nbrs, mask, nd2 = patch_connected_lists(nbrs, mask, nd2, pos)
    return NeighborGraph(nbrs=nbrs, nbr_mask=mask, positions=pos,
                         nbr_d2=nd2)


def _knn_graphs_batch(pos: np.ndarray, min_degree: int) -> list[ClientGraph]:
    """Batched ``random_geometric_graph`` body for pre-drawn positions:
    kNN adjacency + connectivity patch per frame, distances in one pass.
    Bit-identical to per-frame construction (rows partition independently).
    """
    d2 = pairwise_sq_dists_batch(pos)
    adj = np.stack([knn_adjacency(d2[r], min_degree)
                    for r in range(pos.shape[0])])
    return graphs_from_stack(adj, d2, pos)


class StaticRegenMobility:
    """The seed behavior: positions redrawn i.i.d. every ``regen_every``
    rounds (``core.graph.DynamicGraph``), static in between."""

    def __init__(self, n: int, cfg: MobilityConfig,
                 backend: str = "dense", k_max: int = 64):
        self.n = n
        self.cfg = cfg
        self.backend = backend
        self.k_max = k_max
        self.regen_every = max(1, cfg.regen_every)
        self._round = 0
        self.n_regens = 0
        self.graph: ClientGraph | NeighborGraph | None = None
        self.pos: np.ndarray | None = None

    def _regen(self, rng: np.random.Generator):
        """One i.i.d. redraw. Both backends consume the RNG identically
        (one (n, 2) uniform draw; graph construction is RNG-free)."""
        if self.backend == "sparse":
            pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
            return sparse_knn_graph(pos, self.cfg.min_degree, self.k_max)
        return random_geometric_graph(self.n, self.cfg.min_degree, rng)

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        self._round = 0
        self.n_regens = 0
        self.graph = self._regen(rng)
        self.pos = self.graph.positions
        return self.graph

    def step(self, rng: np.random.Generator) -> ClientGraph:
        self._round += 1
        if self._round % self.regen_every == 0:
            self.graph = self._regen(rng)
            self.pos = self.graph.positions
            self.n_regens += 1
        return self.graph

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]:
        """``rounds`` steps in one pass: draw every regen epoch's
        positions as one (K, n, 2) block (bit-identical to K sequential
        draws), build the K graphs batched, repeat objects in between
        (so downstream per-graph caches keep hitting)."""
        rs = np.arange(self._round + 1, self._round + rounds + 1)
        regen = rs % self.regen_every == 0
        k = int(regen.sum())
        fresh: list[ClientGraph] = []
        if k:
            pos = rng.uniform(0.0, 1.0, size=(k, self.n, 2))
            if self.backend == "sparse":
                # O(n·k) per frame — no (R, n, n) stack to batch over.
                fresh = [sparse_knn_graph(pos[r], self.cfg.min_degree,
                                          self.k_max)
                         for r in range(k)]
            else:
                fresh = _knn_graphs_batch(pos, self.cfg.min_degree)
        out: list[ClientGraph] = []
        j = 0
        cur = self.graph
        for flag in regen:
            if flag:
                cur = fresh[j]
                j += 1
                self.n_regens += 1
            out.append(cur)
        self._round += rounds
        self.graph = cur
        self.pos = cur.positions
        return out

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray:
        self._round = 0
        self.n_regens = 0
        self.graph = None
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        return self.pos

    def step_positions(self, rng: np.random.Generator) -> np.ndarray:
        self._round += 1
        if self._round % self.regen_every == 0:
            self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
            self.n_regens += 1
        return self.pos


class RandomWaypointMobility:
    """Random waypoint: each client walks toward a uniform waypoint at a
    per-leg speed ∈ [speed_min, speed_max], pauses ``pause_rounds`` on
    arrival, then draws the next leg. The classic ad-hoc-network model
    (Johnson & Maltz); positions move ≤ speed_max per round, so graphs
    evolve smoothly instead of redrawing."""

    def __init__(self, n: int, cfg: MobilityConfig,
                 backend: str = "dense", k_max: int = 64):
        self.n = n
        self.cfg = cfg
        self.backend = backend
        self.k_max = k_max

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray:
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        self.waypoint = rng.uniform(0.0, 1.0, size=(self.n, 2))
        self.speed = rng.uniform(self.cfg.speed_min, self.cfg.speed_max,
                                 size=self.n)
        self.pause = np.zeros(self.n, dtype=np.int64)
        return self.pos

    def step_positions(self, rng: np.random.Generator) -> np.ndarray:
        delta = self.waypoint - self.pos
        dist = np.linalg.norm(delta, axis=1)
        moving = (self.pause == 0) & (dist > 1e-12)
        frac = np.where(dist > 1e-12,
                        np.minimum(1.0, self.speed / np.maximum(dist, 1e-12)),
                        0.0)
        self.pos = self.pos + (moving * frac)[:, None] * delta
        arrived = moving & (frac >= 1.0)
        self.pause = np.maximum(self.pause - 1, 0)
        self.pause[arrived] = self.cfg.pause_rounds
        # Draw the next leg for every arrival (boolean indexing consumes
        # the RNG in client order, so replays are deterministic).
        if arrived.any():
            k = int(arrived.sum())
            self.waypoint[arrived] = rng.uniform(0.0, 1.0, size=(k, 2))
            self.speed[arrived] = rng.uniform(
                self.cfg.speed_min, self.cfg.speed_max, size=k)
        return self.pos

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.reset_positions(rng))

    def step(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.step_positions(rng))

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]:
        """Advance positions round-by-round (O(n) each; waypoint-arrival
        draws are data-dependent, so the RNG order must stay per-step),
        then build all ``rounds`` graphs in one batched pass."""
        pos = np.empty((rounds, self.n, 2))
        for t in range(rounds):
            pos[t] = self.step_positions(rng)
        return _range_rollout_graphs(pos, self.cfg, self.backend,
                                     self.k_max)

    def _graph(self, pos: np.ndarray) -> ClientGraph:
        if self.backend == "sparse":
            return sparse_range_graph(pos, self.cfg.radio_range,
                                      self.cfg.min_degree, self.k_max)
        return range_graph(pos, self.cfg.radio_range,
                           self.cfg.min_degree)


def _range_rollout_graphs(pos: np.ndarray, cfg: MobilityConfig,
                          backend: str, k_max: int):
    """Rollout tail shared by the smooth models: dense batches the
    (R, n, n) construction; sparse builds each frame's O(n·k) neighbor
    lists (there is no quadratic stack to batch over — the per-frame
    cell-list pass IS the batched form)."""
    if backend == "sparse":
        return [sparse_range_graph(pos[t], cfg.radio_range,
                                   cfg.min_degree, k_max)
                for t in range(pos.shape[0])]
    return range_graphs_batch(pos, cfg.radio_range, cfg.min_degree)


class GaussMarkovMobility:
    """Gauss-Markov: temporally correlated velocities,

        v_{t+1} = α v_t + (1 − α) v̄_i + σ √(1 − α²) w_t,

    with per-client mean velocities v̄_i (magnitude ``mean_speed``,
    uniform heading) and boundary reflection. α → 1 gives straight-line
    motion, α → 0 memoryless Brownian drift (Camp et al. survey §2.5)."""

    def __init__(self, n: int, cfg: MobilityConfig,
                 backend: str = "dense", k_max: int = 64):
        self.n = n
        self.cfg = cfg
        self.backend = backend
        self.k_max = k_max

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray:
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        heading = rng.uniform(0.0, 2 * np.pi, size=self.n)
        self.mean_v = self.cfg.mean_speed * np.stack(
            [np.cos(heading), np.sin(heading)], axis=1)
        self.vel = self.mean_v.copy()
        return self.pos

    def step_positions(self, rng: np.random.Generator) -> np.ndarray:
        return self._advance(rng.normal(size=(self.n, 2)))

    def _advance(self, noise: np.ndarray) -> np.ndarray:
        a, s = self.cfg.alpha, self.cfg.sigma_speed
        self.vel = (a * self.vel + (1.0 - a) * self.mean_v
                    + s * np.sqrt(max(1.0 - a * a, 0.0)) * noise)
        self.pos = self.pos + self.vel
        # Reflect at the unit-square boundary (flip offending velocity
        # components; mean heading reflects too so clients don't fight
        # the wall forever).
        for lo, hi in ((0.0, 1.0),):
            under, over = self.pos < lo, self.pos > hi
            self.pos = np.where(under, 2 * lo - self.pos, self.pos)
            self.pos = np.where(over, 2 * hi - self.pos, self.pos)
            flip = under | over
            self.vel = np.where(flip, -self.vel, self.vel)
            self.mean_v = np.where(flip, -self.mean_v, self.mean_v)
        self.pos = np.clip(self.pos, 0.0, 1.0)
        return self.pos

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.reset_positions(rng))

    def step(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.step_positions(rng))

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]:
        """One (rounds, n, 2) normal block (bit-identical to per-round
        draws), a cheap sequential velocity/reflection recurrence, then
        one batched graph-construction pass."""
        noise = rng.normal(size=(rounds, self.n, 2))
        pos = np.empty((rounds, self.n, 2))
        for t in range(rounds):
            pos[t] = self._advance(noise[t])
        return _range_rollout_graphs(pos, self.cfg, self.backend,
                                     self.k_max)

    def _graph(self, pos: np.ndarray) -> ClientGraph:
        if self.backend == "sparse":
            return sparse_range_graph(pos, self.cfg.radio_range,
                                      self.cfg.min_degree, self.k_max)
        return range_graph(pos, self.cfg.radio_range,
                           self.cfg.min_degree)


# ---------------------------------------------------------------------------
# Trace replay: recorded (R, n, 2) positions, e.g. from a field trial or
# an external mobility simulator.
# ---------------------------------------------------------------------------

_TRACES: dict[str, np.ndarray] = {}


def _validate_trace(pos: np.ndarray) -> np.ndarray:
    if pos.ndim != 3 or pos.shape[2] != 2 or pos.shape[0] < 1:
        raise ValueError(
            f"trace must be a (R, n, 2) position array with R >= 1, "
            f"got shape {pos.shape}")
    if not np.isfinite(pos).all():
        raise ValueError("trace positions must be finite")
    if pos.min() < 0.0 or pos.max() > 1.0:
        raise ValueError("trace positions must lie in the unit square")
    return pos


def register_trace(name: str, positions: np.ndarray) -> np.ndarray:
    """Register an in-memory (R, n, 2) unit-square position trace under
    ``name`` so a plain-string ``MobilityConfig(model="trace",
    trace_path=name)`` can refer to it (configs stay frozen/hashable —
    no array-valued fields). Returns the validated float64 copy."""
    pos = _validate_trace(np.array(positions, np.float64))
    _TRACES[name] = pos
    return pos


def load_trace(spec: str) -> np.ndarray:
    """Resolve a trace spec: a ``register_trace`` name, an ``.npz`` file
    holding a ``"positions"`` array, or a bare ``.npy`` array file."""
    if not spec:
        raise ValueError(
            "mobility model 'trace' needs MobilityConfig.trace_path "
            "(a register_trace name or an .npz/.npy file)")
    if spec in _TRACES:
        return _TRACES[spec]
    if spec.endswith(".npz"):
        with np.load(spec) as z:
            if "positions" not in z:
                raise ValueError(
                    f"{spec!r} has no 'positions' array "
                    f"(found: {sorted(z.files)})")
            return _validate_trace(np.asarray(z["positions"], np.float64))
    if spec.endswith(".npy"):
        return _validate_trace(np.asarray(np.load(spec), np.float64))
    raise ValueError(
        f"unknown trace {spec!r}: not a registered name "
        f"(known: {sorted(_TRACES)}) and not an .npz/.npy path")


class TraceMobility:
    """Replay recorded positions: round t shows frame ``t mod R`` of the
    (R, n, 2) trace named by ``cfg.trace_path`` (wrap-around looping).
    Consumes **no** RNG, so swapping a synthetic model for a trace leaves
    every other stream (links, churn, walker) untouched, and replays are
    exact by construction. Graphs derive from ``radio_range``/
    ``min_degree`` exactly like the smooth models."""

    def __init__(self, n: int, cfg: MobilityConfig,
                 backend: str = "dense", k_max: int = 64):
        self.n = n
        self.cfg = cfg
        self.backend = backend
        self.k_max = k_max
        self.trace = load_trace(cfg.trace_path)
        if self.trace.shape[1] != n:
            raise ValueError(
                f"trace {cfg.trace_path!r} has {self.trace.shape[1]} "
                f"clients, scenario has {n}")
        self._t = 0

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray:
        self._t = 0
        self.pos = self.trace[0]
        return self.pos

    def step_positions(self, rng: np.random.Generator) -> np.ndarray:
        self._t += 1
        self.pos = self.trace[self._t % len(self.trace)]
        return self.pos

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.reset_positions(rng))

    def step(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.step_positions(rng))

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]:
        """Slice the next ``rounds`` frames (with wrap-around) and push
        them through the shared batched graph-construction tail."""
        idx = (self._t + 1 + np.arange(rounds)) % len(self.trace)
        pos = self.trace[idx]
        self._t += rounds
        if rounds:
            self.pos = pos[-1]
        return _range_rollout_graphs(pos, self.cfg, self.backend,
                                     self.k_max)

    def _graph(self, pos: np.ndarray) -> ClientGraph:
        if self.backend == "sparse":
            return sparse_range_graph(pos, self.cfg.radio_range,
                                      self.cfg.min_degree, self.k_max)
        return range_graph(pos, self.cfg.radio_range,
                           self.cfg.min_degree)


_MODELS = {
    "static_regen": StaticRegenMobility,
    "random_waypoint": RandomWaypointMobility,
    "gauss_markov": GaussMarkovMobility,
    "trace": TraceMobility,
}


def build_mobility(n: int, cfg: MobilityConfig, *, backend: str = "dense",
                   k_max: int = 64) -> MobilityModel:
    try:
        cls = _MODELS[cfg.model]
    except KeyError:
        raise ValueError(
            f"unknown mobility model {cfg.model!r}; "
            f"known: {sorted(_MODELS)}") from None
    if backend not in GRAPH_BACKENDS:
        raise ValueError(
            f"graph_backend must be one of {'|'.join(GRAPH_BACKENDS)}, "
            f"got {backend!r}")
    return cls(n, cfg, backend=backend, k_max=int(k_max))
