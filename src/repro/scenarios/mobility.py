"""Mobility models: client positions → per-round connectivity graphs.

All models run host-side (control plane) and share one contract:

    reset(rng) -> ClientGraph     # round-0 graph
    step(rng)  -> ClientGraph     # advance one round
    rollout(rounds, rng) -> list[ClientGraph]   # batched step×rounds

plus a positions-only lane for consumers that never touch connectivity
(the FedAvg-family base-station baselines — ``scenarios.Scenario``'s
``positions_only`` mode):

    reset_positions(rng) -> (n, 2)
    step_positions(rng)  -> (n, 2)

``rollout`` and the positions-only lane consume the RNG exactly as the
same number of ``step()`` calls would, so every lane replays every other
lane draw-for-draw (pinned in ``tests/test_scenario_rollout.py``).
``rollout`` batches the O(n²) work — pairwise distances, range/kNN
adjacency, degree patching, connectivity checks — across the whole
window in a few vectorized passes; position *advancement* stays a cheap
O(n) per-round recurrence (it is inherently sequential: waypoint
arrivals and boundary reflections depend on the previous round).

Connectivity for the smooth models derives from a radio range — an edge
(i, j) exists iff ‖p_i − p_j‖ ≤ radio_range — then a ``min_degree``
nearest-neighbor floor and a deterministic connected-components patch
keep the walk chain irreducible (Assumption 3.1), matching the paper's
"at least 5 neighboring nodes" App. D.2 construction.

``static_regen`` reproduces the seed repo's ``DynamicGraph`` draw
sequence bit-for-bit: i.i.d. ``random_geometric_graph`` redraws every
``regen_every`` rounds and *no* RNG consumption in between.
"""
from __future__ import annotations

from typing import Protocol

import numpy as np

from ..core.graph import (
    ClientGraph,
    graphs_from_stack,
    knn_adjacency,
    pairwise_sq_dists,
    pairwise_sq_dists_batch,
    patch_connected,
    random_geometric_graph,
    seed_sq_dist_cache,
)
from .config import MobilityConfig


class MobilityModel(Protocol):
    def reset(self, rng: np.random.Generator) -> ClientGraph: ...

    def step(self, rng: np.random.Generator) -> ClientGraph: ...

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]: ...

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray: ...

    def step_positions(self, rng: np.random.Generator) -> np.ndarray: ...


def range_graph(pos: np.ndarray, radio_range: float,
                min_degree: int) -> ClientGraph:
    """Geometric connectivity: radio-range disk graph with a min-degree
    patch (nodes below the degree floor get their nearest neighbors
    linked in), patched connected. Deterministic given positions; runs
    every round for the smooth mobility models, so the k-NN work is
    restricted to the deficient rows only.
    """
    n = pos.shape[0]
    d2 = pairwise_sq_dists(pos)
    adj = d2 <= radio_range * radio_range
    np.fill_diagonal(adj, False)
    k = min(min_degree, n - 1)
    deficient = np.flatnonzero(adj.sum(axis=1) < k)
    if len(deficient) and k > 0:
        nearest = np.argpartition(d2[deficient], k - 1, axis=1)[:, :k]
        adj[deficient[:, None], nearest] = True
        adj[nearest, deficient[:, None]] = True
    adj = patch_connected(adj, d2)
    graph = ClientGraph(adjacency=adj, positions=pos)
    seed_sq_dist_cache(graph, d2)
    return graph


def range_graphs_batch(pos: np.ndarray, radio_range: float,
                       min_degree: int) -> list[ClientGraph]:
    """Batched :func:`range_graph`: R graphs from (R, n, 2) positions.

    One (R, n, n) distance pass, one vectorized degree patch over all
    deficient rows of all rounds at once, one batched connectivity
    check; only rounds that actually come out disconnected pay the
    per-graph component patch. Deterministic and bit-identical to R
    per-round ``range_graph`` calls (same argpartition per row, same
    patch order) — pinned in ``tests/test_scenario_rollout.py``.
    """
    n = pos.shape[1]
    d2 = pairwise_sq_dists_batch(pos)
    adj = d2 <= radio_range * radio_range       # inf diagonal → False
    k = min(min_degree, n - 1)
    if k > 0:
        r_idx, i_idx = np.nonzero(adj.sum(axis=2) < k)
        if len(r_idx):
            nearest = np.argpartition(d2[r_idx, i_idx], k - 1,
                                      axis=1)[:, :k]
            adj[r_idx[:, None], i_idx[:, None], nearest] = True
            adj[r_idx[:, None], nearest, i_idx[:, None]] = True
    return graphs_from_stack(adj, d2, pos)


def _knn_graphs_batch(pos: np.ndarray, min_degree: int) -> list[ClientGraph]:
    """Batched ``random_geometric_graph`` body for pre-drawn positions:
    kNN adjacency + connectivity patch per frame, distances in one pass.
    Bit-identical to per-frame construction (rows partition independently).
    """
    d2 = pairwise_sq_dists_batch(pos)
    adj = np.stack([knn_adjacency(d2[r], min_degree)
                    for r in range(pos.shape[0])])
    return graphs_from_stack(adj, d2, pos)


class StaticRegenMobility:
    """The seed behavior: positions redrawn i.i.d. every ``regen_every``
    rounds (``core.graph.DynamicGraph``), static in between."""

    def __init__(self, n: int, cfg: MobilityConfig):
        self.n = n
        self.cfg = cfg
        self.regen_every = max(1, cfg.regen_every)
        self._round = 0
        self.n_regens = 0
        self.graph: ClientGraph | None = None
        self.pos: np.ndarray | None = None

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        self._round = 0
        self.n_regens = 0
        self.graph = random_geometric_graph(self.n, self.cfg.min_degree, rng)
        self.pos = self.graph.positions
        return self.graph

    def step(self, rng: np.random.Generator) -> ClientGraph:
        self._round += 1
        if self._round % self.regen_every == 0:
            self.graph = random_geometric_graph(
                self.n, self.cfg.min_degree, rng
            )
            self.pos = self.graph.positions
            self.n_regens += 1
        return self.graph

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]:
        """``rounds`` steps in one pass: draw every regen epoch's
        positions as one (K, n, 2) block (bit-identical to K sequential
        draws), build the K graphs batched, repeat objects in between
        (so downstream per-graph caches keep hitting)."""
        rs = np.arange(self._round + 1, self._round + rounds + 1)
        regen = rs % self.regen_every == 0
        k = int(regen.sum())
        fresh: list[ClientGraph] = []
        if k:
            pos = rng.uniform(0.0, 1.0, size=(k, self.n, 2))
            fresh = _knn_graphs_batch(pos, self.cfg.min_degree)
        out: list[ClientGraph] = []
        j = 0
        cur = self.graph
        for flag in regen:
            if flag:
                cur = fresh[j]
                j += 1
                self.n_regens += 1
            out.append(cur)
        self._round += rounds
        self.graph = cur
        self.pos = cur.positions
        return out

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray:
        self._round = 0
        self.n_regens = 0
        self.graph = None
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        return self.pos

    def step_positions(self, rng: np.random.Generator) -> np.ndarray:
        self._round += 1
        if self._round % self.regen_every == 0:
            self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
            self.n_regens += 1
        return self.pos


class RandomWaypointMobility:
    """Random waypoint: each client walks toward a uniform waypoint at a
    per-leg speed ∈ [speed_min, speed_max], pauses ``pause_rounds`` on
    arrival, then draws the next leg. The classic ad-hoc-network model
    (Johnson & Maltz); positions move ≤ speed_max per round, so graphs
    evolve smoothly instead of redrawing."""

    def __init__(self, n: int, cfg: MobilityConfig):
        self.n = n
        self.cfg = cfg

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray:
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        self.waypoint = rng.uniform(0.0, 1.0, size=(self.n, 2))
        self.speed = rng.uniform(self.cfg.speed_min, self.cfg.speed_max,
                                 size=self.n)
        self.pause = np.zeros(self.n, dtype=np.int64)
        return self.pos

    def step_positions(self, rng: np.random.Generator) -> np.ndarray:
        delta = self.waypoint - self.pos
        dist = np.linalg.norm(delta, axis=1)
        moving = (self.pause == 0) & (dist > 1e-12)
        frac = np.where(dist > 1e-12,
                        np.minimum(1.0, self.speed / np.maximum(dist, 1e-12)),
                        0.0)
        self.pos = self.pos + (moving * frac)[:, None] * delta
        arrived = moving & (frac >= 1.0)
        self.pause = np.maximum(self.pause - 1, 0)
        self.pause[arrived] = self.cfg.pause_rounds
        # Draw the next leg for every arrival (boolean indexing consumes
        # the RNG in client order, so replays are deterministic).
        if arrived.any():
            k = int(arrived.sum())
            self.waypoint[arrived] = rng.uniform(0.0, 1.0, size=(k, 2))
            self.speed[arrived] = rng.uniform(
                self.cfg.speed_min, self.cfg.speed_max, size=k)
        return self.pos

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.reset_positions(rng))

    def step(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.step_positions(rng))

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]:
        """Advance positions round-by-round (O(n) each; waypoint-arrival
        draws are data-dependent, so the RNG order must stay per-step),
        then build all ``rounds`` graphs in one batched pass."""
        pos = np.empty((rounds, self.n, 2))
        for t in range(rounds):
            pos[t] = self.step_positions(rng)
        return range_graphs_batch(pos, self.cfg.radio_range,
                                  self.cfg.min_degree)

    def _graph(self, pos: np.ndarray) -> ClientGraph:
        return range_graph(pos, self.cfg.radio_range,
                           self.cfg.min_degree)


class GaussMarkovMobility:
    """Gauss-Markov: temporally correlated velocities,

        v_{t+1} = α v_t + (1 − α) v̄_i + σ √(1 − α²) w_t,

    with per-client mean velocities v̄_i (magnitude ``mean_speed``,
    uniform heading) and boundary reflection. α → 1 gives straight-line
    motion, α → 0 memoryless Brownian drift (Camp et al. survey §2.5)."""

    def __init__(self, n: int, cfg: MobilityConfig):
        self.n = n
        self.cfg = cfg

    def reset_positions(self, rng: np.random.Generator) -> np.ndarray:
        self.pos = rng.uniform(0.0, 1.0, size=(self.n, 2))
        heading = rng.uniform(0.0, 2 * np.pi, size=self.n)
        self.mean_v = self.cfg.mean_speed * np.stack(
            [np.cos(heading), np.sin(heading)], axis=1)
        self.vel = self.mean_v.copy()
        return self.pos

    def step_positions(self, rng: np.random.Generator) -> np.ndarray:
        return self._advance(rng.normal(size=(self.n, 2)))

    def _advance(self, noise: np.ndarray) -> np.ndarray:
        a, s = self.cfg.alpha, self.cfg.sigma_speed
        self.vel = (a * self.vel + (1.0 - a) * self.mean_v
                    + s * np.sqrt(max(1.0 - a * a, 0.0)) * noise)
        self.pos = self.pos + self.vel
        # Reflect at the unit-square boundary (flip offending velocity
        # components; mean heading reflects too so clients don't fight
        # the wall forever).
        for lo, hi in ((0.0, 1.0),):
            under, over = self.pos < lo, self.pos > hi
            self.pos = np.where(under, 2 * lo - self.pos, self.pos)
            self.pos = np.where(over, 2 * hi - self.pos, self.pos)
            flip = under | over
            self.vel = np.where(flip, -self.vel, self.vel)
            self.mean_v = np.where(flip, -self.mean_v, self.mean_v)
        self.pos = np.clip(self.pos, 0.0, 1.0)
        return self.pos

    def reset(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.reset_positions(rng))

    def step(self, rng: np.random.Generator) -> ClientGraph:
        return self._graph(self.step_positions(rng))

    def rollout(self, rounds: int,
                rng: np.random.Generator) -> list[ClientGraph]:
        """One (rounds, n, 2) normal block (bit-identical to per-round
        draws), a cheap sequential velocity/reflection recurrence, then
        one batched graph-construction pass."""
        noise = rng.normal(size=(rounds, self.n, 2))
        pos = np.empty((rounds, self.n, 2))
        for t in range(rounds):
            pos[t] = self._advance(noise[t])
        return range_graphs_batch(pos, self.cfg.radio_range,
                                  self.cfg.min_degree)

    def _graph(self, pos: np.ndarray) -> ClientGraph:
        return range_graph(pos, self.cfg.radio_range,
                           self.cfg.min_degree)


_MODELS = {
    "static_regen": StaticRegenMobility,
    "random_waypoint": RandomWaypointMobility,
    "gauss_markov": GaussMarkovMobility,
}


def build_mobility(n: int, cfg: MobilityConfig) -> MobilityModel:
    try:
        cls = _MODELS[cfg.model]
    except KeyError:
        raise ValueError(
            f"unknown mobility model {cfg.model!r}; "
            f"known: {sorted(_MODELS)}") from None
    return cls(n, cfg)
