"""Scenario subsystem: mobility models, wireless links, client churn.

Composable, config-driven environments for the mobile-server random
walk — all host-side control plane that compiles into the fixed-shape
``ZoneSchedule`` arrays, keeping the ``engine="scan"``/``"scan_fused"``
hot path scenario-agnostic. See ``docs/scenarios.md``.
"""
from .churn import ChurnModel
from .config import (
    ChurnConfig,
    CommConfig,
    LinkConfig,
    MobilityConfig,
    ScenarioConfig,
    available_scenarios,
    get_scenario_config,
    register_scenario,
)
from .links import CommModel, LinkModel
from .mobility import (
    GRAPH_BACKENDS,
    GaussMarkovMobility,
    MobilityModel,
    RandomWaypointMobility,
    StaticRegenMobility,
    TraceMobility,
    build_mobility,
    load_trace,
    range_graph,
    range_graphs_batch,
    register_trace,
    sparse_knn_graph,
    sparse_range_graph,
)
from .scenario import Scenario, build_scenario

__all__ = [
    "ChurnConfig",
    "ChurnModel",
    "CommConfig",
    "CommModel",
    "GRAPH_BACKENDS",
    "GaussMarkovMobility",
    "LinkConfig",
    "LinkModel",
    "MobilityConfig",
    "MobilityModel",
    "RandomWaypointMobility",
    "Scenario",
    "ScenarioConfig",
    "StaticRegenMobility",
    "TraceMobility",
    "available_scenarios",
    "build_mobility",
    "build_scenario",
    "get_scenario_config",
    "load_trace",
    "range_graph",
    "range_graphs_batch",
    "register_scenario",
    "register_trace",
    "sparse_knn_graph",
    "sparse_range_graph",
]
