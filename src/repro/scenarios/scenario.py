"""Scenario: mobility + links + churn behind the DynamicGraph contract.

A ``Scenario`` is a drop-in replacement for ``core.graph.DynamicGraph``
(``current()`` / ``step()`` / ``schedule()``), so the random walker, the
eager driver, and the compiled-schedule driver all work unchanged. Per
round it:

  1. advances the mobility model (positions → base connectivity),
  2. applies stochastic link dropouts (link layer) to the adjacency,
  3. advances the churn model (availability mask for zone planning),

and offers deterministic comm pricing (latency/energy) for whatever
zone the planner forms. Everything is host-side control plane; the
fixed-shape ``ZoneSchedule`` arrays it compiles into are all the device
ever sees, so ``engine="scan"``/``"scan_fused"`` keep the fused hot
path under every scenario.

``schedule()`` is a **batched rollout**, not R ``step()`` iterations:
each layer generates its whole window in a few vectorized passes
(mobility positions + graphs, the (R, n, n) link-dropout tensor, the
(R, n) churn masks), chunked to ``cfg.rollout_chunk`` rounds so the
O(R·n²) intermediates stay bounded for large windows. Every lane —
``step()``, batched ``schedule()``, and stepped ``schedule(batched=
False)`` — consumes the RNG streams identically, so they replay each
other draw-for-draw (pinned in ``tests/test_scenario_rollout.py``).

Three independent RNG streams (mobility / links / churn) are derived
from the seed, so toggling one layer never perturbs another layer's
draw sequence. With the default ``static_regen`` config (links and
churn off) the mobility stream consumes exactly like ``DynamicGraph``'s
single RNG — bit-for-bit identical trajectories.

``positions_only=True`` drops the connectivity stack entirely: the
mobility model advances positions (identical RNG consumption — the
graph construction is RNG-free) but never builds adjacency, never
patches degrees or components, and the link layer never samples
dropouts. The FedAvg-family base-station baselines run in this mode:
they only consume positions (pricing against the base station) and
churn masks (selection), so the O(n²)-per-round graph work is pure
waste for them.
"""
from __future__ import annotations

import numpy as np

from ..core.graph import ClientGraph, detach_rollout_views
from .churn import ChurnModel
from .config import ScenarioConfig, get_scenario_config
from .links import CommModel, LinkModel
from .mobility import build_mobility


class Scenario:
    def __init__(self, n: int, cfg: ScenarioConfig | str, seed: int = 0,
                 *, positions_only: bool = False, telemetry=None):
        if isinstance(cfg, str):
            cfg = get_scenario_config(cfg)
        self.n = n
        self.cfg = cfg
        self.telemetry = telemetry   # TelemetryRun or None (off):
        # schedule() emits fenced "scenario_rollout" phase spans into it
        # — pure host-side control plane, no RNG or trajectory impact.
        self.positions_only = bool(positions_only)
        self.mobility = build_mobility(n, cfg.mobility,
                                       backend=cfg.graph_backend,
                                       k_max=cfg.neighbor_k_max)
        # Stream 0 mirrors DynamicGraph(seed) exactly (static_regen
        # bit-compat); links/churn get independent streams. A negative
        # seed never reaches the SeedSequence: default_rng(seed) above
        # it already rejects one (pinned in the seed-stability test).
        self._rng_mob = np.random.default_rng(seed)
        self._rng_link = np.random.default_rng(
            np.random.SeedSequence([seed, 1]))
        self._rng_churn = np.random.default_rng(
            np.random.SeedSequence([seed, 2]))
        self.link = LinkModel(cfg.links) if cfg.links.enabled else None
        self.churn = ChurnModel(n, cfg.churn) if cfg.churn.enabled else None
        self.comm = CommModel(cfg.comm, self.link)
        self._round = 0
        if self.positions_only:
            self._base = self.graph = None
            self._pos = self.mobility.reset_positions(self._rng_mob)
        else:
            self._base = self.mobility.reset(self._rng_mob)
            self.graph = self._effective(self._base)
            self._pos = self._base.positions
        self.avail = (self.churn.reset(self._rng_churn)
                      if self.churn is not None else None)
        self._avail_trace: np.ndarray | None = None

    # -- DynamicGraph contract -------------------------------------------
    @property
    def n_regens(self) -> int:
        return getattr(self.mobility, "n_regens", 0)

    @property
    def positions(self) -> np.ndarray:
        """(n, 2) current client positions (works in every mode)."""
        return self._pos

    def current(self) -> ClientGraph:
        if self.graph is None:
            raise RuntimeError(
                "positions-only scenario has no connectivity graph; "
                "rebuild with positions_only=False for graph walking")
        return self.graph

    def step(self) -> ClientGraph | None:
        """Advance one round: mobility, link dropouts, churn. In
        positions-only mode just positions and churn — the whole
        connectivity stack (adjacency, degree floor, component patch,
        dropout sampling) is skipped."""
        self._round += 1
        if self.positions_only:
            self._pos = self.mobility.step_positions(self._rng_mob)
        else:
            self._base = self.mobility.step(self._rng_mob)
            self.graph = self._effective(self._base)
            self._pos = self._base.positions
        if self.churn is not None:
            self.avail = self.churn.step(self._round, self._rng_churn)
        return self.graph

    def schedule(self, rounds: int, *, include_current: bool = False,
                 batched: bool = True) -> list[ClientGraph]:
        """Batch variant of :meth:`step` (same contract as
        ``DynamicGraph.schedule``). Also records the per-round
        availability masks for the same window; ``pop_avail_trace()``
        hands them to ``markov.zone_schedule`` aligned with the graphs.

        ``batched=True`` (default) runs the vectorized rollout engine:
        one array program per layer per ≤``cfg.rollout_chunk``-round
        chunk. ``batched=False`` keeps the legacy per-round stepping —
        same RNG consumption, bit-identical output (the equivalence is
        pinned in tests); it exists as the oracle for that pin.
        """
        if self.positions_only:
            raise RuntimeError(
                "positions-only scenario cannot compile graph schedules; "
                "rebuild with positions_only=False for graph walking")
        graphs: list[ClientGraph] = []
        avails: list[np.ndarray] = []
        if include_current:
            graphs.append(self.current())
            avails.append(self.avail)
        if self.telemetry is not None:
            span = self.telemetry.phase(
                "scenario_rollout", rounds=rounds, batched=bool(batched),
                backend=self.cfg.graph_backend)
            span.__enter__()
        else:
            span = None
        if batched:
            chunk = max(1, int(self.cfg.rollout_chunk))
            while len(graphs) < rounds:
                m = min(rounds - len(graphs), chunk)
                base = self.mobility.rollout(m, self._rng_mob)
                if self.link is not None:
                    eff = self.link.apply_dropouts_batch(
                        base, self._rng_link)
                else:
                    eff = base
                if self.churn is not None:
                    block = self.churn.rollout(
                        self._round + 1, m, self._rng_churn)
                    avails.extend(block)
                    self.avail = block[-1]
                self._round += m
                graphs.extend(eff)
                self._base = base[-1]
                self.graph = eff[-1]
        else:
            while len(graphs) < rounds:
                graphs.append(self.step())
                avails.append(self.avail)
        if span is not None:
            span.__exit__(None, None, None)
        # Copy-on-seed: the scenario retains the window's last graphs as
        # its current state; their arrays/caches are views into the
        # rollout's (R, n, n)/(R, n, 2) stacks and would pin the whole
        # window in memory. Detach BEFORE mirroring positions so _pos
        # references the copy, not the stack.
        for g in (self._base, self.graph):
            if g is not None:
                detach_rollout_views(g)
        self._pos = self._base.positions
        self._avail_trace = (np.stack(avails)
                             if self.churn is not None else None)
        return graphs

    def pop_avail_trace(self) -> np.ndarray | None:
        """(R, n) availability masks aligned with the last
        :meth:`schedule` call (None when churn is disabled — the
        planner then consumes RNG exactly like the pre-scenario path)."""
        trace, self._avail_trace = self._avail_trace, None
        return trace

    # -- layers -----------------------------------------------------------
    def _effective(self, base: ClientGraph) -> ClientGraph:
        """Link-layer view of the mobility graph. Without a link model
        this is ``base`` itself (same object — the walker's per-graph
        transition-matrix cache keeps hitting between regens)."""
        if self.link is None:
            return base
        return self.link.apply_dropouts(base, self._rng_link)

    def availability(self) -> np.ndarray | None:
        """(n,) bool mask for the current round, or None (all on)."""
        return self.avail

    def price_round(self, graph: ClientGraph, i_k: int, idx: np.ndarray,
                    mask: np.ndarray, payload_bytes: int
                    ) -> tuple[float, float]:
        """(latency_s, energy_j) for one zone round — deterministic, so
        eager rounds and precomputed schedules price identically."""
        return self.comm.price_round(graph, i_k, idx, mask, payload_bytes)

    def price_schedule(self, graphs, clients, idx, mask,
                       payload_bytes: int):
        """Vectorized pricing of a whole precomputed schedule window
        (one pass — same math as R ``price_round`` calls)."""
        return self.comm.price_schedule(graphs, clients, idx, mask,
                                        payload_bytes)

    def price_fleet_schedule(self, graphs, clients, idx, mask,
                             payload_bytes: int):
        """Per-walker pricing of a simultaneous-fleet window: clients
        (R, K), idx/mask (R, K, Z) → ((R, K), (R, K)) latency/energy."""
        return self.comm.price_fleet_schedule(graphs, clients, idx, mask,
                                              payload_bytes)

    def price_star_round(self, members: np.ndarray, payload_bytes: int
                         ) -> tuple[float, float]:
        """Baseline (base-station) pricing against current positions
        (graph-free: works in positions-only mode)."""
        return self.comm.price_star_round(
            self._pos, members, payload_bytes)


def build_scenario(spec: ScenarioConfig | str | None, n: int,
                   seed: int = 0, *, min_degree: int = 5,
                   regen_every: int = 10,
                   positions_only: bool = False) -> Scenario:
    """Resolve a scenario spec (name, config, or None) into a Scenario.

    ``None`` builds the default ``static_regen`` from the caller's
    legacy graph knobs (min_degree/regen_every) — the exact seed-repo
    ``DynamicGraph`` behavior. A named or explicit config is
    authoritative: its own mobility knobs win over the legacy kwargs.

    ``positions_only=True`` skips the whole connectivity stack — for
    base-station consumers (the FedAvg-family baselines) that only read
    positions and churn masks.
    """
    if spec is None:
        import dataclasses

        base = get_scenario_config("static_regen")
        spec = dataclasses.replace(
            base, mobility=dataclasses.replace(
                base.mobility, min_degree=min_degree,
                regen_every=regen_every),
        )
    return Scenario(n, spec, seed=seed, positions_only=positions_only)
