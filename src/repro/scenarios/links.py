"""Wireless link layer: path loss → success probability → dropouts,
plus the comm-cost model pricing each round in bytes/latency/energy.

Log-distance path loss with shadowing (Rappaport Ch. 4):

    PL(d) = PL₀ + 10 η log₁₀(max(d, d₀)/d₀)        [dB]
    M(d)  = P_tx − P_sens − PL(d)                   fade margin [dB]
    p(d)  = clip(σ(M(d)/s_sh), p_min, 1)            link success prob,

where the log-normal shadowing is folded into a logistic curve of the
margin (scale ``shadowing_db``) — the standard sigmoid outage
approximation, dependency-free and monotone-decreasing in distance.

Stochastic dropouts draw each edge ~ Bernoulli(p(d)) per round and then
re-patch connectivity (deterministically, nearest across components) so
the random-walk chain stays irreducible.

``CommModel`` prices a zone round under the first-order radio model
(Heinzelman et al. 2000): the server broadcasts the token y once at the
power needed to reach the farthest zone member, each active client
uploads its contribution over its own link, and expected retransmissions
1/p(d) scale both latency and energy. All pricing is deterministic given
the zone — no RNG — so eager and scan engines price identically.
"""
from __future__ import annotations

import numpy as np

from ..core.graph import (
    ClientGraph,
    NeighborGraph,
    graph_sq_dists,
    graphs_from_stack,
    neighbor_graph_from_pairs,
    patch_connected,
    patch_connected_lists,
    seed_sq_dist_cache,
)
from .config import CommConfig, LinkConfig


class LinkModel:
    """Per-link success probabilities + per-round stochastic dropouts.

    Both graph backends are served: dense ``ClientGraph``s sample a
    symmetric (n, n) uniform matrix per round; sparse ``NeighborGraph``s
    sample one uniform per *undirected edge* (canonical (i < j) order) —
    O(n·k) instead of O(n²) per round. The two lanes draw different
    uniform counts, so **enabling dropout is an RNG-stream break between
    backends** (each lane is individually deterministic and
    chunk-composable; the sparse stream is pinned by a seed-stability
    test). Everything RNG-free — success probabilities, pricing — is
    bit-identical across backends.
    """

    def __init__(self, cfg: LinkConfig):
        self.cfg = cfg
        # Distances/probabilities depend only on the base (mobility)
        # graph, which under static_regen changes every ``regen_every``
        # rounds while dropouts redraw every round — cache per graph
        # instance (weakref so a recycled id can't alias a dead graph).
        self._cache: tuple | None = None

    def _geometry(self, graph: ClientGraph):
        """(d2, link success matrix) for ``graph``, cached per instance."""
        import weakref

        if self._cache is not None and self._cache[0]() is graph:
            return self._cache[1], self._cache[2]
        d2 = graph_sq_dists(graph)
        finite = np.where(np.isfinite(d2), d2, 0.0)   # inf diagonal
        p = np.where(graph.adjacency,
                     self.success_probability_sq(finite), 0.0)
        self._cache = (weakref.ref(graph), d2, p)
        return d2, p

    def success_probability(self, dist: np.ndarray) -> np.ndarray:
        """p(d) for an array of distances (elementwise, vectorized)."""
        return self.success_probability_sq(
            np.square(np.asarray(dist, dtype=np.float64)))

    def success_probability_sq(self, d2: np.ndarray) -> np.ndarray:
        """p as a function of *squared* distance.

        Algebraically identical to the logistic-of-margin form in the
        module docstring:  σ(M(d)/s) = 1 / (1 + C · (d²/d₀²)^(q/2))
        with C = exp(−M(d₀)/s) and q = 10η/(s·ln10) — no sqrt/log10
        over the (n, n) matrix (this runs every round under dropout
        scenarios).
        """
        c = self.cfg
        s = max(c.shadowing_db, 1e-6)
        m0 = c.tx_power_dbm - c.sensitivity_dbm - c.ref_loss_db
        big_c = np.exp(-m0 / s)
        q = 10.0 * c.path_loss_exp / (s * np.log(10.0))
        ratio = np.maximum(
            np.asarray(d2, dtype=np.float64) / c.ref_distance**2, 1.0)
        p = 1.0 / (1.0 + big_c * ratio ** (q / 2.0))
        return np.clip(p, c.min_success, 1.0)

    def link_matrix(self, graph: ClientGraph) -> np.ndarray:
        """(n, n) success probabilities on the graph's edges, 0 elsewhere."""
        return self._geometry(graph)[1]

    def _edge_geometry(self, graph: NeighborGraph):
        """Canonical-edge arrays (ei, ej, d2, p) for a sparse graph,
        cached per graph instance (same policy as :meth:`_geometry`)."""
        import weakref

        if self._cache is not None and self._cache[0]() is graph:
            return self._cache[1]
        ei, ej, d2 = graph.undirected_edges()
        p = self.success_probability_sq(d2)
        self._cache = (weakref.ref(graph), (ei, ej, d2, p))
        return ei, ej, d2, p

    def _apply_dropouts_sparse(self, graph: NeighborGraph,
                               rng: np.random.Generator
                               ) -> NeighborGraph:
        """One uniform per undirected edge in canonical (i < j) order
        (symmetric outcome by construction), survivors re-packed into
        neighbor lists and re-patched connected."""
        ei, ej, d2, p = self._edge_geometry(graph)
        u = rng.uniform(size=len(ei))
        keep = u < p
        pi = np.concatenate([ei[keep], ej[keep]])
        pj = np.concatenate([ej[keep], ei[keep]])
        ed2 = np.concatenate([d2[keep], d2[keep]])
        out = neighbor_graph_from_pairs(graph.n, pi, pj, ed2,
                                        graph.positions)
        nbrs, mask, nd2 = patch_connected_lists(
            out.nbrs, out.nbr_mask, out.nbr_d2, graph.positions)
        return NeighborGraph(nbrs=nbrs, nbr_mask=mask,
                             positions=graph.positions, nbr_d2=nd2)

    def apply_dropouts(self, graph: ClientGraph,
                       rng: np.random.Generator) -> ClientGraph:
        """Edge (i,j) survives this round w.p. p(d_ij); the surviving
        adjacency is re-patched connected so zones/walks stay well
        defined. Draws the upper triangle only (symmetric outcome)."""
        if not self.cfg.dropout:
            return graph
        if isinstance(graph, NeighborGraph):
            return self._apply_dropouts_sparse(graph, rng)
        d2, p = self._geometry(graph)
        u = rng.uniform(size=p.shape)
        u = np.triu(u, 1)
        u = u + u.T                      # symmetric uniforms
        adj = graph.adjacency & (u < p)
        adj = patch_connected(adj, d2)
        out = ClientGraph(adjacency=adj, positions=graph.positions)
        seed_sq_dist_cache(out, d2)      # same positions → same distances
        return out

    def apply_dropouts_batch(self, graphs: list[ClientGraph],
                             rng: np.random.Generator) -> list[ClientGraph]:
        """Batched :meth:`apply_dropouts` for a whole rollout window.

        Samples the full (R, n, n) uniform tensor in one draw (bit-
        identical to R sequential (n, n) draws), applies every round's
        Bernoulli edge survival elementwise, then checks connectivity of
        all R survivors with one batched frontier expansion — only the
        rounds that actually disconnect pay the per-graph component
        patch. Link success probabilities are computed once per distinct
        base graph (consecutive rounds share the mobility graph under
        ``static_regen``).
        """
        if not self.cfg.dropout:
            return list(graphs)
        rounds = len(graphs)
        if rounds == 0:
            return []
        if isinstance(graphs[0], NeighborGraph):
            return self._apply_dropouts_batch_sparse(graphs, rng)
        n = graphs[0].n
        u = rng.uniform(size=(rounds, n, n))
        u = np.triu(u, 1)
        u = u + u.transpose(0, 2, 1)     # symmetric uniforms, per round
        # Geometry once per *distinct* base graph (static_regen shares
        # one graph per regen epoch; smooth mobility has one per round),
        # with the success-probability curve evaluated over the whole
        # distinct-graph stack in a single vectorized pass.
        runs: list[tuple[int, int, ClientGraph]] = []
        start = 0
        while start < rounds:
            g = graphs[start]
            stop = start + 1
            while stop < rounds and graphs[stop] is g:
                stop += 1
            runs.append((start, stop, g))
            start = stop
        d2_stack = np.stack([graph_sq_dists(g) for _, _, g in runs])
        adj_stack = np.stack([g.adjacency for _, _, g in runs])
        finite = np.where(np.isfinite(d2_stack), d2_stack, 0.0)
        p_stack = np.where(adj_stack,
                           self.success_probability_sq(finite), 0.0)
        ri = np.repeat(np.arange(len(runs)),
                       [b - a for a, b, _ in runs])
        surv = adj_stack[ri] & (u < p_stack[ri])
        d2s = [d2_stack[j] for j in ri]
        return graphs_from_stack(surv, d2s,
                                 [g.positions for g in graphs])

    def _apply_dropouts_batch_sparse(self, graphs: list[NeighborGraph],
                                     rng: np.random.Generator
                                     ) -> list[NeighborGraph]:
        """Sparse lane of :meth:`apply_dropouts_batch`: one uniform per
        undirected edge, drawn round-by-round — the generator fills
        sequentially, so this equals one whole-window draw bit-for-bit
        while never materializing a window-sized edge tensor (the
        windowed peak stays O(n·k) + the survivors themselves).
        :meth:`_edge_geometry`'s last-graph cache already serves the
        window's run-length structure (``static_regen`` repeats one
        graph per regen epoch; smooth mobility is one per round)."""
        return [self._apply_dropouts_sparse(g, rng) for g in graphs]


class CommModel:
    """Price one zone round in (bytes, latency_s, energy_j).

    Per transmission of ``b`` bytes over distance ``d``:
      latency  = base_latency_s + b / bandwidth
      E_tx     = b · (e_elec + e_amp · d^η)
      E_rx     = b · e_elec
    scaled by expected transmission count 1/p(d) (capped by the link
    model's ``min_success``; p ≡ 1 when no link model is attached).
    The broadcast is one transmission sized to the farthest member
    (latency takes the worst link's retry count); uploads are
    sequential TDMA slots, so their latencies add.
    """

    def __init__(self, cfg: CommConfig, link: LinkModel | None = None,
                 path_loss_exp: float = 3.0):
        self.cfg = cfg
        self.link = link
        self.eta = link.cfg.path_loss_exp if link is not None \
            else path_loss_exp

    def _link_costs(self, d: np.ndarray, retries: np.ndarray,
                    payload: float
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-link (latency, tx energy, rx energy) of one ``payload``-
        byte transmission over distance ``d``, scaled by the expected
        transmission count ``retries`` — the one radio-cost formula
        shared by zone pricing and base-station pricing."""
        c = self.cfg
        t = (c.base_latency_s + payload / c.bandwidth_bytes_per_s) * retries
        e_tx = payload * (c.e_elec_j_per_byte
                          + c.e_amp_j_per_byte * d ** self.eta) * retries
        e_rx = payload * c.e_elec_j_per_byte * retries
        return t, e_tx, e_rx

    def _retries(self, d: np.ndarray, base: np.ndarray) -> np.ndarray:
        """Expected transmissions per link: base/p(d) under the link
        model (capped by its ``min_success``), ``base`` without one."""
        if self.link is None:
            return base
        return base / self.link.success_probability(d)

    def price_rounds(self, pos_ik: np.ndarray, mem_pos: np.ndarray,
                     mem_mask: np.ndarray, payload_bytes: int
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized pricing of R zone rounds in one pass.

        pos_ik (R, 2) server positions, mem_pos (R, Z, 2) padded member
        positions, mem_mask (R, Z) ∈ {0,1} live *non-self* members.
        Returns (latency_s (R,), energy_j (R,)).

        Broadcast and uploads traverse the same links, so one per-link
        evaluation prices both directions: broadcast — one TX sized to
        the farthest member, every member receives, the worst link
        gates the latency; uploads — one TX per member, sequential
        TDMA slots (sum). Rounds with no live members (solo zone: the
        walker updates in place) price to zero. This single code path
        serves both the eager per-round driver (R = 1) and whole
        precomputed schedules, so the engines price identically.
        """
        payload = float(payload_bytes)
        d = np.linalg.norm(mem_pos - pos_ik[:, None, :], axis=2)  # (R, Z)
        m = np.asarray(mem_mask, dtype=np.float64)
        t, e_tx, e_rx = self._link_costs(d, self._retries(d, m), payload)
        latency = t.max(axis=1) + t.sum(axis=1)
        energy = (e_tx.max(axis=1) + e_rx.sum(axis=1)      # broadcast
                  + e_tx.sum(axis=1) + e_rx.sum(axis=1))   # uploads
        return latency, energy

    def price_schedule(self, graphs, clients: np.ndarray, idx: np.ndarray,
                       mask: np.ndarray, payload_bytes: int
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Price a whole precomputed schedule: R per-round position
        gathers, then one vectorized :meth:`price_rounds` pass."""
        clients = np.asarray(clients)
        pos_ik = np.stack([g.positions[int(c)]
                           for g, c in zip(graphs, clients)])
        mem_pos = np.stack([g.positions[i]
                            for g, i in zip(graphs, idx)])
        mem_mask = np.asarray(mask) * (idx != clients[:, None])
        return self.price_rounds(pos_ik, mem_pos, mem_mask, payload_bytes)

    def price_fleet_schedule(self, graphs, clients: np.ndarray,
                             idx: np.ndarray, mask: np.ndarray,
                             payload_bytes: int
                             ) -> tuple[np.ndarray, np.ndarray]:
        """Per-walker pricing of a simultaneous-fleet window.

        clients (R, K) walker positions, idx (R, K, Z) / mask (R, K, Z)
        padded zones. Each walker's zone is an independent short-range
        exchange, so the walker axis flattens into the round axis and
        one :meth:`price_schedule` pass prices all R·K zones; returns
        ((R, K), (R, K)) latency/energy columns for the caller to
        aggregate (wall latency = max over walkers — the zones are
        served in parallel — and energy = sum).
        """
        clients = np.asarray(clients)
        rounds, k_walkers = clients.shape
        graphs_f = [g for g in graphs for _ in range(k_walkers)]
        lat, en = self.price_schedule(
            graphs_f, clients.reshape(-1),
            np.asarray(idx).reshape(rounds * k_walkers, -1),
            np.asarray(mask).reshape(rounds * k_walkers, -1),
            payload_bytes)
        return (lat.reshape(rounds, k_walkers),
                en.reshape(rounds, k_walkers))

    def price_round(self, graph: ClientGraph, i_k: int, idx: np.ndarray,
                    mask: np.ndarray, payload_bytes: int
                    ) -> tuple[float, float]:
        """Latency and energy for one zone round (deterministic)."""
        lat, en = self.price_schedule(
            [graph], np.asarray([i_k]), np.asarray(idx)[None],
            np.asarray(mask)[None], payload_bytes)
        return float(lat[0]), float(en[0])

    def price_star_round(self, positions: np.ndarray, members: np.ndarray,
                         payload_bytes: int) -> tuple[float, float]:
        """Infrastructure baseline pricing: every selected client
        exchanges one model copy each way with a base station at the
        field center (0.5, 0.5). Used by the FedAvg-family trainers so
        wireless costs are comparable across algorithms."""
        members = np.asarray(members)
        if len(members) == 0:
            return 0.0, 0.0
        payload = float(payload_bytes)
        d = np.linalg.norm(positions[members] - 0.5, axis=1)
        t, e_tx, e_rx = self._link_costs(
            d, self._retries(d, np.ones_like(d)), payload)
        # Download + upload per client; uplink slots shared (sum), the
        # broadcast downlink gated by the worst client.
        latency = float(t.max() + t.sum())
        energy = float(2.0 * (e_tx.sum() + e_rx.sum()))
        return latency, energy
