"""Scenario configuration dataclasses + named-scenario registry.

A scenario is the full environment the mobile server operates in
(paper §5's "infrastructure-less wireless environment"), split into
three orthogonal, individually-toggleable layers:

  * **mobility** — how client positions evolve and how connectivity is
    derived from them (``mobility.py``),
  * **links** — per-link wireless quality: log-distance path loss +
    shadowing → success probability, stochastic link dropouts, and the
    comm-cost model pricing each round in bytes/latency/energy
    (``links.py``),
  * **churn** — client availability: duty-cycled radios and stragglers
    masked out of zones (``churn.py``).

Everything here is host-side control plane: scenarios decide *which*
clients form each round's zone and what the round costs, then compile
into the fixed-shape ``ZoneSchedule`` arrays, so the compiled
``engine="scan"``/``"scan_fused"`` hot path is scenario-agnostic.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MobilityConfig:
    """How client positions (unit square) evolve per round.

    model:
      * ``static_regen`` — i.i.d. position redraw every ``regen_every``
        rounds (the seed repo's ``DynamicGraph``, bit-for-bit).
      * ``random_waypoint`` — each client moves toward a uniformly drawn
        waypoint at a per-leg speed in [speed_min, speed_max], pausing
        ``pause_rounds`` on arrival.
      * ``gauss_markov`` — temporally correlated velocities,
        v' = α v + (1−α) v̄ + σ√(1−α²) w, reflected at the boundary.
      * ``trace`` — replay a recorded (R, n, 2) position trace named by
        ``trace_path`` (a ``register_trace`` name or an ``.npz``/``.npy``
        file), looping past the end; consumes no RNG.
    """

    model: str = "static_regen"
    min_degree: int = 5          # degree floor patched into connectivity
    regen_every: int = 10        # static_regen redraw period (rounds)
    radio_range: float = 0.35    # connectivity radius (unit square)
    speed_min: float = 0.01      # random_waypoint leg speed (units/round)
    speed_max: float = 0.05
    pause_rounds: int = 0        # random_waypoint dwell time at waypoints
    alpha: float = 0.85          # gauss_markov velocity memory
    mean_speed: float = 0.02     # gauss_markov long-run speed v̄ magnitude
    sigma_speed: float = 0.01    # gauss_markov velocity noise σ
    # trace replay source: a name registered via
    # scenarios.register_trace(name, positions) or a path to an .npz
    # (key "positions") / .npy file holding an (R, n, 2) unit-square
    # array. A plain string keeps this dataclass hashable/frozen.
    trace_path: str = ""


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Wireless link quality: log-distance path loss + shadowing.

    PL(d) = ref_loss_db + 10·η·log10(max(d, d0)/d0), and the fade margin
    M(d) = tx_power_dbm − sensitivity_dbm − PL(d). Shadowing is folded
    into a logistic success curve  p(d) = σ(M(d)/shadowing_db), clipped
    to [min_success, 1]. When ``dropout`` is set, each edge survives a
    round with probability p(d) (then connectivity is re-patched so the
    walk chain stays irreducible).
    """

    enabled: bool = False
    path_loss_exp: float = 3.0       # η
    ref_loss_db: float = 40.0        # PL at the reference distance d0
    ref_distance: float = 0.05       # d0 (unit-square units)
    tx_power_dbm: float = 10.0
    sensitivity_dbm: float = -68.0
    shadowing_db: float = 8.0        # logistic shadowing scale
    min_success: float = 0.05        # retransmission-count cap = 1/this
    dropout: bool = True             # Bernoulli(p) per-edge per-round


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Round pricing constants (first-order radio model, Heinzelman
    et al.): E_tx(b, d) = b·(e_elec + e_amp·d^η), E_rx(b) = b·e_elec,
    latency per transmission = base_latency_s + bytes/bandwidth, scaled
    by expected retransmissions 1/p(d) under the link model. Constants
    are illustrative but internally consistent (bytes, seconds, joules,
    unit-square distances)."""

    bandwidth_bytes_per_s: float = 1.5e6   # ~12 Mbit/s short-range radio
    base_latency_s: float = 0.002          # per-transmission overhead
    e_elec_j_per_byte: float = 4e-7        # electronics energy, tx & rx
    e_amp_j_per_byte: float = 8e-7         # amplifier energy at d = 1


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Client availability. Duty-cycling: client i is awake iff
    ((round + phase_i) mod period) < duty_cycle·period, with per-client
    phases drawn once. Stragglers: a fixed ``straggler_frac`` subset
    additionally misses each round with probability ``straggler_p``
    (slow compute / drained battery). The visited client i_k always
    participates — the server is physically at its location."""

    enabled: bool = False
    duty_cycle: float = 0.75
    period: int = 20
    straggler_frac: float = 0.0
    straggler_p: float = 0.5


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    name: str = "custom"
    mobility: MobilityConfig = MobilityConfig()
    links: LinkConfig = LinkConfig()
    comm: CommConfig = CommConfig()
    churn: ChurnConfig = ChurnConfig()
    # Batched-rollout chunk: Scenario.schedule materializes at most this
    # many rounds of (R, n, n) link/geometry tensors at once — the
    # memory/speed trade-off knob for large windows (docs/scenarios.md).
    # RNG consumption is chunk-size-invariant, so changing it never
    # changes trajectories.
    rollout_chunk: int = 128
    # Graph backend: "dense" keeps O(n²) adjacency/distance matrices
    # (the small-n oracle); "sparse" stores capped-degree (n, k) neighbor
    # lists built by grid-bucket search — O(n·k) control plane, the
    # large-n lane (docs/scenarios.md §Graph backends). Everything
    # RNG-free (graphs, zones, pricing) is bit-identical across
    # backends; link *dropout sampling* draws per-edge instead of per-
    # matrix, a documented RNG-stream break between backends.
    graph_backend: str = "dense"
    # Sparse-backend degree cap: each node keeps at most this many
    # in-range neighbors (nearest first); min-degree/connectivity
    # patches may exceed it (lists grow). For dense-parity at small n,
    # set it at or above the realized max degree.
    neighbor_k_max: int = 64


# ---------------------------------------------------------------------------
# Registry: named presets + user registration.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ScenarioConfig] = {}


def register_scenario(cfg: ScenarioConfig) -> ScenarioConfig:
    """Register (or overwrite) a named scenario preset."""
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_scenario_config(name: str) -> ScenarioConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; known: {sorted(_REGISTRY)}"
        ) from None


def available_scenarios() -> list[str]:
    return sorted(_REGISTRY)


register_scenario(ScenarioConfig(name="static_regen"))
register_scenario(ScenarioConfig(
    name="random_waypoint",
    mobility=MobilityConfig(model="random_waypoint"),
))
register_scenario(ScenarioConfig(
    name="gauss_markov",
    mobility=MobilityConfig(model="gauss_markov"),
))
# Lossy urban canyon: waypoint mobility + shadowed links that drop.
register_scenario(ScenarioConfig(
    name="lossy_links",
    mobility=MobilityConfig(model="random_waypoint"),
    links=LinkConfig(enabled=True),
))
# Battery-constrained fleet: duty-cycled radios + stragglers.
register_scenario(ScenarioConfig(
    name="duty_cycle",
    mobility=MobilityConfig(model="random_waypoint"),
    churn=ChurnConfig(enabled=True, straggler_frac=0.2),
))
# Everything at once: the paper's tactical-field setting, worst case.
register_scenario(ScenarioConfig(
    name="field_trial",
    mobility=MobilityConfig(model="gauss_markov"),
    links=LinkConfig(enabled=True),
    churn=ChurnConfig(enabled=True, straggler_frac=0.2),
))
