"""Client availability: duty-cycled radios and stragglers.

Host-side control plane. Each round yields an (n,) bool mask; offline
clients are dropped from zones before subsampling (the visited client
i_k always participates — the server is physically at its location).

  * Duty cycling: client i is awake iff
    ((round + phase_i) mod period) < duty_cycle · period, with phases
    drawn once at reset — staggered sleep schedules, the standard
    sensor-network energy policy.
  * Stragglers: a fixed ``straggler_frac`` subset additionally misses
    each round with probability ``straggler_p`` (slow compute, drained
    battery) — an independent Bernoulli draw per straggler per round.
"""
from __future__ import annotations

import numpy as np

from .config import ChurnConfig


class ChurnModel:
    def __init__(self, n: int, cfg: ChurnConfig):
        self.n = n
        self.cfg = cfg

    def reset(self, rng: np.random.Generator) -> np.ndarray:
        self.phase = rng.integers(self.cfg.period, size=self.n)
        k = int(round(self.cfg.straggler_frac * self.n))
        self.stragglers = np.zeros(self.n, dtype=bool)
        if k > 0:
            self.stragglers[
                rng.choice(self.n, size=k, replace=False)] = True
        return self._avail(0, rng)

    def step(self, round_idx: int, rng: np.random.Generator) -> np.ndarray:
        return self._avail(round_idx, rng)

    def rollout(self, start_round: int, rounds: int,
                rng: np.random.Generator) -> np.ndarray:
        """(rounds, n) availability masks for rounds ``start_round ..
        start_round + rounds - 1`` in one vectorized pass. The straggler
        tensor is one (rounds, n) draw — bit-identical to ``rounds``
        sequential per-round draws, so batched and stepped schedules
        replay each other exactly."""
        c = self.cfg
        rs = np.arange(start_round, start_round + rounds)
        on = ((rs[:, None] + self.phase[None, :]) % c.period) \
            < c.duty_cycle * c.period
        miss = rng.uniform(size=(rounds, self.n)) < c.straggler_p
        return on & ~(self.stragglers[None, :] & miss)

    def _avail(self, round_idx: int,
               rng: np.random.Generator) -> np.ndarray:
        c = self.cfg
        on = ((round_idx + self.phase) % c.period) \
            < c.duty_cycle * c.period
        # Fixed-shape draw (all n) so RNG consumption is independent of
        # the straggler set — replays stay aligned across configs.
        miss = rng.uniform(size=self.n) < c.straggler_p
        return on & ~(self.stragglers & miss)
