"""The paper's "Synthetic" dataset — the pFedMe / FedProx generative
procedure (paper §5 cites [19]; 60 features, 10 classes, 100 clients).

Synthetic(α, β):
  for client k:
    u_k ~ N(0, α),  b_k ~ N(0, α)            (model heterogeneity)
    B_k ~ N(0, β)                              (feature-mean heterogeneity)
    v_k ~ N(B_k, 1)  per-dim feature mean
    Σ diagonal with Σ_jj = j^{-1.2}            (decaying covariance)
    W_k ~ N(u_k, 1) ∈ R^{d×C},  c_k ~ N(b_k, 1) ∈ R^C
    x ~ N(v_k, Σ);   y = argmax softmax(W_kᵀ x + c_k)
  sample counts follow a lognormal power law.
"""
from __future__ import annotations

import numpy as np


def make_synthetic_lr(
    n_clients: int = 100,
    *,
    alpha: float = 0.5,
    beta: float = 0.5,
    n_features: int = 60,
    n_classes: int = 10,
    min_samples: int = 50,
    mean_samples: float = 4.0,  # lognormal mean of per-client counts
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Returns per-client list of (X (n_k, d) float32, y (n_k,) int32)."""
    rng = np.random.default_rng(seed)
    cov_diag = np.array(
        [(j + 1) ** (-1.2) for j in range(n_features)], dtype=np.float64
    )
    counts = (
        rng.lognormal(mean=mean_samples, sigma=1.0, size=n_clients).astype(int)
        + min_samples
    )
    out = []
    for k in range(n_clients):
        out.append(_client_pair(rng, int(counts[k]), alpha, beta,
                                n_features, n_classes, cov_diag))
    return out


def _client_pair(rng: np.random.Generator, count: int, alpha: float,
                 beta: float, n_features: int, n_classes: int,
                 cov_diag: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """One client's Synthetic(α, β) draw from a caller-owned rng — the
    generative math shared by the sequential generator above and the
    per-client-seeded lazy generator below."""
    u_k = rng.normal(0.0, np.sqrt(alpha))
    b_k = rng.normal(0.0, np.sqrt(alpha))
    big_b = rng.normal(0.0, np.sqrt(beta))
    v_k = rng.normal(big_b, 1.0, size=n_features)
    w_k = rng.normal(u_k, 1.0, size=(n_features, n_classes))
    c_k = rng.normal(b_k, 1.0, size=n_classes)
    x = rng.normal(
        loc=v_k[None, :], scale=np.sqrt(cov_diag)[None, :],
        size=(count, n_features),
    )
    logits = x @ w_k + c_k[None, :]
    e = np.exp(logits - logits.max(axis=1, keepdims=True))
    probs = e / e.sum(axis=1, keepdims=True)
    y = np.array([rng.choice(n_classes, p=p) for p in probs])
    return x.astype(np.float32), y.astype(np.int32)


def make_synthetic_lr_lazy(
    n_clients: int = 100,
    *,
    alpha: float = 0.5,
    beta: float = 0.5,
    n_features: int = 60,
    n_classes: int = 10,
    min_samples: int = 50,
    mean_samples: float = 4.0,
    seed: int = 0,
):
    """Per-client-seeded Synthetic(α, β): ``(counts, client_pair)``.

    :func:`make_synthetic_lr` draws every client from ONE sequential rng,
    so client k's data depends on generating clients 0..k-1 first — it
    cannot back a lazy client plane at n = 10⁶. This twin gives each
    client its own `SeedSequence`-derived stream (``default_rng([seed,
    k])``), so ``client_pair(k)`` is O(1), order-independent, and
    bit-reproducible after eviction. Sample counts are the only O(n)
    precompute (one vectorized lognormal draw, ~8 MB at n = 10⁶), which
    also fixes the padded row widths up front.

    Same generative procedure per client, different stream layout — the
    realized datasets differ from :func:`make_synthetic_lr` under the
    same seed (both are valid Synthetic(α, β) draws).
    """
    cov_diag = np.array(
        [(j + 1) ** (-1.2) for j in range(n_features)], dtype=np.float64
    )
    count_rng = np.random.default_rng([seed, n_clients])
    counts = (
        count_rng.lognormal(mean=mean_samples, sigma=1.0,
                            size=n_clients).astype(int)
        + min_samples
    )

    def client_pair(k: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng([seed, int(k)])
        return _client_pair(rng, int(counts[k]), alpha, beta,
                            n_features, n_classes, cov_diag)

    return counts, client_pair


def synthetic_lr_factory(n_clients: int = 100, *, test_frac: float = 0.25,
                         seed: int = 0, **kw):
    """A lazy :class:`~repro.data.loader.ClientDataFactory` over
    :func:`make_synthetic_lr_lazy`, with the same per-client 75/25
    train/test split :func:`~repro.data.loader.build_federated_from_pairs`
    applies to the eager generator — the data plane of the n = 10⁶
    lazy-plane benchmark (``benchmarks/scan_scaling.py --lazy``)."""
    from .loader import ClientDataFactory
    from .partition import train_test_split_indices

    counts, client_pair = make_synthetic_lr_lazy(n_clients, seed=seed, **kw)
    n_test = np.maximum(np.round(counts * test_frac).astype(int), 1)
    n_train = counts - n_test
    n_features = kw.get("n_features", 60)

    def fetch(k: int):
        x, y = client_pair(k)
        tr, te = train_test_split_indices(len(y), test_frac, seed + k)
        return x[tr], y[tr], x[te], y[te]

    return ClientDataFactory(
        n_clients=int(n_clients),
        max_train=int(n_train.max()),
        max_test=int(n_test.max()),
        feature_shape=(n_features,),
        fetch=fetch,
    )
