"""The paper's "Synthetic" dataset — the pFedMe / FedProx generative
procedure (paper §5 cites [19]; 60 features, 10 classes, 100 clients).

Synthetic(α, β):
  for client k:
    u_k ~ N(0, α),  b_k ~ N(0, α)            (model heterogeneity)
    B_k ~ N(0, β)                              (feature-mean heterogeneity)
    v_k ~ N(B_k, 1)  per-dim feature mean
    Σ diagonal with Σ_jj = j^{-1.2}            (decaying covariance)
    W_k ~ N(u_k, 1) ∈ R^{d×C},  c_k ~ N(b_k, 1) ∈ R^C
    x ~ N(v_k, Σ);   y = argmax softmax(W_kᵀ x + c_k)
  sample counts follow a lognormal power law.
"""
from __future__ import annotations

import numpy as np


def make_synthetic_lr(
    n_clients: int = 100,
    *,
    alpha: float = 0.5,
    beta: float = 0.5,
    n_features: int = 60,
    n_classes: int = 10,
    min_samples: int = 50,
    mean_samples: float = 4.0,  # lognormal mean of per-client counts
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Returns per-client list of (X (n_k, d) float32, y (n_k,) int32)."""
    rng = np.random.default_rng(seed)
    cov_diag = np.array(
        [(j + 1) ** (-1.2) for j in range(n_features)], dtype=np.float64
    )
    counts = (
        rng.lognormal(mean=mean_samples, sigma=1.0, size=n_clients).astype(int)
        + min_samples
    )
    out = []
    for k in range(n_clients):
        u_k = rng.normal(0.0, np.sqrt(alpha))
        b_k = rng.normal(0.0, np.sqrt(alpha))
        big_b = rng.normal(0.0, np.sqrt(beta))
        v_k = rng.normal(big_b, 1.0, size=n_features)
        w_k = rng.normal(u_k, 1.0, size=(n_features, n_classes))
        c_k = rng.normal(b_k, 1.0, size=n_classes)
        x = rng.normal(
            loc=v_k[None, :], scale=np.sqrt(cov_diag)[None, :],
            size=(counts[k], n_features),
        )
        logits = x @ w_k + c_k[None, :]
        e = np.exp(logits - logits.max(axis=1, keepdims=True))
        probs = e / e.sum(axis=1, keepdims=True)
        y = np.array([rng.choice(n_classes, p=p) for p in probs])
        out.append((x.astype(np.float32), y.astype(np.int32)))
    return out
