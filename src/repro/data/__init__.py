"""Offline data pipeline: synthetic datasets + federated partitioners."""
from .partition import dirichlet_split, pathological_split  # noqa: F401
from .synthetic_images import make_image_dataset  # noqa: F401
from .synthetic_lr import make_synthetic_lr  # noqa: F401
from .loader import ClientDataset, FederatedData, minibatch  # noqa: F401
