"""Offline data pipeline: synthetic datasets + federated partitioners."""
from .partition import dirichlet_split, pathological_split  # noqa: F401
from .synthetic_images import make_image_dataset  # noqa: F401
from .synthetic_lr import (  # noqa: F401
    make_synthetic_lr,
    make_synthetic_lr_lazy,
    synthetic_lr_factory,
)
from .loader import (  # noqa: F401
    ClientDataFactory,
    ClientDataset,
    FederatedData,
    factory_from_federated,
    minibatch,
)
