"""Offline stand-ins for MNIST / CIFAR10 (no network access in this
environment — see DESIGN.md §7.1).

Each class c gets a smooth random prototype image; samples are
``prototype + structured noise + random translation``, which yields a
learnable 10-class problem with MNIST/CIFAR-like shapes and difficulty
knobs. Class-conditional structure makes the *pathological non-IID* split
(2 labels per client) meaningfully heterogeneous, which is what the paper's
experiments stress.
"""
from __future__ import annotations

import numpy as np


def _smooth_noise(rng: np.random.Generator, shape, smooth: int = 3):
    """Low-frequency random field: random normal blurred by a box filter."""
    x = rng.normal(size=shape).astype(np.float32)
    for axis in range(2):  # blur H and W only
        for _ in range(smooth):
            x = 0.5 * x + 0.25 * (np.roll(x, 1, axis=axis)
                                  + np.roll(x, -1, axis=axis))
    return x


def make_image_dataset(
    n_samples: int,
    *,
    shape: tuple[int, int, int] = (28, 28, 1),   # MNIST-like; (32,32,3) CIFAR
    n_classes: int = 10,
    noise: float = 0.45,
    max_shift: int = 2,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (N,H,W,C) float32 in [0,1]-ish, labels (N,) int32)."""
    rng = np.random.default_rng(seed)
    h, w, c = shape
    protos = np.stack(
        [_smooth_noise(rng, (h, w, c)) for _ in range(n_classes)]
    )
    protos = (protos - protos.min()) / (np.ptp(protos) + 1e-8)

    labels = rng.integers(0, n_classes, size=n_samples).astype(np.int32)
    imgs = protos[labels].copy()
    imgs += noise * rng.normal(size=imgs.shape).astype(np.float32)
    if max_shift > 0:
        sh = rng.integers(-max_shift, max_shift + 1, size=(n_samples, 2))
        for i in range(n_samples):
            imgs[i] = np.roll(imgs[i], sh[i, 0], axis=0)
            imgs[i] = np.roll(imgs[i], sh[i, 1], axis=1)
    imgs = np.clip(imgs, -1.0, 2.0).astype(np.float32)
    return imgs, labels


def make_mnist_like(n_samples: int = 12_000, seed: int = 0):
    return make_image_dataset(n_samples, shape=(28, 28, 1), seed=seed)


def make_cifar_like(n_samples: int = 12_000, seed: int = 0):
    return make_image_dataset(
        n_samples, shape=(32, 32, 3), noise=0.6, seed=seed
    )
