"""Client dataset containers + padded stacked layout for vmapped FL.

The simulation runner jits a *single* round function over stacked client
arrays; per-client datasets are padded to a common ``max_samples`` with a
validity mask, so heterogeneous sizes (the paper's variable allocations)
never retrigger compilation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .partition import train_test_split_indices


@dataclasses.dataclass
class ClientDataset:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return len(self.y_train)

    @property
    def n_test(self) -> int:
        return len(self.y_test)


@dataclasses.dataclass
class FederatedData:
    """Stacked, padded federated dataset.

    x_train: (n_clients, max_train, *feat)   mask_train: (n_clients, max_train)
    x_test:  (n_clients, max_test, *feat)    mask_test:  (n_clients, max_test)
    """

    x_train: np.ndarray
    y_train: np.ndarray
    mask_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    mask_test: np.ndarray

    @property
    def n_clients(self) -> int:
        return self.x_train.shape[0]

    @property
    def feature_shape(self) -> tuple[int, ...]:
        return self.x_train.shape[2:]

    def client(self, i: int) -> ClientDataset:
        mt, me = self.mask_train[i].astype(bool), self.mask_test[i].astype(bool)
        return ClientDataset(
            x_train=self.x_train[i][mt], y_train=self.y_train[i][mt],
            x_test=self.x_test[i][me], y_test=self.y_test[i][me],
        )


def build_federated(
    features: np.ndarray,
    labels: np.ndarray,
    client_indices: list[np.ndarray],
    *,
    test_frac: float = 0.25,
    seed: int = 0,
) -> FederatedData:
    """Split each client's allocation 75/25 (paper §5), pad and stack."""
    clients = []
    for k, idx in enumerate(client_indices):
        tr, te = train_test_split_indices(len(idx), test_frac, seed + k)
        clients.append((features[idx[tr]], labels[idx[tr]],
                        features[idx[te]], labels[idx[te]]))
    return _stack(clients)


def build_federated_from_pairs(
    per_client: list[tuple[np.ndarray, np.ndarray]],
    *,
    test_frac: float = 0.25,
    seed: int = 0,
) -> FederatedData:
    """For generators that already emit per-client data (Synthetic(α,β))."""
    clients = []
    for k, (x, y) in enumerate(per_client):
        tr, te = train_test_split_indices(len(y), test_frac, seed + k)
        clients.append((x[tr], y[tr], x[te], y[te]))
    return _stack(clients)


def _stack(clients) -> FederatedData:
    max_tr = max(len(c[1]) for c in clients)
    max_te = max(len(c[3]) for c in clients)
    feat = clients[0][0].shape[1:]
    n = len(clients)

    def alloc(m, shape, dtype):
        return np.zeros((n, m) + shape, dtype=dtype)

    xt = alloc(max_tr, feat, np.float32)
    yt = alloc(max_tr, (), np.int32)
    mt = alloc(max_tr, (), np.float32)
    xe = alloc(max_te, feat, np.float32)
    ye = alloc(max_te, (), np.int32)
    me = alloc(max_te, (), np.float32)
    for k, (a, b, c, d) in enumerate(clients):
        xt[k, : len(b)] = a
        yt[k, : len(b)] = b
        mt[k, : len(b)] = 1.0
        xe[k, : len(d)] = c
        ye[k, : len(d)] = d
        me[k, : len(d)] = 1.0
    return FederatedData(xt, yt, mt, xe, ye, me)


def minibatch(
    rng: np.random.Generator,
    fed: FederatedData,
    client: int,
    batch_size: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Sample a minibatch ξ from one client's (unpadded) training data."""
    mask = fed.mask_train[client].astype(bool)
    valid = np.flatnonzero(mask)
    take = rng.choice(valid, size=min(batch_size, len(valid)),
                      replace=len(valid) < batch_size)
    return fed.x_train[client][take], fed.y_train[client][take]


def minibatch_indices(
    rng: np.random.Generator, fed: FederatedData, client: int,
    batch_size: int,
) -> np.ndarray:
    """Index-only variant (fixed ``batch_size``, samples with replacement if
    the client is small) — keeps jitted round shapes static."""
    valid = np.flatnonzero(fed.mask_train[client].astype(bool))
    return rng.choice(valid, size=batch_size, replace=len(valid) < batch_size)
