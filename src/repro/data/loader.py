"""Client dataset containers + padded stacked layout for vmapped FL.

The simulation runner jits a *single* round function over stacked client
arrays; per-client datasets are padded to a common ``max_samples`` with a
validity mask, so heterogeneous sizes (the paper's variable allocations)
never retrigger compilation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .partition import train_test_split_indices


@dataclasses.dataclass
class ClientDataset:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return len(self.y_train)

    @property
    def n_test(self) -> int:
        return len(self.y_test)


@dataclasses.dataclass
class FederatedData:
    """Stacked, padded federated dataset.

    x_train: (n_clients, max_train, *feat)   mask_train: (n_clients, max_train)
    x_test:  (n_clients, max_test, *feat)    mask_test:  (n_clients, max_test)
    """

    x_train: np.ndarray
    y_train: np.ndarray
    mask_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    mask_test: np.ndarray

    @property
    def n_clients(self) -> int:
        return self.x_train.shape[0]

    @property
    def feature_shape(self) -> tuple[int, ...]:
        return self.x_train.shape[2:]

    def client(self, i: int) -> ClientDataset:
        mt, me = self.mask_train[i].astype(bool), self.mask_test[i].astype(bool)
        return ClientDataset(
            x_train=self.x_train[i][mt], y_train=self.y_train[i][mt],
            x_test=self.x_test[i][me], y_test=self.y_test[i][me],
        )


def build_federated(
    features: np.ndarray,
    labels: np.ndarray,
    client_indices: list[np.ndarray],
    *,
    test_frac: float = 0.25,
    seed: int = 0,
) -> FederatedData:
    """Split each client's allocation 75/25 (paper §5), pad and stack."""
    clients = []
    for k, idx in enumerate(client_indices):
        tr, te = train_test_split_indices(len(idx), test_frac, seed + k)
        clients.append((features[idx[tr]], labels[idx[tr]],
                        features[idx[te]], labels[idx[te]]))
    return _stack(clients)


def build_federated_from_pairs(
    per_client: list[tuple[np.ndarray, np.ndarray]],
    *,
    test_frac: float = 0.25,
    seed: int = 0,
) -> FederatedData:
    """For generators that already emit per-client data (Synthetic(α,β))."""
    clients = []
    for k, (x, y) in enumerate(per_client):
        tr, te = train_test_split_indices(len(y), test_frac, seed + k)
        clients.append((x[tr], y[tr], x[te], y[te]))
    return _stack(clients)


def _stack(clients) -> FederatedData:
    max_tr = max(len(c[1]) for c in clients)
    max_te = max(len(c[3]) for c in clients)
    feat = clients[0][0].shape[1:]
    n = len(clients)

    def alloc(m, shape, dtype):
        return np.zeros((n, m) + shape, dtype=dtype)

    xt = alloc(max_tr, feat, np.float32)
    yt = alloc(max_tr, (), np.int32)
    mt = alloc(max_tr, (), np.float32)
    xe = alloc(max_te, feat, np.float32)
    ye = alloc(max_te, (), np.int32)
    me = alloc(max_te, (), np.float32)
    for k, (a, b, c, d) in enumerate(clients):
        xt[k, : len(b)] = a
        yt[k, : len(b)] = b
        mt[k, : len(b)] = 1.0
        xe[k, : len(d)] = c
        ye[k, : len(d)] = d
        me[k, : len(d)] = 1.0
    return FederatedData(xt, yt, mt, xe, ye, me)


# ---------------------------------------------------------------------------
# Lazy client plane: per-client dataset factories.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClientDataFactory:
    """Deterministic on-demand source of per-client dataset rows.

    The lazy client plane (``client_plane="lazy"``) materializes a
    client's dataset only when the random walk first reaches it, instead
    of stacking all n clients up front (``_stack``/``to_device_data``).
    ``fetch(k)`` must be a pure function of ``k`` — re-materializing a
    client after eviction must reproduce byte-identical rows, which is
    what lets the bounded LRU store skip spilling data (only ADMM state
    spills; data is regenerated).

    ``rows(ids)`` pads every client to the declared ``max_train`` /
    ``max_test`` widths — the same zero-fill layout ``_stack`` uses, so
    a factory wrapped around a stacked :class:`FederatedData` reproduces
    its rows bit-for-bit (pinned in ``tests/test_lazy_plane.py``).
    """

    n_clients: int
    max_train: int
    max_test: int
    feature_shape: tuple
    fetch: Callable[[int], tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]]

    def rows(self, ids) -> tuple[np.ndarray, ...]:
        """Stacked padded rows for ``ids`` in DeviceData column order:
        (x_train, y_train, n_train, x_test, y_test, mask_test)."""
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        m = len(ids)
        feat = tuple(self.feature_shape)
        xt = np.zeros((m, self.max_train) + feat, np.float32)
        yt = np.zeros((m, self.max_train), np.int32)
        nt = np.zeros((m,), np.int32)
        xe = np.zeros((m, self.max_test) + feat, np.float32)
        ye = np.zeros((m, self.max_test), np.int32)
        me = np.zeros((m, self.max_test), np.float32)
        for j, k in enumerate(ids):
            a, b, c, d = self.fetch(int(k))
            if len(b) > self.max_train or len(d) > self.max_test:
                raise ValueError(
                    f"client {int(k)}: {len(b)} train / {len(d)} test "
                    f"samples exceed the factory's declared widths "
                    f"({self.max_train}, {self.max_test})")
            xt[j, : len(b)] = a
            yt[j, : len(b)] = b
            nt[j] = len(b)
            xe[j, : len(d)] = c
            ye[j, : len(d)] = d
            me[j, : len(d)] = 1.0
        return xt, yt, nt, xe, ye, me


def factory_from_federated(fed: FederatedData) -> ClientDataFactory:
    """Wrap an eagerly stacked dataset as a lazy factory (small-n
    equivalence testing: the factory's rows are literally slices of the
    dense arrays, so lazy ≡ dense data is exact by construction)."""

    def fetch(k: int):
        c = fed.client(k)
        return c.x_train, c.y_train, c.x_test, c.y_test

    return ClientDataFactory(
        n_clients=fed.n_clients,
        max_train=fed.x_train.shape[1],
        max_test=fed.x_test.shape[1],
        feature_shape=fed.feature_shape,
        fetch=fetch,
    )


def minibatch(
    rng: np.random.Generator,
    fed: FederatedData,
    client: int,
    batch_size: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Sample a minibatch ξ from one client's (unpadded) training data."""
    mask = fed.mask_train[client].astype(bool)
    valid = np.flatnonzero(mask)
    take = rng.choice(valid, size=min(batch_size, len(valid)),
                      replace=len(valid) < batch_size)
    return fed.x_train[client][take], fed.y_train[client][take]


def minibatch_indices(
    rng: np.random.Generator, fed: FederatedData, client: int,
    batch_size: int,
) -> np.ndarray:
    """Index-only variant (fixed ``batch_size``, samples with replacement if
    the client is small) — keeps jitted round shapes static."""
    valid = np.flatnonzero(fed.mask_train[client].astype(bool))
    return rng.choice(valid, size=batch_size, replace=len(valid) < batch_size)
