"""Federated partitioners.

``pathological_split`` is the paper's §5 setting: "The data on each client
contains a portion of labels (two out of ten labels), and the allocated
data size for each client is variable."  ``dirichlet_split`` is the
standard Dir(α) alternative (beyond-paper, used in ablations).
"""
from __future__ import annotations

import numpy as np


def pathological_split(
    labels: np.ndarray,
    n_clients: int,
    *,
    labels_per_client: int = 2,
    size_variability: float = 0.5,
    seed: int = 0,
) -> list[np.ndarray]:
    """Returns per-client index arrays. Each client draws from exactly
    ``labels_per_client`` classes; per-client sizes vary by up to
    ±``size_variability`` relative to the mean."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    ptr = [0] * n_classes

    # Assign label pairs round-robin so every class is used roughly equally.
    client_labels = []
    pool = rng.permutation(
        np.tile(np.arange(n_classes),
                int(np.ceil(n_clients * labels_per_client / n_classes)))
    )
    p = 0
    for _ in range(n_clients):
        chosen: list[int] = []
        while len(chosen) < labels_per_client:
            c = int(pool[p % len(pool)])
            p += 1
            if c not in chosen:
                chosen.append(c)
        client_labels.append(chosen)

    # Per-(client, class) demand ∝ variable sizes.
    base = len(labels) // (n_clients * labels_per_client)
    out: list[np.ndarray] = []
    for k in range(n_clients):
        take: list[np.ndarray] = []
        for c in client_labels[k]:
            frac = 1.0 + size_variability * (rng.random() * 2.0 - 1.0)
            cnt = max(4, int(base * frac))
            avail = len(by_class[c]) - ptr[c]
            if avail < cnt:  # recycle with replacement if exhausted
                extra = rng.choice(by_class[c], size=cnt - avail)
                take.append(
                    np.concatenate([by_class[c][ptr[c]:], extra])
                )
                ptr[c] = len(by_class[c])
            else:
                take.append(by_class[c][ptr[c]: ptr[c] + cnt])
                ptr[c] += cnt
        out.append(np.concatenate(take))
    return out


def dirichlet_split(
    labels: np.ndarray,
    n_clients: int,
    *,
    alpha: float = 0.3,
    min_per_client: int = 8,
    seed: int = 0,
) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    out = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            out[k].extend(part.tolist())
    result = []
    all_idx = np.arange(len(labels))
    for k in range(n_clients):
        arr = np.asarray(out[k], dtype=np.int64)
        if len(arr) < min_per_client:
            arr = np.concatenate(
                [arr, rng.choice(all_idx, size=min_per_client - len(arr))]
            )
        result.append(arr)
    return result


def client_label_histograms(labels: np.ndarray, parts: list[np.ndarray],
                            n_classes: int | None = None) -> np.ndarray:
    """(n_clients, C) row-normalized label histograms of a partition —
    the data-utility substrate for the ``label_skew`` walk policy."""
    if n_classes is None:
        n_classes = int(labels.max()) + 1
    hist = np.zeros((len(parts), n_classes), np.float64)
    for k, idx in enumerate(parts):
        cnt = np.bincount(np.asarray(labels)[idx], minlength=n_classes)
        hist[k] = cnt / max(int(cnt.sum()), 1)
    return hist


def padded_label_histograms(y_padded: np.ndarray, n_valid: np.ndarray,
                            n_classes: int | None = None) -> np.ndarray:
    """(n, C) label histograms from the trainers' padded device layout:
    ``y_padded`` (n, m) labels with only the first ``n_valid[i]`` entries
    of row i real (``fl.base.DeviceData.y_train``/``n_train``)."""
    y = np.asarray(y_padded)
    n_valid = np.asarray(n_valid)
    if n_classes is None:
        n_classes = int(y.max()) + 1
    hist = np.zeros((y.shape[0], n_classes), np.float64)
    for k in range(y.shape[0]):
        cnt = np.bincount(y[k, : int(n_valid[k])], minlength=n_classes)
        hist[k] = cnt / max(int(cnt.sum()), 1)
    return hist


def label_skew_weights(hist: np.ndarray, *, gamma: float = 1.0
                       ) -> np.ndarray:
    """Per-client data-utility weights for the ``label_skew`` walk policy.

    A client's utility is the mean inverse global propensity of its
    labels, u_i = Σ_c h_ic · q̄/q_c (q = the fleet-average label
    distribution, q̄ = 1/C): u_i = 1 when client i's label mix matches
    the global mix, u_i ≫ 1 when it concentrates on globally rare
    labels. ``gamma`` sharpens (γ > 1) or flattens (γ < 1) the bias;
    the result is strictly positive and mean-normalized downstream by
    ``RandomWalkServer.set_label_weights``.
    """
    h = np.asarray(hist, np.float64)
    n_classes = h.shape[1]
    q = h.mean(axis=0)
    q = np.maximum(q, 1e-12)
    u = (h * ((1.0 / n_classes) / q)[None, :]).sum(axis=1)
    u = np.maximum(u, 1e-12)
    return u ** float(gamma)


def train_test_split_indices(
    n: int, test_frac: float = 0.25, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Paper §5: local datasets split 75% / 25% train/test."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_test = max(1, int(round(n * test_frac)))
    return perm[n_test:], perm[:n_test]
