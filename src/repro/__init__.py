"""repro: RWSADMM — mobilizing personalized FL via random-walk stochastic
ADMM (NeurIPS 2023) as a production JAX training/serving framework."""

__version__ = "1.0.0"
