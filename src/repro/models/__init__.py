"""Model zoo: the paper's small FL models + the assigned LM architectures."""
from .small import CNN, MLP, MLR, SmallModel  # noqa: F401
