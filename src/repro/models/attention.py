"""GQA attention: training/prefill (query-chunked, flash-style online
softmax at the HLO level) and single-token decode against a KV cache
(full cache for global layers, ring buffer for sliding-window layers).

The query-chunked lax.scan formulation keeps the attention transient at
O(chunk × S) instead of O(S²) — this doubles as the jnp oracle for the
Pallas ``flash_decode`` kernel (kernels/flash_decode/ref.py reuses it).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_mrope, apply_rope, dense_init

NEG_INF = -1e30


def attn_init(key, cfg, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt, scale=1.0 / np.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _project_qkv(params, x, x_kv, cfg):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ params["wq"]
    k = x_kv @ params["wk"]
    v = x_kv @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, x_kv.shape[1], kv, hd)
    v = v.reshape(b, x_kv.shape[1], kv, hd)
    return q, k, v


def _rope_qk(q, k, positions, cfg):
    if cfg.rope == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k


def _chunked_attention(q, k, v, *, causal: bool, window: int | None,
                       chunk: int = 512):
    """q: (B,S,H,hd), k/v: (B,T,K,hd). GQA by head grouping. Query-chunked
    scan; scores fp32; optional sliding window of size ``window``."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, s, kvh, g, hd)

    chunk = min(chunk, s)
    n_chunks = s // chunk if s % chunk == 0 else -1
    if n_chunks == -1:  # pad to a chunk multiple
        pad = (-s) % chunk
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        n_chunks = (s + pad) // chunk
    qg = qg.reshape(b, n_chunks, chunk, kvh, g, hd)
    kpos = jnp.arange(t)

    def one_chunk(carry, inp):
        qc, idx = inp  # (B, chunk, K, G, hd), scalar chunk index
        qpos = idx * chunk + jnp.arange(chunk)
        scores = jnp.einsum(
            "bqkgh,btkh->bkgqt", qc.astype(jnp.float32),
            k.astype(jnp.float32),
        ) * scale
        mask = jnp.ones((chunk, t), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqt,btkh->bqkgh", w, v.astype(jnp.float32))
        return carry, out.astype(q.dtype)

    qg_t = jnp.moveaxis(qg, 1, 0)  # (n_chunks, B, chunk, K, G, hd)
    _, outs = jax.lax.scan(one_chunk, None,
                           (qg_t, jnp.arange(n_chunks)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * chunk, kvh, g, hd)
    return out[:, :s].reshape(b, s, h * hd)


def attention(params, x, positions, cfg, *, kind: str = "attn",
              x_kv=None, causal: bool = True, chunk: int = 512):
    """Training/prefill attention. kind: "attn" (global) | "local"."""
    x_kv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(params, x, x_kv, cfg)
    if x_kv is x:
        q, k = _rope_qk(q, k, positions, cfg)
    window = cfg.window if kind == "local" else None
    out = _chunked_attention(q, k, v, causal=causal and x_kv is x,
                             window=window, chunk=chunk)
    return out @ params["wo"]


# ------------------------------------------------------------ decode ------
class KVCache(NamedTuple):
    """KV cache for one attention layer (possibly stacked over repeats).

    k/v: (B, S_cache, K, hd). ``length`` — valid prefix (global layers) or
    total tokens written (ring layers, where S_cache == window)."""
    k: jnp.ndarray
    v: jnp.ndarray
    length: jnp.ndarray  # scalar int32


def init_kv_cache(cfg, batch: int, max_len: int, kind: str,
                  dtype=None) -> KVCache:
    size = min(max_len, cfg.window) if kind == "local" else max_len
    dt = dtype or jnp.dtype(cfg.dtype)
    shape = (batch, size, cfg.n_kv_heads, cfg.hd)
    return KVCache(
        k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
        length=jnp.zeros((), jnp.int32),
    )


def prefill_attention(params, x, positions, cache: KVCache, cfg, *,
                      kind: str = "attn", chunk: int = 512):
    """Prefill: full-sequence attention that also fills the KV cache.

    Global layers write positions [0, T); local layers keep the last
    ``window`` entries at their ring slots (slot = pos % window)."""
    t = x.shape[1]
    q, k, v = _project_qkv(params, x, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    window = cfg.window if kind == "local" else None
    out = _chunked_attention(q, k, v, causal=True, window=window,
                             chunk=chunk)
    size = cache.k.shape[1]
    if kind == "local" and t > size:
        keep = jnp.arange(t - size, t)
        slots = keep % size
        k_c = jnp.zeros_like(cache.k).at[:, slots].set(
            k[:, keep].astype(cache.k.dtype))
        v_c = jnp.zeros_like(cache.v).at[:, slots].set(
            v[:, keep].astype(cache.v.dtype))
    else:
        k_c = jax.lax.dynamic_update_slice(
            cache.k, k[:, :min(t, size)].astype(cache.k.dtype),
            (0, 0, 0, 0))
        v_c = jax.lax.dynamic_update_slice(
            cache.v, v[:, :min(t, size)].astype(cache.v.dtype),
            (0, 0, 0, 0))
    new_cache = KVCache(k=k_c, v=v_c,
                        length=jnp.asarray(t, jnp.int32))
    return out @ params["wo"], new_cache


def decode_attention(params, x, cache: KVCache, cfg, *, kind: str = "attn",
                     use_pallas: bool = False):
    """One-token decode: x (B, 1, d) against the cache; returns
    (out (B,1,d), new cache). Ring-buffer write for local layers.

    use_pallas=True routes the attention contraction through the
    kernels/flash_decode Pallas kernel (VMEM-blocked online softmax) —
    validated against this jnp path in tests/test_kernels_integration."""
    b = x.shape[0]
    pos = cache.length  # current absolute position of the new token
    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope == "mrope":
        positions = jnp.stack([positions] * 3, 0)
    q, k_new = _rope_qk(q, k_new, positions, cfg)

    size = cache.k.shape[1]
    slot = jnp.where(kind == "local", pos % size, jnp.minimum(pos, size - 1))
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))

    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if use_pallas:
        from ..kernels.flash_decode.ops import flash_decode

        # ring buffers hold every slot valid once full; express validity
        # through `length` + window on the kernel side.
        if kind == "local":
            length = jnp.minimum(pos + 1, size)
            length = jnp.broadcast_to(length, (b,))
            out = flash_decode(q[:, 0], k, v, length)
        else:
            out = flash_decode(q[:, 0], k, v,
                               jnp.broadcast_to(pos + 1, (b,)))
        out = out.reshape(b, 1, h * hd).astype(x.dtype)
        return out @ params["wo"], KVCache(k=k, v=v, length=pos + 1)
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    tpos = jnp.arange(size)
    if kind == "local":
        valid = (tpos <= pos % size) | (pos >= size)
    else:
        valid = tpos <= jnp.minimum(pos, size - 1)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    return out @ params["wo"], KVCache(k=k, v=v, length=pos + 1)
