"""Composable decoder LM covering all assigned architectures.

A model is a repeating ``layer_pattern`` of mixer kinds (attn / local /
rglru / mlstm / slstm) + FFN (dense SwiGLU/GELU or MoE), scanned over
``pattern_repeats`` with stacked parameters (compact HLO, fast compiles,
remat-friendly). Encoder-decoder (whisper) and multimodal stubs (VLM /
audio) are handled by input assembly around the same block stack.

Distribution: pure GSPMD (pjit in/out shardings, see repro.launch) except
the MoE FFN, which runs in an explicit shard_map island (expert parallel —
see models/moe.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import recurrent as rec_mod
from .layers import (
    dense_init,
    embedding_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """How the model should use the mesh (None ⇒ single-device math)."""

    mesh: Any = None
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    zero3_moe: bool = False      # store MoE expert hidden dim sharded
                                 # over the data axis, gather per layer


class LM:
    """Decoder-only LM (also the VLM/audio backbone and whisper decoder)."""

    def __init__(self, cfg: ModelConfig, ctx: Optional[ShardingCtx] = None,
                 *, unroll: bool = False):
        self.cfg = cfg
        self.ctx = ctx
        self.pattern = cfg.layer_pattern
        self.repeats = cfg.pattern_repeats
        # unroll=True fully unrolls the layer scan — used by the dry-run's
        # flop-accounting variants (XLA cost_analysis counts a scan body
        # once, not ×trip-count; see launch/dryrun.py).
        self.unroll = self.repeats if unroll else 1

    # ------------------------------------------------------------ init --
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k_emb, k_head, k_layers, k_front, k_enc = jax.random.split(key, 5)

        def init_block(kind, k):
            ks = jax.random.split(k, 4)
            p = {"norm1": rmsnorm_init(cfg.d_model, dt)}
            if kind in ("attn", "local"):
                p["mix"] = attn_mod.attn_init(ks[0], cfg)
            elif kind == "rglru":
                p["mix"] = rec_mod.rglru_init(ks[0], cfg)
            elif kind == "mlstm":
                p["mix"] = rec_mod.mlstm_init(ks[0], cfg)
            elif kind == "slstm":
                p["mix"] = rec_mod.slstm_init(ks[0], cfg)
            else:
                raise ValueError(kind)
            if cfg.moe is not None:
                p["norm2"] = rmsnorm_init(cfg.d_model, dt)
                p["ffn"] = moe_mod.moe_init(ks[1], cfg)
            elif cfg.d_ff > 0:
                p["norm2"] = rmsnorm_init(cfg.d_model, dt)
                p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
            return p

        layer_keys = jax.random.split(k_layers, self.repeats)
        layers = []
        for gi, kind in enumerate(self.pattern):
            stacked = jax.vmap(
                lambda k, kind=kind, gi=gi: init_block(
                    kind, jax.random.fold_in(k, gi))
            )(layer_keys)
            layers.append(stacked)

        params = {
            "embed": embedding_init(k_emb, cfg.vocab, cfg.d_model, dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
            "layers": tuple(layers),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dt)
        if cfg.frontend == "vision_stub":
            params["projector"] = dense_init(
                k_front, cfg.d_model, cfg.d_model, dt)
        if self._needs_pos_table():
            params["pos_embed"] = (jax.random.normal(
                k_front, (cfg.max_pos, cfg.d_model), jnp.float32)
                * 0.02).astype(dt)
        return params

    def _needs_pos_table(self) -> bool:
        """Learned positions only for rope-less ATTENTION archs; recurrent
        stacks (xLSTM) are order-aware and need none."""
        cfg = self.cfg
        return cfg.rope == "none" and any(
            k in ("attn", "local") for k in cfg.layer_pattern)

    # --------------------------------------------------------- forward --
    def _block(self, p, h, kind: str, positions, decode_cache=None):
        cfg = self.cfg
        hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
        new_cache = None
        if decode_cache is None:
            if kind in ("attn", "local"):
                mixed = attn_mod.attention(p["mix"], hn, positions, cfg,
                                           kind=kind)
            elif kind == "rglru":
                mixed = rec_mod.rglru_block(p["mix"], hn)
            elif kind == "mlstm":
                mixed = rec_mod.mlstm_block(p["mix"], hn, cfg)
            elif kind == "slstm":
                mixed = rec_mod.slstm_block(p["mix"], hn, cfg)
        else:
            if kind in ("attn", "local"):
                mixed, new_cache = attn_mod.decode_attention(
                    p["mix"], hn, decode_cache, cfg, kind=kind)
            elif kind == "rglru":
                mixed, new_cache = rec_mod.rglru_decode_step(
                    p["mix"], hn, decode_cache)
            elif kind == "mlstm":
                mixed, new_cache = rec_mod.mlstm_decode_step(
                    p["mix"], hn, decode_cache, cfg)
            elif kind == "slstm":
                mixed, new_cache = rec_mod.slstm_decode_step(
                    p["mix"], hn, decode_cache, cfg)
        h = h + mixed
        if "ffn" in p:
            hn2 = rmsnorm(p["norm2"], h, cfg.norm_eps)
            if cfg.moe is not None:
                h = h + self._moe(p["ffn"], hn2)
            else:
                h = h + mlp(p["ffn"], hn2, cfg.act)
        return h, new_cache

    def _moe(self, p, h):
        cfg, ctx = self.cfg, self.ctx
        if ctx is None or ctx.mesh is None:
            return moe_mod.moe_ffn_local(p, h, cfg)
        try:
            from jax import shard_map
        except ImportError:  # older jax
            from jax.experimental.shard_map import shard_map

        mp, za = ctx.model_axis, ("data" if ctx.zero3_moe else None)
        w_spec = P(mp, None, za)
        wo_spec = P(mp, za, None)
        shared_spec = {"w_in": P(None, mp), "w_gate": P(None, mp),
                       "w_out": P(mp, None)}
        in_specs = {
            "router": P(None, None),
            "w_in": w_spec, "w_gate": w_spec, "w_out": wo_spec,
        }
        if "shared" in p:
            in_specs["shared"] = shared_spec

        def local_fn(pl, xl):
            idx = jax.lax.axis_index(mp)
            out = moe_mod.moe_ffn_local(
                pl, xl, cfg, axis=mp, shard_index=idx,
                gather_axis=("data" if ctx.zero3_moe else None),
            )
            if "shared" in pl:
                # shared-expert partials were summed in the same psum
                pass
            return out

        x_spec = P(ctx.data_axes, None, None)
        import inspect

        kw = ("check_vma" if "check_vma"
              in inspect.signature(shard_map).parameters else "check_rep")
        return shard_map(
            local_fn, mesh=ctx.mesh,
            in_specs=(in_specs, x_spec),
            out_specs=x_spec,
            **{kw: False},
        )(p, h)

    def _assemble_inputs(self, params, batch):
        """Returns (h (B,S,d), positions, label_offset)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        h = params["embed"][tokens]
        b, s = tokens.shape
        offset = 0
        if cfg.frontend == "vision_stub":
            patches = batch["patches"].astype(h.dtype)  # (B, Pn, d) stub
            patches = patches @ params["projector"]
            h = jnp.concatenate([patches, h], axis=1)
            offset = patches.shape[1]
        s_total = h.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s_total), (b, s_total))
        if cfg.rope == "mrope":
            # Vision span: (t=0, row, col); text span: global index on all
            # three tracks (so decode positions continue seamlessly).
            pn = offset
            g = max(1, int(np.sqrt(max(pn, 1))))
            t_track = jnp.where(pos < pn, 0, pos)
            h_track = jnp.where(pos < pn, pos // g, pos)
            w_track = jnp.where(pos < pn, pos % g, pos)
            positions = jnp.stack([t_track, h_track, w_track], axis=0)
        else:
            positions = pos
        if self._needs_pos_table():
            h = h + params["pos_embed"][:s_total][None].astype(h.dtype)
        return h, positions, offset

    def apply(self, params, batch) -> jnp.ndarray:
        """Training/prefill forward → logits (B, S_total, vocab)."""
        h, positions, _ = self._assemble_inputs(params, batch)
        h = self._run_stack(params, h, positions)
        return self._logits(params, h)

    def _run_stack(self, params, h, positions):
        pattern = self.pattern

        def body(h, group_params):
            for gi, kind in enumerate(pattern):
                h, _ = self._block(group_params[gi], h, kind, positions)
            return h, None

        body = jax.checkpoint(body)  # remat per pattern group
        h, _ = jax.lax.scan(body, h, params["layers"], unroll=self.unroll)
        return rmsnorm(params["final_norm"], h, self.cfg.norm_eps)

    logits_dtype = jnp.float32  # §Perf knob: bf16 halves the logits psum
                                # bytes when the contraction dim is sharded

    def _logits(self, params, h):
        cfg = self.cfg
        dt = self.logits_dtype
        if cfg.tie_embeddings:
            out = h.astype(dt) @ params["embed"].astype(dt).T
        else:
            out = h.astype(dt) @ params["head"].astype(dt)
        return out.astype(jnp.float32)

    def loss(self, params, batch, *, ce_impl: str = "gather") -> jnp.ndarray:
        """Next-token cross entropy over the text span.

        ce_impl:
          "gather" — log_softmax + take_along_axis (baseline; under a
            model-sharded vocab the per-token dynamic gather forces GSPMD
            to materialize/gather full logits),
          "onehot" — nll = logsumexp(logits) − Σ logits·onehot(targets):
            both terms are contractions over the vocab axis, so they
            reduce *in place* on the vocab shards (psum of partials) —
            the §Perf hillclimb optimization.
        """
        logits = self.apply(params, batch)
        tokens = batch["tokens"]
        offset = logits.shape[1] - tokens.shape[1]
        logits = logits[:, offset:-1]
        targets = tokens[:, 1:]
        if ce_impl == "onehot":
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.sum(
                logits * jax.nn.one_hot(targets, logits.shape[-1],
                                        dtype=logits.dtype),
                axis=-1)
            return jnp.mean(lse - tgt)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll)

    # ---------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        groups = []
        for kind in self.pattern:
            if kind in ("attn", "local"):
                one = attn_mod.init_kv_cache(cfg, batch, max_len, kind)
            elif kind == "rglru":
                one = rec_mod.rglru_init_state(cfg, batch)
            elif kind == "mlstm":
                one = rec_mod.mlstm_init_state(cfg, batch)
            elif kind == "slstm":
                one = rec_mod.slstm_init_state(cfg, batch)
            stacked = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(
                    l, (self.repeats,) + l.shape), one)
            groups.append(stacked)
        return {"step": jnp.zeros((), jnp.int32), "groups": tuple(groups)}

    def prefill(self, params, batch, max_len: int):
        """Serving prefill: full forward that also fills the caches.

        Returns (logits (B, S_total, V), cache ready for decode_step)."""
        cfg = self.cfg
        h, positions, _ = self._assemble_inputs(params, batch)
        b, s_total = h.shape[0], h.shape[1]
        pattern = self.pattern
        cache = self.init_cache(b, max_len)

        def body(h, xs):
            group_params, group_cache = xs
            new_caches = []
            for gi, kind in enumerate(pattern):
                p = group_params[gi]
                hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
                if kind in ("attn", "local"):
                    mixed, nc = attn_mod.prefill_attention(
                        p["mix"], hn, positions, group_cache[gi], cfg,
                        kind=kind)
                elif kind == "rglru":
                    mixed, nc = rec_mod.rglru_block(
                        p["mix"], hn, return_state=True)
                elif kind == "mlstm":
                    mixed, nc = rec_mod.mlstm_block(
                        p["mix"], hn, cfg, return_state=True)
                elif kind == "slstm":
                    mixed, nc = rec_mod.slstm_block(
                        p["mix"], hn, cfg, return_state=True)
                h = h + mixed
                if "ffn" in p:
                    hn2 = rmsnorm(p["norm2"], h, cfg.norm_eps)
                    if cfg.moe is not None:
                        h = h + self._moe(p["ffn"], hn2)
                    else:
                        h = h + mlp(p["ffn"], hn2, cfg.act)
                new_caches.append(nc)
            return h, tuple(new_caches)

        if cfg.rope == "none":
            h = h  # pos-embed already added in _assemble_inputs
        h, new_groups = jax.lax.scan(
            body, h, (params["layers"], cache["groups"]),
            unroll=self.unroll)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = self._logits(params, h)
        return logits, {"step": jnp.asarray(s_total, jnp.int32),
                        "groups": new_groups}

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) → (logits (B, vocab), new cache)."""
        cfg = self.cfg
        h = params["embed"][tokens]
        if self._needs_pos_table():
            h = h + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], cache["step"], 1, 0
            )[None].astype(h.dtype)
        pattern = self.pattern

        def body(h, xs):
            group_params, group_cache = xs
            new_caches = []
            for gi, kind in enumerate(pattern):
                h, nc = self._block(group_params[gi], h, kind, None,
                                    decode_cache=group_cache[gi])
                new_caches.append(nc)
            return h, tuple(new_caches)

        h, new_groups = jax.lax.scan(
            body, h, (params["layers"], cache["groups"]),
            unroll=self.unroll)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = self._logits(params, h)[:, 0]
        return logits, {"step": cache["step"] + 1, "groups": new_groups}
