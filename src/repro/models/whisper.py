"""Whisper-style encoder-decoder backbone (audio family).

The mel-spectrogram + conv2 frontend is a STUB per the assignment: the
encoder consumes precomputed frame embeddings (B, encoder_seq, d). The
encoder (bidirectional attention) and decoder (causal self-attention +
cross-attention) stacks are real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from .layers import (
    embedding_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_positions,
)
from .transformer import ShardingCtx


class EncDecLM:
    def __init__(self, cfg: ModelConfig, ctx: ShardingCtx | None = None,
                 *, unroll: bool = False):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        self.ctx = ctx
        self.unroll_enc = cfg.encoder_layers if unroll else 1
        self.unroll_dec = cfg.n_layers if unroll else 1

    # ------------------------------------------------------------ init --
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 6)

        def enc_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "norm1": rmsnorm_init(cfg.d_model, dt),
                "attn": attn_mod.attn_init(k1, cfg),
                "norm2": rmsnorm_init(cfg.d_model, dt),
                "ffn": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dt),
            }

        def dec_block(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "norm1": rmsnorm_init(cfg.d_model, dt),
                "attn": attn_mod.attn_init(k1, cfg),
                "norm_x": rmsnorm_init(cfg.d_model, dt),
                "xattn": attn_mod.attn_init(k2, cfg),
                "norm2": rmsnorm_init(cfg.d_model, dt),
                "ffn": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, dt),
            }

        enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": embedding_init(ks[2], cfg.vocab, cfg.d_model, dt),
            "dec_pos": (jax.random.normal(ks[3], (cfg.max_pos, cfg.d_model),
                                          jnp.float32) * 0.02).astype(dt),
            "enc_layers": jax.vmap(enc_block)(enc_keys),
            "dec_layers": jax.vmap(dec_block)(dec_keys),
            "enc_norm": rmsnorm_init(cfg.d_model, dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
        }

    # --------------------------------------------------------- encoder --
    def encode(self, params, frames) -> jnp.ndarray:
        """frames: (B, encoder_seq, d) stub embeddings → (B, T, d)."""
        cfg = self.cfg
        t = frames.shape[1]
        h = frames.astype(jnp.dtype(cfg.dtype))
        h = h + sinusoidal_positions(t, cfg.d_model)[None].astype(h.dtype)
        b = h.shape[0]
        pos = jnp.broadcast_to(jnp.arange(t), (b, t))

        def body(h, p):
            hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
            h = h + attn_mod.attention(p["attn"], hn, pos, cfg,
                                       causal=False)
            hn = rmsnorm(p["norm2"], h, cfg.norm_eps)
            return h + mlp(p["ffn"], hn, cfg.act), None

        body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["enc_layers"],
                            unroll=self.unroll_enc)
        return rmsnorm(params["enc_norm"], h, cfg.norm_eps)

    # --------------------------------------------------------- decoder --
    def apply(self, params, batch) -> jnp.ndarray:
        """batch: {frames (B,T,d), tokens (B,S)} → logits (B,S,V)."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        h = params["embed"][tokens]
        h = h + params["dec_pos"][:s][None].astype(h.dtype)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))

        def body(h, p):
            hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
            h = h + attn_mod.attention(p["attn"], hn, pos, cfg, causal=True)
            hn = rmsnorm(p["norm_x"], h, cfg.norm_eps)
            h = h + attn_mod.attention(p["xattn"], hn, pos, cfg,
                                       x_kv=enc, causal=False)
            hn = rmsnorm(p["norm2"], h, cfg.norm_eps)
            return h + mlp(p["ffn"], hn, cfg.act), None

        body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["dec_layers"],
                            unroll=self.unroll_dec)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return (h.astype(jnp.float32)
                @ params["embed"].astype(jnp.float32).T)

    def loss(self, params, batch) -> jnp.ndarray:
        logits = self.apply(params, batch)[:, :-1]
        targets = batch["tokens"][:, 1:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll)

    # ---------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int, enc_out=None,
                   params=None) -> dict:
        """Self-attn KV caches per decoder layer + cross-attention K/V.

        When ``params`` is given, the encoder output is projected ONCE
        into per-layer cross K/V (the §Perf fix — without it, every
        decoded token re-projects the 1500-frame encoder output in every
        layer; the dry-run measured useful-flops ratio 0.01 for that
        path). Without params, cross K/V start zeroed and ``enc_out`` is
        kept for the recompute path."""
        cfg = self.cfg
        one = attn_mod.init_kv_cache(cfg, batch, max_len, "attn")
        self_kv = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape), one)
        if enc_out is None:
            enc_out = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        cache = {"step": jnp.zeros((), jnp.int32), "self_kv": self_kv,
                 "enc_out": enc_out}
        if params is not None:
            kv, hd = cfg.n_kv_heads, cfg.hd
            t = enc_out.shape[1]

            def one_layer(p):
                k = (enc_out @ p["xattn"]["wk"]).reshape(batch, t, kv, hd)
                v = (enc_out @ p["xattn"]["wv"]).reshape(batch, t, kv, hd)
                if cfg.qkv_bias:
                    k = k + p["xattn"]["bk"].reshape(kv, hd)
                    v = v + p["xattn"]["bv"].reshape(kv, hd)
                return k, v

            xk, xv = jax.lax.map(one_layer, params["dec_layers"])
            cache["cross_kv"] = {"k": xk, "v": xv}  # (L, B, T, K, hd)
        return cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        h = params["embed"][tokens]
        h = h + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], cache["step"], 1, 0)[None].astype(h.dtype)
        enc = cache["enc_out"]
        b = h.shape[0]
        pos = jnp.zeros((b, 1), jnp.int32)
        cached_cross = cache.get("cross_kv")

        def cross_attn(p, hn, xkv):
            """Cross-attention against precomputed K/V (one q token)."""
            import numpy as np

            kvh, hd, nh = cfg.n_kv_heads, cfg.hd, cfg.n_heads
            q = (hn @ p["xattn"]["wq"])
            if cfg.qkv_bias:
                q = q + p["xattn"]["bq"]
            q = q.reshape(b, nh, hd)
            g = nh // kvh
            qg = q.reshape(b, kvh, g, hd)
            scores = jnp.einsum(
                "bkgh,btkh->bkgt", qg.astype(jnp.float32),
                xkv["k"].astype(jnp.float32)) / np.sqrt(hd)
            w = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bkgt,btkh->bkgh", w,
                             xkv["v"].astype(jnp.float32))
            out = out.reshape(b, 1, nh * hd).astype(hn.dtype)
            return out @ p["xattn"]["wo"]

        def body(h, xs):
            if cached_cross is not None:
                p, kv, xkv = xs
            else:
                p, kv = xs
                xkv = None
            hn = rmsnorm(p["norm1"], h, cfg.norm_eps)
            mixed, kv_new = attn_mod.decode_attention(
                p["attn"], hn, kv, cfg, kind="attn")
            h = h + mixed
            hn = rmsnorm(p["norm_x"], h, cfg.norm_eps)
            if xkv is not None:
                h = h + cross_attn(p, hn, xkv)
            else:
                h = h + attn_mod.attention(p["xattn"], hn, pos, cfg,
                                           x_kv=enc, causal=False)
            hn = rmsnorm(p["norm2"], h, cfg.norm_eps)
            return h + mlp(p["ffn"], hn, cfg.act), kv_new

        xs = ((params["dec_layers"], cache["self_kv"], cached_cross)
              if cached_cross is not None
              else (params["dec_layers"], cache["self_kv"]))
        h, new_kv = jax.lax.scan(body, h, xs, unroll=self.unroll_dec)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = (h.astype(jnp.float32)
                  @ params["embed"].astype(jnp.float32).T)[:, 0]
        out = {"step": cache["step"] + 1, "self_kv": new_kv,
               "enc_out": cache["enc_out"]}
        if cached_cross is not None:
            out["cross_kv"] = cached_cross
        return logits, out
