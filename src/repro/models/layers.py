"""Shared neural layers for the assigned architectures (TPU-native JAX).

Everything is functional: ``init_*`` builds parameter dicts, ``apply``-style
functions are pure. dtype policy: parameters/activations in cfg.dtype
(bf16 for full configs), softmax/normalization statistics in fp32.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, n_in: int, n_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(n_in)
    return (jax.random.normal(key, (n_in, n_out), jnp.float32)
            * scale).astype(dtype)


# ------------------------------------------------------------- norms ------
def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------- RoPE -------
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    angles = angles[..., None, :]                       # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, theta: float):
    """M-RoPE (Qwen2-VL): head_dim split into (temporal, height, width)
    sections — hd/2, hd/4, hd/4 — each rotated by its own position track.

    x: (B, S, H, hd); positions_3d: (3, B, S).
    """
    hd = x.shape[-1]
    sec = (hd // 2, hd // 4, hd - hd // 2 - hd // 4)
    parts, off = [], 0
    for i, s in enumerate(sec):
        parts.append(apply_rope(x[..., off:off + s], positions_3d[i], theta))
        off += s
    return jnp.concatenate(parts, axis=-1)


def text_mrope_positions(positions):
    """Text tokens use the same index on all three M-RoPE tracks."""
    return jnp.stack([positions] * 3, axis=0)


# ------------------------------------------------------------- MLP --------
def mlp_init(key, d: int, ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], d, ff, dtype),
        "w_out": dense_init(ks[1], ff, d, dtype),
    }
    if act == "silu":  # SwiGLU: gate projection
        p["w_gate"] = dense_init(ks[2], d, ff, dtype)
    return p


def mlp(params, x, act: str):
    h = x @ params["w_in"]
    if act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["w_out"]


# --------------------------------------------------------- embeddings -----
def embedding_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * (1.0 / np.sqrt(d))).astype(dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / d)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, jnp.float32)
