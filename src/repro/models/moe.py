"""Mixture-of-Experts FFN — TPU-native expert parallelism.

Design (see DESIGN.md §3): activations are batch-sharded over the data
axes and replicated over the model axis; experts are sharded over the
model axis. Each model shard routes its (replicated) tokens to its LOCAL
experts with a sort-based capacity dispatch (differentiable gather/scatter
+ dense batched GEMMs), produces a partial output, and the partials are
combined with a psum over the model axis — the same collective a
Megatron-style dense FFN needs, i.e. no all-to-all. Per-shard compute is
~T·k/E_shards tokens worth of expert GEMMs (balanced in expectation).

The module is mesh-agnostic: ``moe_ffn_local`` runs on whatever slice of
experts it is handed and psums over ``axis`` if given. Without a mesh
(unit tests, smoke tests) it sees all experts and no collective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init


def moe_init(key, cfg):
    """Expert weights (E, d, h) ×3 (SwiGLU) + router (+ shared experts)."""
    e = cfg.moe
    d, h = cfg.d_model, e.d_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)

    def experts(k, n_in, n_out, sc):
        return (jax.random.normal(k, (e.n_experts, n_in, n_out), jnp.float32)
                * sc).astype(dt)

    p = {
        "router": dense_init(ks[0], d, e.n_experts, jnp.float32, scale=scale),
        "w_in": experts(ks[1], d, h, scale),
        "w_gate": experts(ks[2], d, h, scale),
        "w_out": experts(ks[3], h, d, 1.0 / np.sqrt(h)),
    }
    if e.n_shared_experts:
        hs = h * e.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_in": dense_init(k1, d, hs, dt),
            "w_gate": dense_init(k2, d, hs, dt),
            "w_out": dense_init(k3, hs, d, dt),
        }
    return p


def _capacity(n_tokens: int, cfg) -> int:
    e = cfg.moe
    c = int(np.ceil(e.capacity_factor * n_tokens * e.top_k / e.n_experts))
    return max(4, min(c, n_tokens))


def moe_ffn_local(params, x, cfg, *, axis: str | None = None,
                  shard_index=0, n_shards: int = 1,
                  gather_axis: str | None = None):
    """x: (B, S, d) local tokens (replicated over the expert-shard axis).

    params hold THIS shard's experts (E_local, d, h) — possibly further
    sharded over ``gather_axis`` on the hidden dim (ZeRO-style storage),
    gathered here before use.
    """
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    w_in, w_gate, w_out = params["w_in"], params["w_gate"], params["w_out"]
    if gather_axis is not None:
        # ZeRO-3 storage: hidden dim sharded over the data axis; gather
        # one layer's local experts just-in-time (transient, not resident).
        w_in = jax.lax.all_gather(w_in, gather_axis, axis=2, tiled=True)
        w_gate = jax.lax.all_gather(w_gate, gather_axis, axis=2, tiled=True)
        w_out = jax.lax.all_gather(w_out, gather_axis, axis=1, tiled=True)
    e_local = w_in.shape[0]

    # --- routing (computed identically on every shard; router is fp32) ---
    logits = xf.astype(jnp.float32) @ params["router"]        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, e.top_k)              # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- local assignment: flatten (T·k) slots, keep local experts -------
    lo = shard_index * e_local
    flat_e = top_i.reshape(-1)                                # (T·k,)
    flat_t = jnp.repeat(jnp.arange(t), e.top_k)
    flat_w = top_w.reshape(-1)
    local_e = flat_e - lo
    is_local = (local_e >= 0) & (local_e < e_local)
    sort_key = jnp.where(is_local, local_e, e_local)          # dummy bucket
    order = jnp.argsort(sort_key, stable=True)
    s_e = sort_key[order]
    s_t = flat_t[order]
    s_w = jnp.where(is_local[order], flat_w[order], 0.0)

    # position within each expert's run → capacity slot
    counts = jnp.bincount(sort_key, length=e_local + 1)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * e.top_k) - starts[s_e]
    cap = _capacity(t, cfg)
    valid = (s_e < e_local) & (pos < cap)
    slot = jnp.where(valid, s_e * cap + pos, e_local * cap)   # overflow slot

    # gather tokens into the (E_local·cap) dispatch buffer
    buf = jnp.zeros((e_local * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(valid[:, None], xf[s_t], 0.0))
    buf = buf[:-1].reshape(e_local, cap, d)

    # --- expert GEMMs (dense batched; FLOPs = E_local·cap·d·h·3·2) -------
    hidd = jnp.einsum("ecd,edh->ech", buf, w_in)
    gate = jnp.einsum("ecd,edh->ech", buf, w_gate)
    hidd = jax.nn.silu(gate) * hidd
    out_e = jnp.einsum("ech,ehd->ecd", hidd, w_out)           # (E_l,cap,d)

    # --- combine: weighted scatter back to tokens ------------------------
    out_flat = out_e.reshape(e_local * cap, d)
    contrib = jnp.where(valid[:, None],
                        out_flat[jnp.clip(slot, 0, e_local * cap - 1)]
                        * s_w[:, None].astype(x.dtype), 0.0)
    out = jnp.zeros((t, d), x.dtype).at[s_t].add(contrib)

    # --- shared (always-on) experts: plain SwiGLU over all tokens --------
    if "shared" in params:
        sh = params["shared"]
        hs = xf @ sh["w_in"]
        hs = jax.nn.silu(xf @ sh["w_gate"]) * hs
        out = out + hs @ sh["w_out"]

    if axis is not None:
        out = jax.lax.psum(out, axis)
    return out.reshape(b, s, d)


def router_aux_loss(params, x, cfg):
    """Load-balance auxiliary loss (Switch-style): E·Σ_e f_e·p_e."""
    e = cfg.moe
    t = x.shape[0] * x.shape[1]
    xf = x.reshape(t, -1).astype(jnp.float32)
    probs = jax.nn.softmax(xf @ params["router"], axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.bincount(top1, length=e.n_experts) / t
    imp = probs.mean(axis=0)
    return e.n_experts * jnp.sum(frac * imp)
