"""The paper's experimental models (App. D.1):

  * MLR — multinomial logistic regression (strongly convex setting),
  * MLP — two hidden dense layers, 100 hidden nodes, cross-entropy,
  * CNN — two 5×5 conv layers + FC-512 + softmax, dropout 25% / 50%.

Plain functional JAX: ``init(key, input_shape) -> params`` and
``apply(params, x, *, train, rng) -> logits``. Parameters are flat dicts of
arrays so RWSADMM's elementwise pytree updates apply directly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SmallModel:
    name: str
    init: Callable
    apply: Callable
    convex: bool = False


def _dense_init(key, n_in, n_out, scale=None):
    scale = scale if scale is not None else float(np.sqrt(2.0 / n_in))
    wk, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(wk, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


# ---------------------------------------------------------------- MLR -----
def make_mlr(input_shape: tuple[int, ...], n_classes: int = 10) -> SmallModel:
    n_in = int(np.prod(input_shape))

    def init(key):
        return {"linear": _dense_init(key, n_in, n_classes, scale=0.01)}

    def apply(params, x, *, train=False, rng=None):
        x = x.reshape(x.shape[0], -1)
        return x @ params["linear"]["w"] + params["linear"]["b"]

    return SmallModel("mlr", init, apply, convex=True)


# ---------------------------------------------------------------- MLP -----
def make_mlp(input_shape: tuple[int, ...], n_classes: int = 10,
             hidden: int = 100) -> SmallModel:
    n_in = int(np.prod(input_shape))

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "fc1": _dense_init(k1, n_in, hidden),
            "fc2": _dense_init(k2, hidden, hidden),
            "out": _dense_init(k3, hidden, n_classes),
        }

    def apply(params, x, *, train=False, rng=None):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]

    return SmallModel("mlp", init, apply)


# ---------------------------------------------------------------- CNN -----
def make_cnn(input_shape: tuple[int, int, int], n_classes: int = 10,
             c1: int = 16, c2: int = 32, fc: int = 512) -> SmallModel:
    h, w, cin = input_shape

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        conv_scale1 = float(np.sqrt(2.0 / (5 * 5 * cin)))
        conv_scale2 = float(np.sqrt(2.0 / (5 * 5 * c1)))
        flat = (h // 4) * (w // 4) * c2
        return {
            "conv1": {
                "w": jax.random.normal(k1, (5, 5, cin, c1)) * conv_scale1,
                "b": jnp.zeros((c1,)),
            },
            "conv2": {
                "w": jax.random.normal(k2, (5, 5, c1, c2)) * conv_scale2,
                "b": jnp.zeros((c2,)),
            },
            "fc": _dense_init(k3, flat, fc),
            "out": _dense_init(k4, fc, n_classes),
        }

    def conv(x, p):
        return jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p["b"]

    def pool(x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )

    def apply(params, x, *, train=False, rng=None):
        x = jax.nn.relu(conv(x, params["conv1"]))
        x = pool(x)
        if train and rng is not None:  # dropout 25% after conv block 1
            keep = jax.random.bernoulli(jax.random.fold_in(rng, 1), 0.75,
                                        x.shape)
            x = jnp.where(keep, x / 0.75, 0.0)
        x = jax.nn.relu(conv(x, params["conv2"]))
        x = pool(x)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc"]["w"] + params["fc"]["b"])
        if train and rng is not None:  # dropout 50% before the head
            keep = jax.random.bernoulli(jax.random.fold_in(rng, 2), 0.5,
                                        x.shape)
            x = jnp.where(keep, x / 0.5, 0.0)
        return x @ params["out"]["w"] + params["out"]["b"]

    return SmallModel("cnn", init, apply)


def get_model(name: str, input_shape, n_classes: int = 10) -> SmallModel:
    name = name.lower()
    if name == "mlr":
        return make_mlr(input_shape, n_classes)
    if name == "mlp":
        return make_mlp(input_shape, n_classes)
    if name == "cnn":
        return make_cnn(input_shape, n_classes)
    raise ValueError(f"unknown small model {name!r}")


MLR, MLP, CNN = "mlr", "mlp", "cnn"


# ------------------------------------------------------------- losses -----
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    if mask is None:
        return jnp.mean(hit)
    return jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)
