"""Arch-id → model builder registry + input batch builders."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .transformer import LM, ShardingCtx
from .whisper import EncDecLM


def build_model(cfg: ModelConfig, ctx: ShardingCtx | None = None,
                *, unroll: bool = False):
    if cfg.encoder_layers > 0:
        return EncDecLM(cfg, ctx, unroll=unroll)
    return LM(cfg, ctx, unroll=unroll)


def batch_spec(cfg: ModelConfig, batch: int, seq: int,
               kind: str = "train") -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    sd = jax.ShapeDtypeStruct
    if kind == "decode":
        out = {"tokens": sd((batch, 1), jnp.int32)}
        return out
    out = {"tokens": sd((batch, seq), jnp.int32)}
    if cfg.frontend == "vision_stub":
        out["patches"] = sd((batch, cfg.n_patches, cfg.d_model),
                            jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio_stub":
        out["frames"] = sd((batch, cfg.encoder_seq, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    return out


def random_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 kind: str = "train") -> dict:
    """Concrete random inputs of the same shapes (smoke tests/examples)."""
    rng = np.random.default_rng(seed)
    if kind == "decode":
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)}
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_patches, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio_stub":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out
