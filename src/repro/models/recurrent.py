"""Recurrent blocks: RG-LRU (Griffin / RecurrentGemma) and xLSTM
(mLSTM + sLSTM).

TPU adaptation notes (DESIGN.md §3):
  * RG-LRU uses a log-space linear recurrence h_t = a_t·h_{t−1} + b_t,
    parallelized with jax.lax.associative_scan (log-depth on TPU); the
    Pallas ``rglru_scan`` kernel implements the same contraction blocked
    over VMEM tiles.
  * mLSTM training uses its parallel (decay-masked linear-attention)
    form — an attention-like quadratic contraction, query-chunked like
    attention.py; decode uses the O(1) recurrent (C, n, m) state.
  * sLSTM is inherently sequential (recurrent gate nonlinearity) and uses
    lax.scan over time.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init

# =========================================================== RG-LRU =======
_C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness

# §Perf knob: dtype of the gate activations (the recurrence itself stays
# fp32 for stability). bf16 halves the TP activation psum bytes.
GATE_DTYPE = jnp.float32


def rglru_init(key, cfg):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, d, dt),       # recurrence branch in-proj
        "w_g": dense_init(ks[1], d, d, dt),       # gate branch in-proj
        "conv_w": (jax.random.normal(ks[2], (4, d), jnp.float32)
                   * 0.1).astype(dt),
        "w_rg": dense_init(ks[3], d, d, dt),      # recurrence gate r_t
        "w_ig": dense_init(ks[4], d, d, dt),      # input gate i_t
        # Λ init so a = exp(-c·softplus(λ)·r) starts near 0.95^c-ish.
        "lam": jnp.full((d,), 0.7, jnp.float32),
        "w_out": dense_init(ks[5], d, d, dt),
    }


def _causal_conv4(x, w):
    """x: (B,S,d), w: (4,d) depthwise causal conv."""
    pads = [jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]]
            for k in range(4)]
    return sum(p * w[k].astype(x.dtype) for k, p in enumerate(pads))


def _rglru_gates(params, u):
    r = jax.nn.sigmoid((u @ params["w_rg"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_ig"]).astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(params["lam"]) * r    # (B,S,d) fp32
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    b = mult * i * u.astype(jnp.float32)
    return a, b


def rglru_block(params, x, use_pallas: bool = False,
                return_state: bool = False):
    """Full Griffin recurrent block: (B,S,d) → (B,S,d)."""
    u_in = x @ params["w_x"]
    u = _causal_conv4(u_in, params["conv_w"])
    gate = jax.nn.gelu((x @ params["w_g"]).astype(GATE_DTYPE))
    a, b = _rglru_gates(params, u)
    if use_pallas:
        from ..kernels.rglru_scan import ops as rg_ops
        h = rg_ops.rglru_scan(a, b)
    else:
        h = linear_scan(a, b)
    out = (h.astype(GATE_DTYPE) * gate).astype(x.dtype)
    out = out @ params["w_out"]
    if return_state:
        s = x.shape[1]
        conv_hist = u_in[:, max(0, s - 3):]
        if s < 3:
            conv_hist = jnp.pad(conv_hist, ((0, 0), (3 - s, 0), (0, 0)))
        state = RGLRUState(h=h[:, -1], conv=conv_hist)
        return out, state
    return out


def linear_scan(a, b):
    """h_t = a_t h_{t−1} + b_t via associative_scan over time axis=1."""

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r

    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


class RGLRUState(NamedTuple):
    h: jnp.ndarray          # (B, d) fp32 recurrent state
    conv: jnp.ndarray       # (B, 3, d) last inputs for the causal conv


def rglru_init_state(cfg, batch: int) -> RGLRUState:
    d = cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, d), jnp.float32),
                      conv=jnp.zeros((batch, 3, d), jnp.dtype(cfg.dtype)))


def rglru_decode_step(params, x, state: RGLRUState):
    """x: (B,1,d) one token; O(1) state update."""
    u_in = (x @ params["w_x"])[:, 0]                      # (B,d)
    hist = jnp.concatenate([state.conv, u_in[:, None]], axis=1)  # (B,4,d)
    w = params["conv_w"].astype(u_in.dtype)
    u = jnp.einsum("bkd,kd->bd", hist, w[::-1])           # causal conv tap
    gate = jax.nn.gelu((x @ params["w_g"]).astype(jnp.float32))[:, 0]
    a, b = _rglru_gates(params, u[:, None])
    h = a[:, 0] * state.h + b[:, 0]
    out = (h * gate).astype(x.dtype)[:, None]
    return out @ params["w_out"], RGLRUState(h=h, conv=hist[:, 1:])


# ============================================================ mLSTM =======
def mlstm_init(key, cfg):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    di = 2 * d  # inner dim (pf=2 up-projection)
    return {
        "w_up": dense_init(ks[0], d, di, dt),
        "w_gate_up": dense_init(ks[1], d, di, dt),
        "wq": dense_init(ks[2], di, di, dt),
        "wk": dense_init(ks[3], di, di, dt),
        "wv": dense_init(ks[4], di, di, dt),
        "w_if": dense_init(ks[5], di, 2 * cfg.n_heads, jnp.float32),
        "w_down": dense_init(ks[6], di, d, dt),
    }


def _mlstm_gates(params, u):
    """Log input/forget gates per head: (B,S,H) fp32 each."""
    gf = (u @ params["w_if"]).astype(jnp.float32)
    h = gf.shape[-1] // 2
    log_i = gf[..., :h]                       # pre-activation ĩ (log space)
    log_f = jax.nn.log_sigmoid(gf[..., h:])   # log σ(f̃)
    return log_i, log_f


def mlstm_block(params, x, cfg, chunk: int = 256,
                return_state: bool = False):
    """mLSTM mixer. Dispatches between the quadratic parallel form (short
    sequences / oracle) and the chunkwise-recurrent form (production)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    u = x @ params["w_up"]
    gate = jax.nn.silu(x @ params["w_gate_up"])
    di = u.shape[-1]
    hd = di // nh
    q = (u @ params["wq"]).reshape(b, s, nh, hd).astype(jnp.float32)
    k = ((u @ params["wk"]).reshape(b, s, nh, hd) / np.sqrt(hd)).astype(
        jnp.float32)
    v = (u @ params["wv"]).reshape(b, s, nh, hd).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(params, u)    # (B,S,H)
    if s <= chunk and not return_state:
        h = _mlstm_quadratic(q, k, v, log_i, log_f)
    else:
        h, state = _mlstm_chunked(q, k, v, log_i, log_f, min(chunk, s),
                                  return_state=True)
    h = h.reshape(b, s, di).astype(x.dtype)
    out = (h * gate) @ params["w_down"]
    if return_state:
        return out, MLSTMState(c=state[0], n=state[1], m=state[2])
    return out


def _mlstm_quadratic(q, k, v, log_i, log_f):
    """Decay-masked linear-attention form (oracle; O(S²) memory).

    h_t = Σ_{s≤t} exp(log_i_s + Σ_{r=s+1..t} log_f_r − m_t)·(q_t·k_s)·v_s,
    normalized by max(|Σ w·(q·k)|, 1).
    """
    b, s, nh, hd = q.shape
    cum_f = jnp.cumsum(log_f, axis=1)
    a = log_i[:, None, :, :] + cum_f[:, :, None, :] - cum_f[:, None, :, :]
    t_idx = jnp.arange(s)
    causal = t_idx[None, :, None, None] >= t_idx[None, None, :, None]
    a = jnp.where(causal, a, -jnp.inf)
    m = jnp.max(a, axis=2, keepdims=True)                     # (B,S,1,H)
    dmat = jnp.exp(a - m)
    qk = jnp.einsum("bthd,bshd->btsh", q, k)
    w = dmat * qk
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), 1.0)
    h = jnp.einsum("btsh,bshd->bthd", w, v)
    return h / norm[..., None]


def _mlstm_chunked(q, k, v, log_i, log_f, chunk: int,
                   return_state: bool = False):
    """Chunkwise-recurrent mLSTM: O(S·chunk) memory, (C,n,m) state carried
    across chunks (the xLSTM paper's production formulation)."""
    b, s, nh, hd = q.shape
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    n_chunks = q.shape[1] // chunk

    def resh(x_, extra):  # (B, n_chunks, chunk, ...) → scan-major
        return jnp.moveaxis(
            x_.reshape((b, n_chunks, chunk) + extra), 1, 0)

    qs, ks, vs = (resh(t_, (nh, hd)) for t_ in (q, k, v))
    lis, lfs = (resh(t_, (nh,)) for t_ in (log_i, log_f))

    c0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)

    def body(carry, inp):
        c_p, n_p, m_p = carry
        qc, kc, vc, lic, lfc = inp              # (B, chunk, H, ...)
        cum_f = jnp.cumsum(lfc, axis=1)         # (B,chunk,H)
        # intra-chunk log weights a[b,t,s,h]
        a = (lic[:, None, :, :] + cum_f[:, :, None, :]
             - cum_f[:, None, :, :])
        t_idx = jnp.arange(chunk)
        causal = t_idx[None, :, None, None] >= t_idx[None, None, :, None]
        a = jnp.where(causal, a, -jnp.inf)
        inter_log = cum_f + m_p[:, None, :]     # (B,chunk,H)
        m_t = jnp.maximum(jnp.max(a, axis=2), inter_log)   # (B,chunk,H)
        w = jnp.exp(a - m_t[:, :, None, :])
        g = jnp.exp(inter_log - m_t)            # (B,chunk,H)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)
        num_intra = jnp.einsum("btsh,bshd->bthd", w * qk, vc)
        num_inter = jnp.einsum("bthd,bhde->bthe", qc, c_p) \
            * g[..., None]
        den_intra = jnp.sum(w * qk, axis=2)                 # (B,chunk,H)
        den_inter = jnp.einsum("bthd,bhd->bth", qc, n_p) * g
        den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
        h = (num_intra + num_inter) / den[..., None]
        # end-of-chunk state
        cf_end = cum_f[:, -1, :]                            # (B,H)
        m_end = jnp.maximum(
            m_p + cf_end,
            jnp.max(lic + cf_end[:, None, :] - cum_f, axis=1))
        carry_sc = jnp.exp(m_p + cf_end - m_end)            # (B,H)
        tok_sc = jnp.exp(lic + cf_end[:, None, :] - cum_f
                         - m_end[:, None, :])                # (B,chunk,H)
        c_new = carry_sc[..., None, None] * c_p + jnp.einsum(
            "bshd,bshe,bsh->bhde", kc, vc, tok_sc)
        n_new = carry_sc[..., None] * n_p + jnp.einsum(
            "bshd,bsh->bhd", kc, tok_sc)
        return (c_new, n_new, m_end), h

    final, hs = jax.lax.scan(body, (c0, n0, m0), (qs, ks, vs, lis, lfs))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, n_chunks * chunk, nh, hd)
    h = h[:, :s]
    if return_state:
        return h, final
    return h


class MLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, H, hd, hd) matrix memory, fp32
    n: jnp.ndarray   # (B, H, hd) normalizer
    m: jnp.ndarray   # (B, H) log-space stabilizer


def mlstm_init_state(cfg, batch: int) -> MLSTMState:
    di = 2 * cfg.d_model
    hd = di // cfg.n_heads
    return MLSTMState(
        c=jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        n=jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
        m=jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
    )


def mlstm_decode_step(params, x, state: MLSTMState, cfg):
    b = x.shape[0]
    nh = cfg.n_heads
    u = (x @ params["w_up"])[:, 0]
    gate = jax.nn.silu(x @ params["w_gate_up"])[:, 0]
    di = u.shape[-1]
    hd = di // nh
    q = (u @ params["wq"]).reshape(b, nh, hd).astype(jnp.float32)
    k = ((u @ params["wk"]).reshape(b, nh, hd) / np.sqrt(hd)).astype(
        jnp.float32)
    v = (u @ params["wv"]).reshape(b, nh, hd).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(params, u[:, None])
    log_i, log_f = log_i[:, 0], log_f[:, 0]                   # (B,H)
    m_new = jnp.maximum(log_f + state.m, log_i)
    f_sc = jnp.exp(log_f + state.m - m_new)[..., None]
    i_sc = jnp.exp(log_i - m_new)[..., None]
    c = f_sc[..., None] * state.c + i_sc[..., None] * (
        k[..., :, None] * v[..., None, :])          # C = k ⊗ v (matches
    n = f_sc * state.n + i_sc * k                   # the chunked form)
    num = jnp.einsum("bhde,bhd->bhe", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = (num / den[..., None]).reshape(b, di)
    out = ((h.astype(x.dtype) * gate) @ params["w_down"])[:, None]
    return out, MLSTMState(c=c, n=n, m=m_new)


# ============================================================ sLSTM =======
def slstm_init(key, cfg):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dt),   # i,f,z,o from x_t
        "r_gates": dense_init(ks[1], d, 4 * d, dt,
                              scale=0.5 / np.sqrt(d)),  # from h_{t−1}
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "w_out": dense_init(ks[2], d, d, dt),
    }


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, d)
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray


def slstm_init_state(cfg, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30))


def _slstm_cell(params, x_t, st: SLSTMState):
    d = x_t.shape[-1]
    pre = (x_t @ params["w_gates"]).astype(jnp.float32) \
        + (st.h.astype(x_t.dtype) @ params["r_gates"]).astype(jnp.float32) \
        + params["b_gates"]
    it, ft, zt, ot = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + st.m, it)
    i_sc = jnp.exp(it - m_new)
    f_sc = jnp.exp(log_f + st.m - m_new)
    c = f_sc * st.c + i_sc * jnp.tanh(zt)
    n = f_sc * st.n + i_sc
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_block(params, x, cfg, return_state: bool = False):
    """Sequential scan over time (sLSTM has no parallel form)."""
    b, s, d = x.shape
    st0 = slstm_init_state(cfg, b)

    def body(st, x_t):
        st = _slstm_cell(params, x_t, st)
        return st, st.h

    st_f, hs = jax.lax.scan(body, st0, jnp.moveaxis(x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    out = h @ params["w_out"]
    if return_state:
        return out, st_f
    return out


def slstm_decode_step(params, x, state: SLSTMState, cfg):
    st = _slstm_cell(params, x[:, 0], state)
    out = st.h.astype(x.dtype)[:, None] @ params["w_out"]
    return out, st
