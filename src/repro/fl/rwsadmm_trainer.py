"""RWSADMM federated trainer (paper Algorithm 1 + Eq. 31 multi-client zone).

Host side per round k:
  1. advance the dynamic graph (regenerated every ``regen_every`` rounds),
  2. the mobile server random-walks to client i_k  (Markov chain, Eq. 2),
  3. the active zone S(i_k) ⊆ N(i_k) is formed (up to ``zone_size``),
  4. one compiled SPMD zone round runs: stochastic grads at the active
     clients' x'_j, closed-form x/z updates, incremental y update,
  5. κ ← 0.99 κ.

The compiled round has *fixed shapes*: zones are padded to ``zone_size``
with a mask; padded slots contribute zero deltas via scatter-add, so a
whole training run reuses a single XLA executable.

Two drivers share that round body:

* **eager** — :meth:`round`: one XLA dispatch + one host sync per round
  (the classic loop; dispatch overhead dominates for small models).
* **scan** — :meth:`schedule` precomputes the whole random-walk / zone /
  key schedule as fixed-shape arrays (``core.markov.zone_schedule``),
  then :meth:`run_chunk` runs R rounds as ONE ``lax.scan`` executable
  with no per-round host round-trips; metrics come back stacked.
  ``engine="scan_fused"`` additionally routes the closed-form triple
  update through the masked multi-client Pallas kernel
  (``kernels.rwsadmm_update``) so the Eq. 31 zone round is one HBM pass.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import markov, rwsadmm
from ..core.markov import ZoneSchedule
from ..core.rwsadmm import ClientState, RWSADMMHparams, ServerState
from ..kernels.rwsadmm_update import ops as fused_ops
from ..scenarios import ScenarioConfig
from .base import DeviceData, TrainerBase, sample_batch

SCAN_ENGINES = ("scan", "scan_fused")      # compiled lax.scan drivers
ENGINES = ("eager",) + SCAN_ENGINES        # everything run_simulation takes


class RWSADMMState(NamedTuple):
    clients: ClientState      # stacked (n, ...)
    server: ServerState
    visited: jnp.ndarray      # (n,) bool — who holds a personalized model


class RWSADMMTrainer(TrainerBase):
    name = "rwsadmm"
    personalized = True

    def __init__(
        self,
        model,
        data: DeviceData,
        hp: RWSADMMHparams = RWSADMMHparams(),
        *,
        batch_size: int = 20,
        zone_size: int = 8,
        min_degree: int = 5,
        regen_every: int = 10,
        transition: str = "degree",
        warm_init: bool = True,
        solver: str = "prox_sgd",   # "prox_sgd" (Eq. 9, K steps) |
                                    # "closed_form" (Eq. 10/11, one step)
        inner_steps: int = 10,
        inner_lr: float = 0.05,
        dp_clip: float | None = None,     # l2 clip on uploaded Δc (DP)
        dp_noise: float = 1.0,            # Gaussian noise multiplier σ
        scenario: ScenarioConfig | str | None = None,
        batched_walk: bool = False,       # inverse-cdf walk sampling in
                                          # schedule() (RNG-stream break
                                          # vs eager; see markov)
        walk_policy: str | None = None,   # markov.WALK_POLICIES; None →
                                          # the unbiased ``transition``
        walk_bias: float = 1.0,           # staleness exponent / label-
                                          # skew sharpening γ
        store_capacity: int = 4096,       # lazy plane: resident slots in
                                          # the bounded LRU client store
        prefetch: bool = False,           # lazy plane: stage the next
                                          # chunk's dataset rows on a
                                          # host thread (bit-identical)
        mesh=None,                        # Mesh/FLSharding: shard the
                                          # client plane's leading axis
                                          # over the mesh "data" axis
        telemetry=None,                   # TelemetryRun or None (off)
        seed: int = 0,
    ):
        # Lazy client plane: when ``data`` is a ClientDataFactory, the
        # base builds the bounded (store_capacity, …) LRU ClientStore —
        # client x/z pytrees and datasets materialize on first visit
        # instead of as (n, …) stacks (docs/performance.md §7), pinned
        # bit-identical to the dense plane (tests/test_lazy_plane).
        super().__init__(model, data, batch_size, telemetry=telemetry,
                         store_capacity=store_capacity,
                         prefetch=prefetch, mesh=mesh)
        self.hp = hp
        self.solver = solver
        self.dp_clip = dp_clip
        self.dp_noise = dp_noise
        self.batched_walk = bool(batched_walk)
        self.inner_steps = int(inner_steps)
        self.inner_lr = float(inner_lr)
        self.zone_size = int(min(zone_size, self.n_clients))
        self.warm_init = warm_init
        self._seed = int(seed)
        self._min_degree = int(min_degree)
        self._regen_every = int(regen_every)
        self._transition = transition
        self.walk_policy = walk_policy
        self.walk_bias = float(walk_bias)
        # Static flag: biased policies thread the per-round importance
        # weight into the Eq. 31 y-update (Walk-for-Learning correction);
        # uniform policies keep the seed computation graph untouched.
        self._use_iw = walk_policy in markov.BIASED_POLICIES
        # The environment: mobility + links + churn behind the old
        # DynamicGraph contract. scenario=None builds "static_regen"
        # from the legacy min_degree/regen_every knobs — bit-for-bit
        # the seed behavior. A named or explicit ScenarioConfig is
        # authoritative: its own mobility knobs override those kwargs.
        self.attach_scenario(scenario, seed=seed)
        # update_wrapper names the partial so jax's compile logs (and
        # the analysis compile-budget sentinel) see jit(_round_impl)
        # instead of jit(<unnamed wrapped function>).
        _round = functools.partial(self._round_impl)
        functools.update_wrapper(_round, self._round_impl)
        self._round_fn = jax.jit(_round)
        self._chunk_fns: dict = {}   # engine -> jitted lax.scan driver
        self._chunk_shapes: set = set()   # (engine, R) already compiled

    def attach_scenario(self, spec, seed: int | None = None) -> None:
        """(Re)build the environment and reset the walker onto it.

        ``seed`` (when given) becomes the trainer's RNG seed so every
        derived stream — scenario layers, walker, fleet walkers —
        reseeds consistently.
        """
        seed = self._seed if seed is None else seed
        self._seed = seed
        self._attach_walking_scenario(
            spec, seed, min_degree=self._min_degree,
            regen_every=self._regen_every, transition=self._transition,
            walk_policy=self.walk_policy, walk_bias=self.walk_bias,
            label_weights=self._label_skew_weights(),
        )
        # Per-client service clock for the staleness round metrics
        # (round index of each client's last zone participation).
        self._last_served = np.full(self.n_clients, -1, dtype=np.int64)

    def _label_skew_weights(self) -> np.ndarray | None:
        """Per-client data utilities for the ``label_skew`` walk policy,
        from the padded device label arrays (None for other policies)."""
        if self.walk_policy != "label_skew":
            return None
        if self.data is None:
            raise ValueError(
                "walk_policy='label_skew' needs the per-client label "
                "histograms of the dense client plane; the lazy plane "
                "never materializes them")
        from ..data import partition

        hist = partition.padded_label_histograms(
            np.asarray(self.data.y_train), np.asarray(self.data.n_train))
        return partition.label_skew_weights(hist, gamma=self.walk_bias)

    def _staleness_metrics(self, idx, mask, rnd: int) -> dict:
        """Update the per-client service clock with one round's zone and
        report the staleness distribution (rounds since last service;
        never-served clients count rnd + 1). Integer math shared by the
        eager driver and ``chunk_round_metrics``, so both engines emit
        identical values (pinned in the scan-driver tests)."""
        served = np.asarray(idx)[np.asarray(mask) > 0]
        self._last_served[served] = rnd
        stale = rnd - self._last_served
        return {"staleness_p50": float(np.median(stale)),
                "staleness_max": int(stale.max())}

    def _price(self, graph, i_k, idx, mask):
        return self.scenario.price_round(graph, int(i_k), idx, mask,
                                         self.params_bytes())

    def _price_schedule(self, graphs, clients, idx, mask):
        return self.scenario.price_schedule(graphs, clients, idx, mask,
                                            self.params_bytes())

    # ------------------------------------------------------------------
    def init_state(self, key) -> RWSADMMState:
        params = self.model.init(key)
        if self.store is not None:
            return self._init_state_lazy(params)
        if self.warm_init:
            clients, server = rwsadmm.init_states_warm(
                params, self.hp, self.n_clients
            )
        else:
            clients, server = rwsadmm.init_states(
                params, self.hp, self.n_clients
            )
        visited = jnp.zeros((self.n_clients,), bool)
        if self.fl_sharding is not None:
            # Data-parallel client plane: the (n, …) stacks split over
            # the mesh "data" axis, the walking token replicates. The
            # jitted round/chunk bodies propagate these placements.
            clients = self.fl_sharding.shard_rows(clients)
            server = self.fl_sharding.replicate(server)
            visited = self.fl_sharding.shard_rows(visited)
        return RWSADMMState(clients=clients, server=server,
                            visited=visited)

    def _init_state_lazy(self, params) -> RWSADMMState:
        """Packed-store twin of the dense init: every client's dense
        init row is IDENTICAL (warm: x=params, z=0; cold: x=z=0), so
        the store pre-fills all capacity slots from that one template —
        lazy materialization is bit-for-bit dense init by construction.
        ``clients`` leaves are (capacity, …); ``visited`` stays a dense
        (n,) bool (1 bit of truth per client costs ~n bytes, not the
        O(n·p) the packed plane removes)."""
        from ..core import tree as t

        zeros = t.zeros_like(params)
        template = (ClientState(x=params, z=zeros) if self.warm_init
                    else ClientState(x=zeros, z=zeros))
        # The store shards the packed rows itself when built with a
        # sharding (capacity axis over "data").
        clients = self.store.reset(template)
        server = ServerState(
            y=params if self.warm_init else zeros,
            kappa=jnp.asarray(self.hp.kappa, jnp.float32),
            round=jnp.asarray(0, jnp.int32),
        )
        visited = jnp.zeros((self.n_clients,), bool)
        if self.fl_sharding is not None:
            server = self.fl_sharding.replicate(server)
            visited = self.fl_sharding.shard_rows(visited)
        return RWSADMMState(clients=clients, server=server,
                            visited=visited)

    # ------------------------------------------------------------------
    def _round_impl(self, state: RWSADMMState, zone_idx, zone_mask, n_i,
                    key, iw=None, gid=None, data=None, *,
                    use_fused: bool = False):
        # Dense plane: zone_idx are global client ids, gid/data are None
        # (empty pytrees under jit — the seed computation graph is
        # untouched) and the stacked dataset is a compile-time closure
        # constant. Lazy plane: zone_idx are STORE SLOTS, ``gid`` carries
        # the global ids (visited-set bookkeeping), and the packed store
        # data MUST arrive as a traced argument — a closure over
        # ``self.store.data`` would bake whatever rows were resident at
        # trace time into the executable.
        data = self.data if data is None else data
        clients, server = state.clients, state.server
        hp, kappa = self.hp, server.kappa

        # Gather active clients' ADMM variables: (Z, ...)
        gather = lambda t: jax.tree_util.tree_map(lambda l: l[zone_idx], t)
        act = ClientState(x=gather(clients.x), z=gather(clients.z))

        keys = jax.random.split(key, self.zone_size)
        y_new = None   # set early by the fused kernel, late by the jnp fold

        if self.solver == "closed_form":
            # One-step stochastic linearization (Eq. 10/11).
            def one_grad(params, client, k):
                xb, yb = sample_batch(data, client, k, self.batch_size)
                return self.value_and_grad_fn(params, xb, yb, k)

            losses, grads = jax.vmap(one_grad)(act.x, zone_idx, keys)
            if use_fused:
                # Whole zone round (Eq. 31) in one HBM pass: x/z updates
                # for every active client + the masked y fold.
                x_f, z_f, y_new = fused_ops.rwsadmm_zone_fused_update(
                    act.x, act.z, server.y, grads, zone_mask, kappa,
                    beta=hp.beta, eps_half=hp.eps_half,
                    n_total=float(self.n_clients),
                )
                new_act = ClientState(x=x_f, z=z_f)
            else:
                upd = jax.vmap(
                    lambda c, g: rwsadmm.client_round(c, server.y, g, hp,
                                                      kappa)
                )
                new_act, c_new, c_old = upd(act, grads)
        else:
            # Iterative solver of the x-subproblem (Eq. 9): K stochastic
            # subgradient steps, warm-started at the client's stored x'.
            eta = self.inner_lr

            def solve_one(c: ClientState, client, k):
                def body(x, kk):
                    xb, yb = sample_batch(data, client, kk,
                                          self.batch_size)
                    loss, gf = self.value_and_grad_fn(x, xb, yb, kk)
                    g = rwsadmm.subproblem_grad(x, server.y, c.z, gf, hp)
                    x = jax.tree_util.tree_map(
                        lambda a, b: a - eta * b, x, g
                    )
                    return x, loss

                kks = jax.random.split(k, self.inner_steps)
                x_new, losses_ = jax.lax.scan(body, c.x, kks)
                z_new = rwsadmm.z_update(x_new, server.y, c.z, hp, kappa)
                c_old_ = rwsadmm.contribution(c.x, c.z, server.y, hp)
                c_new_ = rwsadmm.contribution(x_new, z_new, server.y, hp)
                return (ClientState(x=x_new, z=z_new), c_new_, c_old_,
                        losses_[-1])

            new_act, c_new, c_old, losses = jax.vmap(solve_one)(
                act, zone_idx, keys
            )

        # Masked incremental y-update:  y += (1/n) Σ_active (c_new − c_old)
        # (1/n, not the printed 1/n_i — see core.rwsadmm.y_update docstring.)
        m = zone_mask  # (Z,)
        n_total = float(self.n_clients)

        if y_new is None:
            if self.dp_clip is not None:
                # DP uploads: clip + noise each active client's Δc before
                # it reaches the walking token (core/privacy.py).
                from ..core import privacy

                dkeys = jax.random.split(jax.random.fold_in(key, 97),
                                         self.zone_size)
                deltas = jax.vmap(
                    lambda k_, cn, co: privacy.privatize_delta(
                        k_, cn, co, clip=self.dp_clip,
                        noise_multiplier=self.dp_noise)
                )(dkeys, c_new, c_old)
            else:
                deltas = jax.tree_util.tree_map(
                    lambda cn, co: cn - co, c_new, c_old)

            def fold(y, d):
                mm = m.reshape((-1,) + (1,) * (d.ndim - 1))
                delta = jnp.sum(mm * d, axis=0) / n_total
                # Importance-weight correction (biased walk policies):
                # the zone fold is scaled by 1/(n π_{i_k}) so the
                # y-update estimator stays unbiased under the biased
                # visit distribution (docs/walks.md). iw=None (uniform
                # policies) keeps the seed computation graph unchanged.
                return y + (delta if iw is None else iw * delta)

            y_new = jax.tree_util.tree_map(fold, server.y, deltas)
        elif iw is not None:
            # Fused-kernel path: the Pallas kernel already folded the
            # unweighted zone delta into y; rescale it post hoc.
            y_new = jax.tree_util.tree_map(
                lambda y0, y1: y0 + iw * (y1 - y0), server.y, y_new)

        # Scatter active deltas back (duplicate-free: zone indices unique,
        # padded slots masked to zero so .add is a no-op for them).
        def scatter(full, old_act, new_act_):
            mm = m.reshape((-1,) + (1,) * (new_act_.ndim - 1))
            return full.at[zone_idx].add(mm * (new_act_ - old_act))

        clients = ClientState(
            x=jax.tree_util.tree_map(scatter, clients.x, act.x, new_act.x),
            z=jax.tree_util.tree_map(scatter, clients.z, act.z, new_act.z),
        )
        server = ServerState(
            y=y_new,
            kappa=server.kappa * hp.kappa_decay,
            round=server.round + 1,
        )
        visited = state.visited.at[
            zone_idx if gid is None else gid].max(m > 0)
        zone_loss = jnp.sum(losses * m) / jnp.maximum(jnp.sum(m), 1.0)
        return RWSADMMState(clients, server, visited), zone_loss

    # ------------------------------------------------------------------
    def round(self, state: RWSADMMState, rnd: int, rng: np.random.Generator):
        """Eager driver: one dispatch + one host sync per round."""
        graph = self.dyn_graph.step() if rnd > 0 else self.dyn_graph.current()
        i_k = self.walker.step(graph) if rnd > 0 else self.walker.position
        idx, mask, n_i = markov.plan_zone_round(
            graph, int(i_k), self.zone_size, rng,
            avail=self.scenario.availability(),
        )
        n_active = int(mask.sum())
        latency_s, energy_j = self._price(graph, i_k, idx, mask)

        key = markov.round_key(rng)
        kwargs = {}
        if self.store is not None:
            state, zone_idx = self._ensure_round(state, idx)
            kwargs = {"gid": jnp.asarray(idx), "data": self.store.data}
        else:
            zone_idx = idx
        args = [state, jnp.asarray(zone_idx), jnp.asarray(mask),
                jnp.asarray(float(n_i)), key]
        if self._use_iw:
            # The weight recorded at the walker's latest visit — the
            # same float the schedule's iw column carries for this round.
            args.append(jnp.asarray(self.walker.weight_history[-1],
                                    jnp.float32))
        self._audit_record("round", self._round_fn, args, kwargs)
        state, zone_loss = self._round_fn(*args, **kwargs)
        metrics = {
            "round": rnd,
            "client": int(i_k),
            "zone": n_active,
            "n_i": int(n_i),
            "train_loss": float(zone_loss),
            "kappa": float(state.server.kappa),
            "comm_bytes": self.comm_bytes_per_round(n_active),
            "latency_s": latency_s,
            "energy_j": energy_j,
            **self._staleness_metrics(idx, mask, rnd),
        }
        return state, metrics

    # ------------------------------------------------------------------
    # Lazy client plane plumbing (client_plane="lazy").
    # ------------------------------------------------------------------
    def _state_clients(self, state):
        """Where the packed client pytree lives in this trainer's state
        (the fleet wraps it one level deeper)."""
        return state.clients

    def _state_visited(self, state):
        return state.visited

    def _with_clients(self, state, clients):
        return state._replace(clients=clients)

    def prefetch_chunk(self, sched) -> int:
        """Hand the NEXT chunk's working set to the store's async
        staging pipeline (no-op unless ``prefetch=True``): dataset rows
        for its predicted misses materialize on a host thread while the
        current chunk executes (``run_simulation`` drives this —
        docs/performance.md §8)."""
        if self.store is None or not self.store.prefetch_enabled:
            return 0
        return self.store.prefetch(np.asarray(sched.idx).reshape(-1))

    # ------------------------------------------------------------------
    # Compiled multi-round (lax.scan) driver.
    # ------------------------------------------------------------------
    def schedule(self, rounds: int, rng: np.random.Generator,
                 *, start_round: int = 0) -> ZoneSchedule:
        """Precompute the next ``rounds`` zone rounds as fixed-shape
        arrays, consuming the graph/walker/sim RNGs exactly as the eager
        driver would (so chunked scans replay eager runs draw-for-draw).
        """
        return markov.zone_schedule(
            self.dyn_graph, self.walker, rounds, self.zone_size, rng,
            start_round=start_round, price=self._price_schedule,
            batched_walk=self.batched_walk,
        )

    def chunk_is_cold(self, engine: str, rounds: int | None = None
                      ) -> bool:
        """True when the next ``run_chunk(engine=…)`` call at this chunk
        length will trace + compile a fresh executable (jit caches by
        engine and by the scan length) — the telemetry phase timers tag
        such spans ``includes_compile`` so the report CLI can separate
        compile cost from steady-state chunk throughput."""
        return (engine, rounds) not in self._chunk_shapes

    def _engine_use_fused(self, engine: str) -> bool:
        """Validate a scan engine name; True when it takes the fused
        (Pallas zone kernel) hot path. Shared with the fleet driver."""
        if engine not in SCAN_ENGINES:
            raise ValueError(
                f"engine must be one of {'|'.join(SCAN_ENGINES)}, "
                f"got {engine}")
        use_fused = engine == "scan_fused"
        if use_fused and self.solver != "closed_form":
            raise ValueError(
                "scan_fused fuses the closed-form triple update; use "
                "solver='closed_form' (prox_sgd has no closed-form x step)")
        if use_fused and self.dp_clip is not None:
            raise ValueError("scan_fused does not support DP uploads; "
                             "use engine='scan'")
        return use_fused

    def chunk_round_metrics(self, sched: ZoneSchedule, stacked: dict,
                            start_round: int) -> list[dict]:
        """Rebuild per-round metric dicts from a finished chunk — the
        host-side mirror of what :meth:`round` emits, so both engines
        share one ``round_metrics`` schema (asserted in tests)."""
        losses = np.asarray(stacked["train_loss"])
        kappas = np.asarray(stacked["kappa"])
        out = []
        for j in range(sched.rounds):
            n_active = int(sched.active[j])
            entry = {
                "round": start_round + j,
                "client": int(sched.clients[j]),
                "zone": n_active,
                "n_i": int(sched.n_i[j]),
                "train_loss": float(losses[j]),
                "kappa": float(kappas[j]),
                "comm_bytes": self.comm_bytes_per_round(n_active),
            }
            if sched.latency_s is not None:
                entry["latency_s"] = float(sched.latency_s[j])
                entry["energy_j"] = float(sched.energy_j[j])
            entry.update(self._staleness_metrics(
                sched.idx[j], sched.mask[j], start_round + j))
            out.append(entry)
        return out

    def run_chunk(self, state: RWSADMMState, sched: ZoneSchedule,
                  engine: str = "scan"):
        """Run a whole schedule chunk as ONE compiled ``lax.scan``.

        No host sync inside the chunk; per-round metrics come back as
        stacked device arrays. Returns (state, {"train_loss": (R,),
        "kappa": (R,)}).
        """
        use_fused = self._engine_use_fused(engine)
        lazy = self.store is not None
        if lazy:
            # The chunk's whole visited set (padding ids included) is
            # gathered from the precomputed schedule BEFORE the scan, so
            # the compiled body only carries the (capacity, …) packed
            # pytree + packed data; ids enter the scan pre-translated
            # to slots, with the global ids riding along for the
            # visited-set update.
            with self._phase("ensure", rounds=int(sched.rounds)):
                state, slot_idx = self._ensure_round(state, sched.idx)

        fn = self._chunk_fns.get(engine)
        if fn is None:
            round_fn = functools.partial(self._round_impl,
                                         use_fused=use_fused)

            if lazy:
                use_iw = self._use_iw

                def chunk(state, data, idx, gidx, mask, n_i, keys,
                          iws=None):
                    def body(carry, per):
                        i_r, g_r, m_r, ni_r, k_r = per[:5]
                        w_r = per[5] if use_iw else None
                        new_state, loss = round_fn(carry, i_r, m_r, ni_r,
                                                   k_r, w_r, gid=g_r,
                                                   data=data)
                        return new_state, (loss, new_state.server.kappa)

                    cols = (idx, gidx, mask, n_i, keys)
                    if use_iw:
                        cols = cols + (iws,)
                    return jax.lax.scan(body, state, cols)
            elif self._use_iw:
                # Biased walk policy: the schedule's per-round importance
                # weights ride along as one more scan input.
                def chunk(state, idx, mask, n_i, keys, iws):
                    def body(carry, per_round):
                        i_r, m_r, ni_r, k_r, w_r = per_round
                        new_state, loss = round_fn(carry, i_r, m_r, ni_r,
                                                   k_r, w_r)
                        return new_state, (loss, new_state.server.kappa)

                    return jax.lax.scan(
                        body, state, (idx, mask, n_i, keys, iws))
            else:
                def chunk(state, idx, mask, n_i, keys):
                    def body(carry, per_round):
                        i_r, m_r, ni_r, k_r = per_round
                        new_state, loss = round_fn(carry, i_r, m_r, ni_r,
                                                   k_r)
                        return new_state, (loss, new_state.server.kappa)

                    return jax.lax.scan(
                        body, state, (idx, mask, n_i, keys))

            if self.fl_sharding is not None:
                # Sharded plane: donate the chunk carry so XLA reuses
                # the per-device client-row buffers in place instead of
                # doubling resident state for every chunk. Opt-in only —
                # the default path keeps the input state alive (tests
                # reuse states across engines).
                fn = jax.jit(chunk, donate_argnums=(0,))
            else:
                fn = jax.jit(chunk)
            self._chunk_fns[engine] = fn

        args = []
        if lazy:
            args += [self.store.data, jnp.asarray(slot_idx),
                     jnp.asarray(sched.idx)]
        else:
            args.append(jnp.asarray(sched.idx))
        args += [jnp.asarray(sched.mask), jnp.asarray(sched.n_i),
                 jnp.asarray(sched.keys)]
        if self._use_iw:
            args.append(jnp.asarray(sched.iw, jnp.float32))
        self._audit_record(f"chunk:{engine}", fn, [state] + args)
        final, (losses, kappas) = fn(state, *args)
        self._chunk_shapes.add((engine, sched.rounds))
        return final, {"train_loss": losses, "kappa": kappas}

    # ------------------------------------------------------------------
    def _lazy_personalized_rows(self, state):
        """Per-slot personalization for the resident-set eval, mirroring
        :meth:`personalized_params`: slots whose client the walk has
        visited evaluate their x row, the rest the token y (what the
        mobile server would hand them)."""
        store = self.store
        occ = store.gid_of >= 0                          # (capacity,)
        occ_ids = np.where(occ, np.maximum(store.gid_of, 0), 0)
        visited_slot = jnp.asarray(
            np.asarray(self._state_visited(state))[occ_ids] & occ)
        clients = self._state_clients(state)
        y = self._eval_token(state)

        def pers_leaf(x, y_):
            v = visited_slot.reshape((-1,) + (1,) * y_.ndim)
            return jnp.where(v, x, y_[None])

        return jax.tree_util.tree_map(pers_leaf, clients.x, y)

    def _eval_token(self, state):
        """The token unvisited clients evaluate against (the fleet
        substitutes its rendezvous mean)."""
        return state.server.y

    def personalized_params(self, state: RWSADMMState):
        """x_i for visited clients; unvisited clients fall back to the
        server token y (what the mobile server would hand them)."""
        if self.store is not None:
            raise NotImplementedError(
                "personalized_params would materialize an (n, …) stack; "
                "under client_plane='lazy' use evaluate() (resident-set "
                "metrics) or read rows off trainer.store")
        def leaf(x, y):
            v = state.visited.reshape((-1,) + (1,) * (y.ndim))
            return jnp.where(v, x, y[None])

        return jax.tree_util.tree_map(leaf, state.clients.x, state.server.y)

    def global_params(self, state: RWSADMMState):
        return state.server.y

    def comm_bytes_per_round(self, participants: int) -> int:
        # Server broadcasts y once into the zone; each active client
        # uploads its contribution delta. O(1) in n — the paper's claim.
        return int((1 + participants) * self.params_bytes())

    # -- diagnostics -----------------------------------------------------
    def lyapunov(self, state: RWSADMMState, key) -> dict:
        """L_β and constraint residuals (Eq. 8 / Eq. 7) for monitoring."""
        if self.store is not None:
            raise NotImplementedError(
                "lyapunov iterates all n clients' data — a dense-plane "
                "diagnostic; run it on a dense twin at small n")
        losses = []
        for c in range(self.n_clients):
            xi = jax.tree_util.tree_map(lambda l: l[c], state.clients.x)
            losses.append(self._train_loss_client(xi, c, key))
        losses = jnp.stack(losses)
        l_beta = rwsadmm.augmented_lagrangian(
            state.server.y, state.clients, losses, self.hp
        )
        viol = rwsadmm.constraint_violation(
            state.server.y, state.clients.x, self.hp
        )
        return {"L_beta": float(l_beta), "violation": float(viol)}
