"""RWSADMM federated trainer (paper Algorithm 1 + Eq. 31 multi-client zone).

Host side per round k:
  1. advance the dynamic graph (regenerated every ``regen_every`` rounds),
  2. the mobile server random-walks to client i_k  (Markov chain, Eq. 2),
  3. the active zone S(i_k) ⊆ N(i_k) is formed (up to ``zone_size``),
  4. one compiled SPMD zone round runs: stochastic grads at the active
     clients' x'_j, closed-form x/z updates, incremental y update,
  5. κ ← 0.99 κ.

The compiled round has *fixed shapes*: zones are padded to ``zone_size``
with a mask; padded slots contribute zero deltas via scatter-add, so a
whole training run reuses a single XLA executable.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import rwsadmm
from ..core.graph import DynamicGraph
from ..core.markov import RandomWalkServer
from ..core.rwsadmm import ClientState, RWSADMMHparams, ServerState
from .base import DeviceData, TrainerBase, sample_batch


class RWSADMMState(NamedTuple):
    clients: ClientState      # stacked (n, ...)
    server: ServerState
    visited: jnp.ndarray      # (n,) bool — who holds a personalized model


class RWSADMMTrainer(TrainerBase):
    name = "rwsadmm"
    personalized = True

    def __init__(
        self,
        model,
        data: DeviceData,
        hp: RWSADMMHparams = RWSADMMHparams(),
        *,
        batch_size: int = 20,
        zone_size: int = 8,
        min_degree: int = 5,
        regen_every: int = 10,
        transition: str = "degree",
        warm_init: bool = True,
        solver: str = "prox_sgd",   # "prox_sgd" (Eq. 9, K steps) |
                                    # "closed_form" (Eq. 10/11, one step)
        inner_steps: int = 10,
        inner_lr: float = 0.05,
        dp_clip: float | None = None,     # l2 clip on uploaded Δc (DP)
        dp_noise: float = 1.0,            # Gaussian noise multiplier σ
        seed: int = 0,
    ):
        super().__init__(model, data, batch_size)
        self.hp = hp
        self.solver = solver
        self.dp_clip = dp_clip
        self.dp_noise = dp_noise
        self.inner_steps = int(inner_steps)
        self.inner_lr = float(inner_lr)
        self.zone_size = int(min(zone_size, self.n_clients))
        self.warm_init = warm_init
        self.dyn_graph = DynamicGraph(
            self.n_clients, min_degree=min_degree,
            regen_every=regen_every, seed=seed,
        )
        self.walker = RandomWalkServer(transition=transition, seed=seed + 1)
        self.walker.reset(self.dyn_graph.current())
        self._round_fn = jax.jit(functools.partial(self._round_impl))

    # ------------------------------------------------------------------
    def init_state(self, key) -> RWSADMMState:
        params = self.model.init(key)
        if self.warm_init:
            clients, server = rwsadmm.init_states_warm(
                params, self.hp, self.n_clients
            )
        else:
            clients, server = rwsadmm.init_states(
                params, self.hp, self.n_clients
            )
        return RWSADMMState(
            clients=clients, server=server,
            visited=jnp.zeros((self.n_clients,), bool),
        )

    # ------------------------------------------------------------------
    def _round_impl(self, state: RWSADMMState, zone_idx, zone_mask, n_i,
                    key):
        clients, server = state.clients, state.server
        hp, kappa = self.hp, server.kappa

        # Gather active clients' ADMM variables: (Z, ...)
        gather = lambda t: jax.tree_util.tree_map(lambda l: l[zone_idx], t)
        act = ClientState(x=gather(clients.x), z=gather(clients.z))

        keys = jax.random.split(key, self.zone_size)

        if self.solver == "closed_form":
            # One-step stochastic linearization (Eq. 10/11).
            def one_grad(params, client, k):
                xb, yb = sample_batch(self.data, client, k, self.batch_size)
                return self.value_and_grad_fn(params, xb, yb, k)

            losses, grads = jax.vmap(one_grad)(act.x, zone_idx, keys)
            upd = jax.vmap(
                lambda c, g: rwsadmm.client_round(c, server.y, g, hp, kappa)
            )
            new_act, c_new, c_old = upd(act, grads)
        else:
            # Iterative solver of the x-subproblem (Eq. 9): K stochastic
            # subgradient steps, warm-started at the client's stored x'.
            eta = self.inner_lr

            def solve_one(c: ClientState, client, k):
                def body(x, kk):
                    xb, yb = sample_batch(self.data, client, kk,
                                          self.batch_size)
                    loss, gf = self.value_and_grad_fn(x, xb, yb, kk)
                    g = rwsadmm.subproblem_grad(x, server.y, c.z, gf, hp)
                    x = jax.tree_util.tree_map(
                        lambda a, b: a - eta * b, x, g
                    )
                    return x, loss

                kks = jax.random.split(k, self.inner_steps)
                x_new, losses_ = jax.lax.scan(body, c.x, kks)
                z_new = rwsadmm.z_update(x_new, server.y, c.z, hp, kappa)
                c_old_ = rwsadmm.contribution(c.x, c.z, server.y, hp)
                c_new_ = rwsadmm.contribution(x_new, z_new, server.y, hp)
                return (ClientState(x=x_new, z=z_new), c_new_, c_old_,
                        losses_[-1])

            new_act, c_new, c_old, losses = jax.vmap(solve_one)(
                act, zone_idx, keys
            )

        # Masked incremental y-update:  y += (1/n) Σ_active (c_new − c_old)
        # (1/n, not the printed 1/n_i — see core.rwsadmm.y_update docstring.)
        m = zone_mask  # (Z,)
        n_total = float(self.n_clients)

        if self.dp_clip is not None:
            # DP uploads: clip + noise each active client's Δc before it
            # reaches the walking token (core/privacy.py).
            from ..core import privacy

            dkeys = jax.random.split(jax.random.fold_in(key, 97),
                                     self.zone_size)
            deltas = jax.vmap(
                lambda k_, cn, co: privacy.privatize_delta(
                    k_, cn, co, clip=self.dp_clip,
                    noise_multiplier=self.dp_noise)
            )(dkeys, c_new, c_old)
        else:
            deltas = jax.tree_util.tree_map(
                lambda cn, co: cn - co, c_new, c_old)

        def fold(y, d):
            mm = m.reshape((-1,) + (1,) * (d.ndim - 1))
            return y + jnp.sum(mm * d, axis=0) / n_total

        y_new = jax.tree_util.tree_map(fold, server.y, deltas)

        # Scatter active deltas back (duplicate-free: zone indices unique,
        # padded slots masked to zero so .add is a no-op for them).
        def scatter(full, old_act, new_act_):
            mm = m.reshape((-1,) + (1,) * (new_act_.ndim - 1))
            return full.at[zone_idx].add(mm * (new_act_ - old_act))

        clients = ClientState(
            x=jax.tree_util.tree_map(scatter, clients.x, act.x, new_act.x),
            z=jax.tree_util.tree_map(scatter, clients.z, act.z, new_act.z),
        )
        server = ServerState(
            y=y_new,
            kappa=server.kappa * hp.kappa_decay,
            round=server.round + 1,
        )
        visited = state.visited.at[zone_idx].max(m > 0)
        zone_loss = jnp.sum(losses * m) / jnp.maximum(jnp.sum(m), 1.0)
        return RWSADMMState(clients, server, visited), zone_loss

    # ------------------------------------------------------------------
    def round(self, state: RWSADMMState, rnd: int, rng: np.random.Generator):
        graph = self.dyn_graph.step() if rnd > 0 else self.dyn_graph.current()
        i_k = self.walker.step(graph) if rnd > 0 else self.walker.position
        zone = graph.neighborhood(i_k)
        n_i = len(zone)
        if n_i > self.zone_size:
            # S(i_k) ⊂ N(i_k): i_k + random neighbors (Eq. 31 subset).
            others = zone[zone != i_k]
            pick = rng.choice(others, size=self.zone_size - 1, replace=False)
            active = np.concatenate([[i_k], pick])
        else:
            active = zone
        mask = np.zeros(self.zone_size, np.float32)
        mask[: len(active)] = 1.0
        idx = np.zeros(self.zone_size, np.int32)
        idx[: len(active)] = active

        key = jax.random.PRNGKey(rng.integers(2**31 - 1))
        state, zone_loss = self._round_fn(
            state, jnp.asarray(idx), jnp.asarray(mask),
            jnp.asarray(float(n_i)), key,
        )
        metrics = {
            "round": rnd,
            "client": int(i_k),
            "zone": int(len(active)),
            "n_i": n_i,
            "train_loss": float(zone_loss),
            "kappa": float(state.server.kappa),
            "comm_bytes": self.comm_bytes_per_round(len(active)),
        }
        return state, metrics

    # ------------------------------------------------------------------
    def personalized_params(self, state: RWSADMMState):
        """x_i for visited clients; unvisited clients fall back to the
        server token y (what the mobile server would hand them)."""
        def leaf(x, y):
            v = state.visited.reshape((-1,) + (1,) * (y.ndim))
            return jnp.where(v, x, y[None])

        return jax.tree_util.tree_map(leaf, state.clients.x, state.server.y)

    def global_params(self, state: RWSADMMState):
        return state.server.y

    def comm_bytes_per_round(self, participants: int) -> int:
        # Server broadcasts y once into the zone; each active client
        # uploads its contribution delta. O(1) in n — the paper's claim.
        from ..core import tree as t

        p_bytes = t.n_bytes(self.model.init(jax.random.PRNGKey(0)))
        return int((1 + participants) * p_bytes)

    # -- diagnostics -----------------------------------------------------
    def lyapunov(self, state: RWSADMMState, key) -> dict:
        """L_β and constraint residuals (Eq. 8 / Eq. 7) for monitoring."""
        losses = []
        for c in range(self.n_clients):
            xi = jax.tree_util.tree_map(lambda l: l[c], state.clients.x)
            losses.append(self._train_loss_client(xi, c, key))
        losses = jnp.stack(losses)
        l_beta = rwsadmm.augmented_lagrangian(
            state.server.y, state.clients, losses, self.hp
        )
        viol = rwsadmm.constraint_violation(
            state.server.y, state.clients.x, self.hp
        )
        return {"L_beta": float(l_beta), "violation": float(viol)}
