"""Client-plane sharding: NamedShardings over a 1-D "data" mesh.

The FL trainers' big arrays all share one layout: a leading client axis
— dense stacked client-state pytrees and ``DeviceData`` columns are
``(n, …)``, the lazy plane's packed store rows are ``(capacity, …)``.
:class:`FLSharding` gives every such leaf a ``NamedSharding`` that
splits that leading axis across the mesh "data" axis, reusing the
divisibility-fallback ``_spec`` rule from ``launch/sharding.py``: a
leading dim that does not divide the device count falls back to
replication on that leaf (so ragged shapes never break lowering — but
pick ``capacity % n_devices == 0`` to actually shard the store; see
docs/performance.md §8).

Everything with no client axis (server/token pytrees, schedule scalars)
stays replicated. Inside jit we rely on sharding propagation: the
Eq. 31 zone update, rendezvous means, and row-based eval are all
elementwise/reduction programs over the leading axis, so placing the
inputs is enough — XLA partitions the loops and inserts collectives
only at the scalar reductions.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..launch.mesh import make_data_mesh
from ..launch.sharding import _spec


class FLSharding:
    """Thin bridge: mesh + per-leaf row/replicated placements."""

    def __init__(self, mesh=None, *, n_devices: int | None = None):
        self.mesh = mesh if mesh is not None \
            else make_data_mesh(n_devices)
        if "data" not in self.mesh.axis_names:
            raise ValueError(
                f"FL mesh needs a 'data' axis, got {self.mesh.axis_names}")

    @property
    def n_devices(self) -> int:
        return int(self.mesh.shape["data"])

    # ---- per-leaf shardings -----------------------------------------
    def row_sharding(self, leaf) -> NamedSharding:
        """Leading axis over "data" (divisibility fallback → replicate)."""
        shape = getattr(leaf, "shape", ())
        if not shape:
            return self.replicated_sharding()
        wanted = [("data",)] + [None] * (len(shape) - 1)
        return NamedSharding(self.mesh, _spec(self.mesh, shape, wanted))

    def replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # ---- pytree placement -------------------------------------------
    def shard_rows(self, tree):
        """device_put every leaf with its leading axis over "data".

        device_put with an identical sharding is a no-op, so re-placing
        an already-sharded tree (e.g. after store ensure() writes) is
        cheap."""
        return jax.tree_util.tree_map(
            lambda leaf: jax.device_put(leaf, self.row_sharding(leaf)),
            tree)

    def replicate(self, tree):
        sh = self.replicated_sharding()
        return jax.tree_util.tree_map(
            lambda leaf: jax.device_put(leaf, sh), tree)

    def row_shardings(self, tree):
        """Sharding pytree matching ``tree`` (for jit in/out_shardings)."""
        return jax.tree_util.tree_map(self.row_sharding, tree)
